"""Paper Table 2 — accuracy restoration by fine-tuning ONLY the LP-merged
layers (AdamW, linear decay — the paper's recipe)."""
from __future__ import annotations

import jax

from benchmarks import common as C
from repro.core.lp import plan_for_depth
from repro.data import lm_batch
from repro.model import transformer as T
from repro.train import OptConfig, TrainConfig
from repro.train.trainer import make_train_step, state_from_params


def run(*, train_steps: int = 1200, ft_steps: int = 300, depth_cut: int = 3):
    params = C.train_bench_model(train_steps)
    n = C.BENCH_CFG.n_layers
    ms0 = T.build_structure(C.BENCH_CFG, tp=1)
    base_icl = C.eval_icl(params, ms0)
    base_ppl = C.eval_ppl(params, ms0)

    plan = plan_for_depth(C.BENCH_CFG, n - depth_cut, end=n - 1)
    ms, p_lp = C.params_with_plan(params, plan)
    rows = [{"steps": "base", "icl": round(base_icl, 4),
             "ppl": round(base_ppl, 3)},
            {"steps": 0, "icl": round(C.eval_icl(p_lp, ms), 4),
             "ppl": round(C.eval_ppl(p_lp, ms), 3)}]
    print(f"base: icl={rows[0]['icl']} ppl={rows[0]['ppl']}")
    print(f"LP  : icl={rows[1]['icl']} ppl={rows[1]['ppl']}")

    tc = TrainConfig(opt=OptConfig(lr=1e-4, warmup_steps=10,
                                   total_steps=ft_steps, schedule="linear",
                                   weight_decay=0.01),
                     finetune_lp_only=True)
    state = state_from_params(p_lp, ms, C.PC, tc)
    step_fn = jax.jit(make_train_step(ms, C.PC, tc), donate_argnums=(0,))
    key = jax.random.PRNGKey(777)
    checkpoints = sorted({ft_steps // 4, ft_steps // 2, ft_steps})
    for s in range(ft_steps):
        batch = lm_batch(jax.random.fold_in(key, s), C.SC, C.SEQ, 16)
        state, m = step_fn(state, batch)
        if (s + 1) in checkpoints:
            icl = C.eval_icl(state["params"], ms)
            ppl = C.eval_ppl(state["params"], ms)
            rows.append({"steps": s + 1, "icl": round(icl, 4),
                         "ppl": round(ppl, 3)})
            print(f"ft {s + 1:4d}: icl={icl:.4f} ppl={ppl:.3f} "
                  f"(loss {float(m['loss']):.3f})")
    out = {"plan_pairs": list(map(list, plan.pairs)), "rows": rows}
    C.save_result("finetune_recovery", out)
    return out


if __name__ == "__main__":
    run()
