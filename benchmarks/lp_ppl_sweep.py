"""Paper Fig. 6 — perplexity when running pairs of consecutive layers in
parallel, as a function of Δ (layers merged) and the end index of the
parallelised interval."""
from __future__ import annotations

import jax

from benchmarks import common as C
from repro.core.lp import LPPlan, plan_range


def run(*, train_steps: int = 1200):
    params = C.train_bench_model(train_steps)
    n = C.BENCH_CFG.n_layers
    ms0 = __import__("repro.model.transformer", fromlist=["build_structure"]) \
        .build_structure(C.BENCH_CFG, tp=1)
    base = C.eval_ppl(params, ms0)
    rows = []
    for end in (n, n - 1):
        for n_pairs in range(1, (end // 2) + 1):
            start = end - 2 * n_pairs
            if start < 0:
                continue
            plan = plan_range(C.BENCH_CFG, start, end)
            plan = LPPlan(plan.pairs[-n_pairs:])
            ms, p = C.params_with_plan(params, plan)
            ppl = C.eval_ppl(p, ms)
            rows.append({"end": end, "delta": plan.delta,
                         "eff_depth": ms.effective_depth,
                         "ppl": round(ppl, 3)})
            print(f"end={end:2d} Δ={plan.delta:2d} "
                  f"eff_depth={ms.effective_depth:2d} ppl={ppl:.3f}")
    out = {"base_ppl": base, "rows": rows}
    C.save_result("lp_ppl_sweep", out)
    return out


if __name__ == "__main__":
    run()
