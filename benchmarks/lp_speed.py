"""Paper Fig. 7/8 + Table 3 + Appendix C — inference speed and the source
of the acceleration.

Two measurements, both on an 8-host-device mesh (subprocess):
  (a) STRUCTURAL (the dry-run analogue of the paper's flame graphs):
      all-reduce count + wire bytes of one decode step, prefill and train
      micro, vanilla vs LP — LP must remove exactly 2 ARs per pair.
  (b) WALL-CLOCK: decode-step latency on the CPU mesh (collectives are
      real inter-device copies here), vanilla vs LP across Δ.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks import common as C

_CHILD = r"""
import json, time
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import transformer as T
from repro.model import stack as STK
from repro.serve.engine import ServeConfig, make_sharded_serve_step
from repro.analysis.roofline import collective_bytes

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=12)
mesh = jax.make_mesh((2, 4), ("data", "model"))
MAXLEN = 512
BATCH = 8

def build(plan):
    ms = T.build_structure(cfg, plan=plan, tp=4)
    sv = ServeConfig(max_len=MAXLEN, kv_mode="heads", cache_dtype=jnp.float32)
    fn, c_abs, c_specs, pc = make_sharded_serve_step(ms, mesh, sv, batch=BATCH)
    params = T.init_params(ms, jax.random.PRNGKey(0))
    caches = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), c_abs)
    tok = jnp.zeros((BATCH,), jnp.int32)
    key = jax.random.PRNGKey(1)
    return ms, fn, params, caches, tok, key

rows = []
for n_pairs in (0, 2, 4, 6):
    plan = LPPlan(plan_range(cfg, 0, 12).pairs[:n_pairs])
    ms, fn, params, caches, tok, key = build(plan)
    # (a) structural: collective counts from compiled HLO (scans unrolled)
    STK.set_scan_unroll(True)
    try:
        low = fn.lower(params, tok, caches, jnp.int32(64), key)
        txt = low.compile().as_text()
    finally:
        STK.set_scan_unroll(False)
    coll = collective_bytes(txt)
    # (b) wall clock: median of 30 steps after warmup
    nxt, caches = fn(params, tok, caches, jnp.int32(64), key)  # compile+warm
    jax.block_until_ready(nxt)
    times = []
    for i in range(30):
        t0 = time.perf_counter()
        nxt, caches = fn(params, nxt, caches, jnp.int32(65 + i), key)
        jax.block_until_ready(nxt)
        times.append(time.perf_counter() - t0)
    times.sort()
    med = times[len(times) // 2]
    rows.append({
        "delta": plan.delta,
        "eff_depth": ms.effective_depth,
        "ar_count": int(coll.get("count:all-reduce", 0)),
        "coll_bytes": coll.get("total", 0.0),
        "decode_ms": round(med * 1e3, 3),
    })
print("RESULT " + json.dumps(rows))
"""


def run():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                       text=True, env=env, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr
    rows = json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("RESULT")][0][7:])
    base = rows[0]
    print(f"{'Δ':>3s} {'depth':>5s} {'ARs':>4s} {'collGB':>8s} "
          f"{'decode ms':>10s} {'speedup':>8s}")
    for row in rows:
        sp = base["decode_ms"] / row["decode_ms"]
        row["speedup"] = round(sp, 3)
        print(f"{row['delta']:3d} {row['eff_depth']:5d} {row['ar_count']:4d} "
              f"{row['coll_bytes'] / 1e9:8.4f} {row['decode_ms']:10.3f} "
              f"{sp:8.3f}x")
    # The paper's structural claim: 2 fewer ARs per pair.
    for row in rows[1:]:
        pairs = row["delta"] // 2
        assert base["ar_count"] - row["ar_count"] == 2 * pairs, (base, row)
    C.save_result("lp_speed", {"rows": rows})
    return {"rows": rows}


if __name__ == "__main__":
    run()
