"""Paper Fig. 7/8 + Table 3 + Appendix C — inference speed and the source
of the acceleration.

Three measurements, the first two on an 8-host-device mesh (subprocess):
  (a) STRUCTURAL (the dry-run analogue of the paper's flame graphs):
      all-reduce count + wire bytes of one decode step, prefill and train
      micro, vanilla vs LP — LP must remove exactly 2 ARs per pair.
  (b) WALL-CLOCK: decode-step latency on the CPU mesh (collectives are
      real inter-device copies here), vanilla vs LP across Δ.
  (c) LAUNCH COUNTS: per-decode-step attention kernel launches and cache
      ring-slot writes. The fused pair path (stacked caches +
      decode_attention_pair) must show ONE attention launch per paired
      phase — pairs collapse 2 launches and 4 cache writes into 1 and 2.

``--structural`` (or run(structural_only=True)) skips the wall-clock half
so CI can gate on (a) + (c) cheaply.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks import common as C

_CHILD = r"""
import json, os, time
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import attention as ATT
from repro.model import transformer as T
from repro.model import stack as STK
from repro.parallel.context import ParallelContext
from repro.serve.engine import ServeConfig, make_sharded_serve_step
from repro.analysis.roofline import collective_bytes, jaxpr_primitive_count

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=12)
from repro.launch.mesh import parse_mesh_spec
DATA, MODEL = parse_mesh_spec(os.environ.get("LP_SPEED_MESH", "2x4"))
assert DATA * MODEL <= len(jax.devices()), (
    f"mesh {DATA}x{MODEL} needs {DATA * MODEL} devices, the subprocess "
    f"forces {len(jax.devices())}")
mesh = jax.make_mesh((DATA, MODEL), ("data", "model"))
MAXLEN = 512
BATCH = 8
STRUCTURAL_ONLY = os.environ.get("LP_SPEED_STRUCTURAL", "0") == "1"

def build(plan):
    ms = T.build_structure(cfg, plan=plan, tp=MODEL)
    sv = ServeConfig(max_len=MAXLEN, kv_mode="heads", cache_dtype=jnp.float32)
    fn, c_abs, c_specs, pc = make_sharded_serve_step(ms, mesh, sv, batch=BATCH)
    params = T.init_params(ms, jax.random.PRNGKey(0))
    caches = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), c_abs)
    tok = jnp.zeros((BATCH,), jnp.int32)
    key = jax.random.PRNGKey(1)
    return ms, fn, params, caches, tok, key

def attn_launches(plan):
    # Kernel launches per decode step: trace the SINGLE-DEVICE decode step
    # with the Pallas decode impl and count pallas_call eqns per executed
    # step (scan bodies weighted by trip count). The fused pair path makes
    # this n_layers - n_pairs; the per-half loop would give n_layers.
    ms1 = T.build_structure(cfg, plan=plan, tp=1)
    params = jax.eval_shape(lambda: T.init_params(ms1, jax.random.PRNGKey(0)))
    c_abs, _ = T.cache_meta(ms1, batch=1, max_len=64, dtype=jnp.float32)
    ATT.set_decode_impl("pallas")
    try:
        jaxpr = jax.make_jaxpr(
            lambda p, c: T.decode_step(p, jnp.zeros((1,), jnp.int32), c,
                                       jnp.int32(3), ms=ms1,
                                       pc=ParallelContext()))(params, c_abs)
    finally:
        ATT.set_decode_impl("xla")
    return jaxpr_primitive_count(jaxpr, "pallas_call")

rows = []
for n_pairs in (0, 2, 4, 6):
    plan = LPPlan(plan_range(cfg, 0, 12).pairs[:n_pairs])
    ms, fn, params, caches, tok, key = build(plan)
    # (a) structural: collective + cache-write counts from compiled HLO
    # (scans unrolled)
    STK.set_scan_unroll(True)
    try:
        low = fn.lower(params, tok, caches, jnp.int32(64), key)
        txt = low.compile().as_text()
    finally:
        STK.set_scan_unroll(False)
    coll = collective_bytes(txt)
    row = {
        "delta": plan.delta,
        "eff_depth": ms.effective_depth,
        "ar_count": int(coll.get("count:all-reduce", 0)),
        "coll_bytes": coll.get("total", 0.0),
        "cache_writes": txt.count("dynamic-update-slice("),
        "attn_launches": attn_launches(plan),
    }
    # (b) wall clock: median of 30 steps after warmup
    if not STRUCTURAL_ONLY:
        nxt, caches = fn(params, tok, caches, jnp.int32(64), key)  # warm
        jax.block_until_ready(nxt)
        times = []
        for i in range(30):
            t0 = time.perf_counter()
            nxt, caches = fn(params, nxt, caches, jnp.int32(65 + i), key)
            jax.block_until_ready(nxt)
            times.append(time.perf_counter() - t0)
        times.sort()
        row["decode_ms"] = round(times[len(times) // 2] * 1e3, 3)
    rows.append(row)
print("RESULT " + json.dumps(rows))
"""


def run(structural_only: bool = False, mesh: str = "2x4"):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["LP_SPEED_STRUCTURAL"] = "1" if structural_only else "0"
    env["LP_SPEED_MESH"] = mesh  # DxM: tp = M (the 2-ARs-per-pair claim is
    # tp-degree-invariant; CI gates it at tp=4 and tp=2)
    r = subprocess.run([sys.executable, "-c", _CHILD], capture_output=True,
                       text=True, env=env, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr
    rows = json.loads([l for l in r.stdout.splitlines()
                       if l.startswith("RESULT")][0][7:])
    base = rows[0]
    hdr = (f"{'Δ':>3s} {'depth':>5s} {'ARs':>4s} {'launch':>6s} "
           f"{'writes':>6s} {'collGB':>8s}")
    if not structural_only:
        hdr += f" {'decode ms':>10s} {'speedup':>8s}"
    print(hdr)
    for row in rows:
        line = (f"{row['delta']:3d} {row['eff_depth']:5d} {row['ar_count']:4d} "
                f"{row['attn_launches']:6d} {row['cache_writes']:6d} "
                f"{row['coll_bytes'] / 1e9:8.4f}")
        if not structural_only:
            sp = base["decode_ms"] / row["decode_ms"]
            row["speedup"] = round(sp, 3)
            line += f" {row['decode_ms']:10.3f} {sp:8.3f}x"
        print(line)
    for row in rows[1:]:
        pairs = row["delta"] // 2
        # The paper's structural claim: 2 fewer ARs per pair.
        assert base["ar_count"] - row["ar_count"] == 2 * pairs, (base, row)
        # The fused decode claim: ONE attention launch per paired phase.
        # (cache_writes is reported, not gated: the HLO dynamic-update-slice
        # count also includes scan-carry updates, so it has no clean
        # per-pair delta — the scatter-count gate lives in
        # benchmarks/serve_throughput.py --structural, counted in jaxpr.)
        assert base["attn_launches"] - row["attn_launches"] == pairs, (base, row)
    # Distinct file per mesh so the tp=2 sharded-structural run never
    # clobbers the tp=4 baseline artifact (serve_throughput's _tp suffix
    # convention); the payload records the mesh either way.
    name = "lp_speed" if mesh == "2x4" else f"lp_speed_{mesh}"
    C.save_result(name, {"mesh": mesh, "rows": rows})
    return {"mesh": mesh, "rows": rows}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description="LP decode speed benchmark")
    ap.add_argument("--structural", action="store_true",
                    help="skip wall-clock timing; assert only the AR-count "
                         "and launch-count invariants (CI gate)")
    ap.add_argument("--mesh", default="2x4",
                    help="DxM subprocess device mesh (8 host devices); "
                         "tp = M — e.g. 4x2 gates the claims at tp=2")
    args = ap.parse_args()
    run(structural_only=args.structural, mesh=args.mesh)
