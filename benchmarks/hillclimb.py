"""§Perf hillclimb driver: re-lower the three featured cells after each
optimisation and append (hypothesis -> change -> before -> after) records to
benchmarks/results/perf_log.json.

    PYTHONPATH=src python -m benchmarks.hillclimb --tag iter123
"""
import argparse
import dataclasses
import json
import os

CELLS = [
    # (arch, shape, label, policy_override)
    ("yi-6b", "train_4k", "lp", None),
    ("yi-6b", "decode_32k", "lp", None),
    ("yi-6b", "decode_32k", "nolp", "nolp"),
    ("minicpm-2b", "decode_32k", "lp", None),
    ("llama4-scout-17b-a16e", "prefill_32k", "lp", None),
    ("dbrx-132b", "decode_32k", "lp", None),
    ("dbrx-132b", "decode_32k", "lp+int8", "quant"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", required=True)
    ap.add_argument("--cells", default=None,
                    help="comma-separated indices into CELLS")
    args = ap.parse_args()

    from repro.launch.dryrun import RESULTS, lower_cell
    out_path = os.path.join(RESULTS, "perf_log.json")
    log = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            log = json.load(f)

    idxs = (list(range(len(CELLS))) if args.cells is None
            else [int(i) for i in args.cells.split(",")])
    for i in idxs:
        arch, shape, label, mode = CELLS[i]
        key = f"{args.tag}/{arch}/{shape}/{label}"
        if key in log:
            print(f"[cached] {key}")
            continue
        override = None
        lp = True
        if mode == "quant":
            override = lambda p: dataclasses.replace(p, quant=True)
        elif mode == "nolp":
            lp = False
        print(f"[lower] {key}", flush=True)
        try:
            rec = lower_cell(arch, shape, lp=lp, policy_override=override)
        except Exception as e:
            import traceback; traceback.print_exc()
            rec = {"error": str(e)[:400]}
        log[key] = rec
        with open(out_path, "w") as f:
            json.dump(log, f, indent=1)
        if "roofline" in rec:
            r = rec["roofline"]
            print(f"  {r['bottleneck']} t=({r['t_compute_s']:.4f},"
                  f"{r['t_memory_s']:.4f},{r['t_collective_s']:.4f})s "
                  f"ops={int(r['coll_ops'])} "
                  f"peak={rec['memory'].get('peak_gb', -1):.2f}GB "
                  f"roofline={r['roofline_fraction']:.3f}", flush=True)


if __name__ == "__main__":
    main()
