"""Paper Fig. 3/4 — effective-depth heatmaps.

Applies each transformation (shuffle / prune / merge / parallel /
2-parallel) to contiguous layer stretches [s, e] of the trained benchmark
model and records the perplexity grid. Reproduces the paper's QUALITATIVE
claims:
  * mid-stack stretches tolerate shuffling and 2-parallel with small PPL
    cost; pruning/merging the same stretch is far worse;
  * contiguous 2-parallel tolerates the LONGEST stretches (the basis of LP).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import interventions as IV
from repro.data import eval_ppl_batch
from repro.model import transformer as T
from repro.model.norms import apply_norm
from repro.model import embedding as E


def _ppl_with_plan(params, layer_params, plan, *, n_batches=2, batch=8):
    """PPL evaluating the intervened stack inside the full model."""
    cfg = C.BENCH_CFG
    tot = 0.0
    for i in range(n_batches):
        b = eval_ppl_batch(jax.random.PRNGKey(10_000 + i), C.SC, C.SEQ, batch)
        toks, labels = b["tokens"], b["labels"]
        pos = jnp.arange(toks.shape[1])[None]
        x = E.embed_lookup(params["embed"], toks, C.PC)
        x = IV.apply_intervened(layer_params, plan, x, cfg=cfg, positions=pos)
        x = apply_norm(x, params["final_norm"], cfg)
        logits = E.local_logits(params["embed"], x, cfg, C.PC)
        xent = E.vocab_parallel_xent(logits, labels, C.PC)
        tot += float(xent)
    return float(np.exp(tot / n_batches))


def run(*, stride: int = 2, n_batches: int = 2, train_steps: int = 1200):
    params = C.train_bench_model(train_steps)
    layers = C.layer_params_of(params)
    n = C.BENCH_CFG.n_layers
    base = _ppl_with_plan(params, layers, IV.sequential_plan(n),
                          n_batches=n_batches)
    print(f"base ppl = {base:.3f}")
    grids = {}
    kinds = ["shuffle", "prune", "merge", "parallel", "parallel2"]
    for kind in kinds:
        grid = {}
        for s in range(0, n - 1, stride):
            for e in range(s + 1, n, stride):
                if kind == "shuffle":
                    plan = IV.shuffle_plan(n, s, e, jax.random.PRNGKey(s * n + e))
                    lp = layers
                elif kind == "prune":
                    plan = IV.prune_plan(n, s, e)
                    lp = layers
                elif kind == "merge":
                    lp, plan = IV.merge_avg(layers, s, e)
                elif kind == "parallel":
                    plan = IV.parallel_plan(n, s, e, form="par")
                    lp = layers
                else:
                    plan = IV.parallel2_plan(n, s, e, form="tp")
                    lp = layers
                ppl = _ppl_with_plan(params, lp, plan, n_batches=n_batches)
                grid[f"{s},{e}"] = round(ppl, 3)
        grids[kind] = grid
        best = min(grid.values())
        worst = max(grid.values())
        print(f"{kind:10s}: ppl range [{best:.2f}, {worst:.2f}] over "
              f"{len(grid)} (s,e) cells")

    # The paper's headline orderings, asserted on the mid-stack stretch:
    mid = f"{2},{n - 3}"
    summary = {
        "base_ppl": base,
        "mid_stretch": mid,
        "mid": {k: grids[k].get(mid) for k in kinds},
        "grids": grids,
    }
    C.save_result("effective_depth", summary)
    print("mid-stretch ppl:", {k: summary['mid'][k] for k in kinds})
    return summary


if __name__ == "__main__":
    run()
