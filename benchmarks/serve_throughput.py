"""Continuous-batching serving benchmark over the paged pair-KV cache.

Synthetic Poisson arrivals drive ``repro.serve.PagedEngine``: requests with
mixed prompt lengths arrive at exponential inter-arrival times, share the
page pool, and finish independently. Reported per run:

  tokens/s            — generated tokens over wall-clock drain time
  latency p50 / p99   — per-request submit -> finish wall time
  TTFT p50 / p99      — submit -> first generated token wall time
  occupancy mean/max  — live pages / allocatable pages per engine step
  LP speedup          — tokens/s of the LP-paired model over vanilla (the
                        paper's decode win, now measured under serving load)

Latency/TTFT/occupancy/prefix numbers all come from
``engine.metrics_snapshot()`` — the telemetry subsystem's span-derived
percentiles — not from benchmark-side timestamp dicts. (The pre-telemetry
host-side bookkeeping that once cross-checked the snapshot is gone: it
rode along as ``_drive(..., legacy_check=True)`` for two PRs of overlap
and the snapshot never drifted.)

``--shared-prefix`` switches to deployment-shaped traffic: N request
families share a per-family system prompt (whole cache pages), exercising
the radix prefix cache — additionally reported are the prefix hit rate,
prefill tokens saved, and the engine-on vs engine-off comparison.
``--seed`` fixes the Poisson arrival stream and all prompt tokens.

``--structural`` (the serve-structural CI gate) skips the wall clock and
asserts the subsystem's invariants instead:
  (a) the paged pair decode still does ONE attention kernel launch and one
      scatter per cache tensor per paired phase — each LP pair removes 1
      launch and 2 cache writes per decode step, exactly like the ring
      fast path lp_speed gates on;
  (b) page accounting balances at every step (allocated - freed ==
      live_unique, checked inside engine.step) and drains to the radix
      tree's residents (zero with the tree disabled);
  (c) >= 8 concurrent, staggered requests come out bit-identical to
      one-shot generate().
``--structural --shared-prefix`` adds the prefix/preemption gates:
  (d) prefix hit rate > 0 and >= 30% of prompt tokens served from cache
      instead of prefill on the family workload, with the SAME launch
      counts (sharing adds zero kernel launches);
  (e) every prefix-hit request bit-identical to one-shot generate();
  (f) a preempted-then-resumed request bit-identical to its uninterrupted
      run (the engine also self-checks every replayed token).
``--structural`` also gates bucketed batched prefill (PR 9):
  (w) every cold prefill of the staggered workload runs through the
      bucket path (bucket_prefills == full_prefills), in FEWER launches
      than requests (bucket_groups < bucket_prefills: batching actually
      happened), with prefill compile count <= the ladder length — while
      the exact-length reference engine compiles one program per
      distinct prompt length;
  (x) the bucketed engine's greedy streams are BIT-identical to the
      exact-length engine (``prefill_buckets=()``) on the same staggered
      arrivals, with identical page accounting (padding never allocates);
  (y) on a varied-length arrival stream (more distinct lengths than
      ladder rungs) the bucketed engine's TTFT p50/p99 land in
      BENCH_serve.json ("prefill_batch" section) next to the exact
      engine's — the compile-stall win the redesign exists for.
``--structural`` also gates the telemetry subsystem (PR 7):
  (p) telemetry-on vs telemetry-off: identical greedy streams, identical
      step/page accounting, identical counters and compile events — the
      registry is pure host bookkeeping and observing a run may never
      change it (launch counts are a per-PROGRAM property gated in (a);
      telemetry never enters a traced function, so they cannot move);
  (r) ``engine.dump_trace`` writes valid Chrome trace_event JSON
      (results/trace_structural.json, uploaded as a CI artifact).
``--structural --mesh 1x2`` (the sharded-structural CI gate, needs
XLA_FLAGS=--xla_force_host_platform_device_count=8) runs the tp>1 half:
  (g) launches == groups and scatters == 2*groups in the SHARD_MAP'd
      paged decode program (one fused launch per paired phase per rank);
  (h) page accounting balance is tp-invariant (same host-side scheduler);
  (i) the tp>1 engine's staggered greedy streams are bit-identical to the
      tp=1 engine AND to one-shot ``sharded_generate`` per request;
  (j) the prefix cache STAYS ON under tp>1 (``prefix_cache=True`` builds a
      live radix tree under the mesh engine, same as tp=1).
``--structural --shared-prefix --mesh 1x2`` (sharded-prefix CI gate) runs
the family workload through the SHARDED engine with the radix cache on:
  (z) measured hit_rate > 0 and prefix_hits > 0 on the tp>1 engine —
      suffix prefills ride the per-row ctx-gather bucket path;
  (z2) every request's greedy stream is bit-identical to the tp=1
      prefix-ON engine on the same arrivals (hit, cold, and resumed rows
      alike — the per-row ctx gather is bit-transparent);
  (z3) page accounting balances and drains to the radix tree's residents,
      tp-invariantly;
  (z4) prefill compile count stays <= the bucket ladder length even with
      heterogeneous (ctx_pages, suffix_len) rows sharing launches — the
      carve-out that previously sent radix hits down the exact-length
      path is closed.

``--chaos`` (the chaos-structural CI gate) runs the hardening soak:
  (k) >= 200 engine steps under a seeded FaultPlan firing all five fault
      kinds (page-alloc failure, NaN logits, block-table corruption,
      poisoned prompts, deadline storms) with page accounting balanced at
      every step and no engine crash;
  (l) every faulted request lands in a TYPED terminal state (failed /
      expired / cancelled) carrying a ServeError;
  (m) surviving requests are bit-identical to the same workload on a
      faults-disabled engine (per-request fault isolation);
  (n) the whole soak replays exactly from the same --seed — including its
      TELEMETRY: the two runs' wall-stripped Chrome traces are
      byte-identical (the trace is evidence, not noise), and the soak's
      trace lands in results/trace_chaos.json;
  (o) under sustained overload the bounded submit queue never exceeds
      max_queue, shedding is deadline-aware, and the aggressive-Δ degraded
      cohort is bit-identical to a fixed-Δ engine re-paired by LP.replan.

``--structural --spec-k K`` (the spec-structural CI gate) runs the
self-speculative decoding half:
  (s) the VERIFY program is the regular paged decode program at batch
      n_slots*(K+1) — widening the batch adds ZERO launches (still one
      fused attention launch + 2 cache writes per paired phase), and the
      DRAFT program over the re-paired shallow structure keeps the
      per-pair launch savings of (a);
  (t) with RAW random weights (draft/full greedy agreement is chance
      level, so rejection + rewind are hammered) the speculative engine's
      greedy streams are BIT-IDENTICAL to the plain engine's under >= 8
      staggered concurrent requests, draft/verify/reject counters
      reconcile (draft_steps == K * verify_steps), exactly ONE verify
      program is ever compiled (launches-per-verify == 1), and page
      accounting balances through every rewind;
  (u) with segment-scaled weights (emulating a trained model's shallow/
      full agreement) the SAME bit-identity holds while accepted-tokens-
      per-verify > 1 and net tok/s >= the non-speculative engine on the
      same warmed workload;
  (v) the acceptance stats land in BENCH_serve.json ("spec" section) and
      the run's trace (results/trace_spec.json) carries per-slot
      ``spec:accepted/probed`` slices.

Every structural run also folds its throughput/latency numbers into
``benchmarks/results/BENCH_serve.json`` so successive PRs leave a
comparable perf trajectory (uploaded as a CI artifact).
"""
from __future__ import annotations

import json
import os
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.analysis.roofline import jaxpr_primitive_count
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_for_depth, plan_range, replan
from repro.launch.mesh import make_serving_mesh
from repro.model import attention as A
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (ALL_FAULT_KINDS, CANCELLED, COHORT_DEGRADED,
                         COHORT_SPEC_DRAFT, COHORT_SPEC_VERIFY, EXPIRED,
                         FAILED, FINISHED, TERMINAL_STATES, FaultPlan,
                         PagedEngine, PagedServeConfig, QueueFullError,
                         ServeConfig, dumps_trace, generate,
                         sharded_generate, validate_trace)
from repro.serve import paged_cache as PG
from repro.serve.engine import make_sharded_serve_step

PC = ParallelContext()

N_LAYERS = 6
MAX_LEN = 64
PAGE_SIZE = 8
N_SLOTS = 8
N_PAGES = 1 + N_SLOTS * (MAX_LEN // PAGE_SIZE)   # full occupancy + garbage
PROMPT_LENS = (8, 16, 24)
MAX_NEW = 16

# Shared-prefix workload geometry: families of equal-total-length prompts
# sharing SHARED_LEN leading tokens (whole pages — the radix match unit).
N_FAMILIES = 4
FAMILY_MEMBERS = 4
SHARED_LEN = 16
TAIL_LEN = 8


def _structure(n_pairs: int, tp: int = 1):
    cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=N_LAYERS)
    plan = LPPlan(plan_range(cfg, 0, N_LAYERS).pairs[:n_pairs])
    return cfg, T.build_structure(cfg, plan=plan, tp=tp)


def _build(n_pairs: int, tp: int = 1):
    # Param shapes are GLOBAL and tp-invariant for the smoke config (heads
    # and vocab divide evenly), so one init serves every tp — which is what
    # lets the tp sweep gate BIT-identity on the same weights.
    cfg, ms = _structure(n_pairs, tp)
    return cfg, ms, T.init_params(ms, jax.random.PRNGKey(0))


# BENCH_serve.json key contract: successive PRs compare these sections
# across runs, so a silently renamed/dropped metric breaks the trajectory
# the artifact exists for. _bench_summary re-validates the WHOLE file on
# every fold and fails on drift (unknown section, missing required key).
BENCH_DRIVE_KEYS = frozenset({"tok_per_s", "lat_p50_ms", "lat_p99_ms",
                              "ttft_p50_ms", "ttft_p99_ms"})
BENCH_CHAOS_KEYS = frozenset({"soak_steps", "faults_applied", "survivors",
                              "overload"})
BENCH_SPEC_KEYS = frozenset({"spec_k", "draft_eff_depth",
                             "accept_per_verify", "accept_rate",
                             "spec_tok_per_s", "base_tok_per_s"})
BENCH_PREFILL_KEYS = frozenset({"ttft_p50_ms", "ttft_p99_ms",
                                "exact_ttft_p50_ms", "exact_ttft_p99_ms",
                                "bucket_groups", "bucket_prefills",
                                "pad_tokens", "compiles_prefill",
                                "exact_compiles_prefill", "n_buckets"})
BENCH_SHARDED_PREFIX_KEYS = frozenset({"hit_rate", "prefix_hits",
                                       "prefill_tokens", "hit_tokens",
                                       "suffix_prefills",
                                       "compiles_prefill", "n_buckets",
                                       "tp"})


def _check_bench_schema(data: dict) -> None:
    for section, payload in data.items():
        if re.fullmatch(r"tp\d+", section):
            required = BENCH_DRIVE_KEYS
        elif section == "shared_prefix":
            required = BENCH_DRIVE_KEYS | {"hit_rate"}
        elif section == "chaos":
            required = BENCH_CHAOS_KEYS
        elif section == "spec":
            required = BENCH_DRIVE_KEYS | BENCH_SPEC_KEYS
        elif section == "prefill_batch":
            required = BENCH_PREFILL_KEYS
        elif section == "sharded_prefix":
            required = BENCH_DRIVE_KEYS | BENCH_SHARDED_PREFIX_KEYS
        else:
            raise AssertionError(
                f"BENCH_serve.json schema drift: unknown section "
                f"{section!r} (known: tpN / shared_prefix / chaos / spec "
                f"/ prefill_batch / sharded_prefix)")
        missing = required - payload.keys()
        assert not missing, (
            f"BENCH_serve.json schema drift: section {section!r} lost "
            f"required keys {sorted(missing)}")


def _bench_summary(section: str, payload: dict) -> str:
    """Fold one run's headline numbers into BENCH_serve.json (read-modify-
    write): the per-PR perf trajectory CI uploads as an artifact. Every
    fold re-validates the file against the key contract above."""
    path = os.path.join(C.RESULTS, "BENCH_serve.json")
    os.makedirs(C.RESULTS, exist_ok=True)
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[section] = payload
    _check_bench_schema(data)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    return path


def _drive_summary(m: dict, **extra) -> dict:
    out = {"tok_per_s": m["tok_per_s"], "lat_p50_ms": m["lat_p50_ms"],
           "lat_p99_ms": m["lat_p99_ms"], "ttft_p50_ms": m["ttft_p50_ms"],
           "ttft_p99_ms": m["ttft_p99_ms"]}
    out.update(extra)
    return out


def _snapshot_summary(snap: dict) -> dict:
    """The step-denominated telemetry slice folded into BENCH_serve.json:
    deterministic per-seed, so PRs can diff it exactly (unlike wall ms)."""
    lat = snap["latency"]
    return {"ttft_steps_p50": lat["ttft_steps_p50"],
            "e2e_steps_p50": lat["e2e_steps_p50"],
            "e2e_steps_p99": lat["e2e_steps_p99"],
            "compiles_total": snap["compiles_total"],
            "requests": snap["requests"]}


def _dump_run_artifacts(eng: PagedEngine, tag: str) -> str:
    """Write the run's Chrome trace + metrics snapshot under results/ (CI
    uploads results/*.json); validates the trace before returning it."""
    os.makedirs(C.RESULTS, exist_ok=True)
    trace_path = eng.dump_trace(os.path.join(C.RESULTS, f"trace_{tag}.json"))
    with open(trace_path) as f:
        validate_trace(json.load(f))
    with open(os.path.join(C.RESULTS, f"metrics_{tag}.json"), "w") as f:
        json.dump(eng.metrics_snapshot(), f, indent=1, sort_keys=True)
    return trace_path


def _workload(cfg, n_requests: int, rate: float, seed: int = 17):
    """(arrival_step, prompt, max_new) triples: Poisson arrivals (rate
    requests per engine step), prompt lengths cycled over PROMPT_LENS."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n_requests):
        L = PROMPT_LENS[i % len(PROMPT_LENS)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (L,), 0, cfg.vocab_size))
        reqs.append((int(arrivals[i]), prompt, MAX_NEW))
    return reqs


VARIED_LENS = (5, 9, 12, 17, 21, 26, 30, 34, 39, 44)


def _varied_workload(cfg, n_requests: int, rate: float, seed: int = 23):
    """Arrivals with MORE distinct prompt lengths than the bucket ladder
    has rungs — the regime bucketing exists for: exact-length prefill pays
    one XLA compile (a TTFT stall) per distinct length, the bucket path at
    most one per rung."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n_requests):
        L = VARIED_LENS[i % len(VARIED_LENS)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (L,), 0, cfg.vocab_size))
        reqs.append((int(arrivals[i]), prompt, MAX_NEW))
    return reqs


def _shared_prefix_workload(cfg, rate: float, seed: int = 17):
    """Family traffic: each family shares SHARED_LEN prompt tokens; every
    member has its own TAIL_LEN suffix (equal total length — the regime
    where donor and consumer prefills have identical reduction shapes, so
    sharing is bit-exact). Arrivals are Poisson over the member stream."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    shared = [np.asarray(jax.random.randint(
        jax.random.fold_in(key, 1000 + f), (SHARED_LEN,), 0, cfg.vocab_size))
        for f in range(N_FAMILIES)]
    n = N_FAMILIES * FAMILY_MEMBERS
    gaps = rng.exponential(1.0 / rate, n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    order = rng.permutation(n)
    reqs = []
    for i in range(n):
        f = int(order[i]) % N_FAMILIES
        tail = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (TAIL_LEN,), 0, cfg.vocab_size))
        reqs.append((int(arrivals[i]), np.concatenate([shared[f], tail]),
                     MAX_NEW))
    return reqs


def _drive(eng: PagedEngine, reqs):
    """Run the arrival schedule to drain; per-request metrics (latency +
    TTFT percentiles, occupancy) come from ``engine.metrics_snapshot()``
    — the span-derived telemetry path."""
    rids = []
    nxt = 0
    t0 = time.perf_counter()
    while nxt < len(reqs) or eng.sched.n_queued or eng.sched.n_running:
        while nxt < len(reqs) and reqs[nxt][0] <= eng.step_count:
            _, prompt, max_new = reqs[nxt]
            rids.append(eng.add_request(prompt, max_new))
            nxt += 1
        eng.step()
    wall = time.perf_counter() - t0
    tokens = sum(len(eng.results[r]) for r in rids)
    snap = eng.metrics_snapshot()
    lat = snap["latency"]["wall"]
    occ = snap.get("occupancy", {"mean": 0.0, "max": 0.0})
    m = {
        "wall_s": round(wall, 3),
        "tokens": int(tokens),
        "tok_per_s": round(tokens / wall, 1),
        "lat_p50_ms": lat["lat_p50_ms"],
        "lat_p99_ms": lat["lat_p99_ms"],
        "ttft_p50_ms": lat["ttft_p50_ms"],
        "ttft_p99_ms": lat["ttft_p99_ms"],
        "occ_mean": occ["mean"],
        "occ_max": occ["max"],
        "steps": eng.step_count,
    }
    return m


def _prefix_stats(eng: PagedEngine) -> dict:
    snap = eng.metrics_snapshot()
    c = snap["counters"]
    return {
        "prefill_tokens": c["prefill_tokens"],
        "hit_tokens": c["hit_tokens"],
        "resume_hit_tokens": c["resume_hit_tokens"],
        "replay_tokens": c["replay_tokens"],
        "prefix_hits": c["prefix_hits"],
        "hit_rate": snap["prefix"]["hit_rate"],
        "preemptions": snap["preemptions"],
    }


# ---------------------------------------------------------------------------
# Structural assertions (CI gate)
# ---------------------------------------------------------------------------

def _launch_and_write_counts(ms, n_slots: int):
    """(pallas launches, cache-tensor scatters) in ONE traced paged decode
    step, scan bodies weighted by trip count."""
    params = jax.eval_shape(lambda: T.init_params(ms, jax.random.PRNGKey(0)))
    c_abs, _ = PG.paged_cache_meta(ms, n_slots=n_slots,
                                   n_pages=N_PAGES, page_size=PAGE_SIZE,
                                   dtype=jnp.float32)
    bt = jnp.zeros((n_slots, MAX_LEN // PAGE_SIZE), jnp.int32)
    tv = jnp.zeros((n_slots,), jnp.int32)
    prev = A.get_decode_impl()
    A.set_decode_impl("pallas")
    try:
        jaxpr = jax.make_jaxpr(
            lambda p, c: T.decode_step(
                p, jnp.zeros((n_slots,), jnp.int32), c, tv, ms=ms, pc=PC,
                cache_layout="paged", block_tables=bt))(params, c_abs)
    finally:
        A.set_decode_impl(prev)
    return (jaxpr_primitive_count(jaxpr, "pallas_call"),
            jaxpr_primitive_count(jaxpr, "scatter"))


def structural() -> dict:
    rows = []
    for n_pairs in (0, 1, 3):
        _, ms = _structure(n_pairs)   # launch counting needs shapes only
        launches, writes = _launch_and_write_counts(ms, N_SLOTS)
        groups = N_LAYERS - n_pairs
        # One attention launch + one scatter per cache tensor (k and v)
        # per phase; a fused pair IS one phase for two layers.
        assert launches == groups, (n_pairs, launches, groups)
        assert writes == 2 * groups, (n_pairs, writes, groups)
        rows.append({"pairs": n_pairs, "launches": launches,
                     "cache_writes": writes})
    base = rows[0]
    for row in rows[1:]:
        assert base["launches"] - row["launches"] == row["pairs"], (base, row)
        assert base["cache_writes"] - row["cache_writes"] == 2 * row["pairs"]

    # Accounting balance + bit-identity under staggered continuous batching.
    # (engine.step checks allocated - freed == live_unique at EVERY step.)
    cfg, ms, params = _build(3)
    psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, max_len=MAX_LEN,
                           cache_dtype=jnp.float32)
    eng = PagedEngine(params, ms, psv)
    reqs = _workload(cfg, 12, rate=4.0)
    m = _drive(eng, reqs)
    assert eng.pool.live == 0
    assert eng.pool.allocated_total == eng.pool.freed_total > 0
    sv = ServeConfig(max_len=MAX_LEN, temperature=0.0,
                     cache_dtype=jnp.float32)
    for rid, (_, prompt, max_new) in zip(sorted(eng.results), reqs):
        ref = np.asarray(generate(params, jnp.asarray(prompt)[None],
                                  max_new, ms=ms, pc=PC, sv=sv)[0])
        assert (eng.results[rid] == ref).all(), rid

    # (p) telemetry-off run of the SAME workload: observing the engine may
    # never change it. Greedy streams, step count, page accounting,
    # counters and compile events must all be identical — launch counts
    # cannot move because telemetry never enters a traced program (the
    # per-program gate (a) above counts the only programs there are).
    psv_off = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                               n_pages=N_PAGES, max_len=MAX_LEN,
                               cache_dtype=jnp.float32, telemetry=False)
    eng_off = PagedEngine(params, ms, psv_off)
    _drive(eng_off, reqs)
    assert eng_off.step_count == eng.step_count
    assert sorted(eng_off.results) == sorted(eng.results)
    for rid in eng.results:
        assert (eng_off.results[rid] == eng.results[rid]).all(), rid
    assert eng_off.pool.allocated_total == eng.pool.allocated_total
    assert eng_off.pool.freed_total == eng.pool.freed_total
    assert dict(eng_off.counters) == dict(eng.counters)
    assert eng_off.telemetry.compiles == eng.telemetry.compiles
    assert not eng_off.telemetry.spans          # the only thing that moved

    # (w) bucketed batched prefill: every cold prefill of the staggered
    # workload rode the bucket path, in FEWER launches than requests
    # (batching actually happened), with the prefill compile count bounded
    # by the LADDER — no exact-length "prefill_full" program ever built.
    c = dict(eng.counters)
    assert c["bucket_prefills"] == c["full_prefills"] == len(reqs), c
    assert 1 <= c["bucket_groups"] < c["bucket_prefills"], c
    assert c["pad_tokens"] > 0, c
    bucket_compiles = [k for k in eng.telemetry.compiles
                       if k[1] == "prefill_bucket"]
    assert 0 < len(bucket_compiles) <= len(eng._buckets), bucket_compiles
    assert not any(k[1] == "prefill_full" for k in eng.telemetry.compiles)

    # (x) the SAME staggered arrivals through the exact-length reference
    # engine (prefill_buckets=()): bit-identical greedy streams, identical
    # page accounting (padding never allocates a page), while the exact
    # engine pays one prefill program per DISTINCT prompt length.
    psv_exact = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                                 n_pages=N_PAGES, max_len=MAX_LEN,
                                 cache_dtype=jnp.float32, prefill_buckets=())
    eng_exact = PagedEngine(params, ms, psv_exact)
    _drive(eng_exact, reqs)
    assert eng_exact.counters["bucket_prefills"] == 0
    exact_compiles = [k for k in eng_exact.telemetry.compiles
                      if k[1] == "prefill_full"]
    assert len(exact_compiles) == len({len(p) for _, p, _ in reqs})
    assert eng_exact.step_count == eng.step_count
    assert sorted(eng_exact.results) == sorted(eng.results)
    for rid in eng.results:
        assert (eng_exact.results[rid] == eng.results[rid]).all(), rid
    assert eng_exact.pool.allocated_total == eng.pool.allocated_total
    assert eng_exact.pool.freed_total == eng.pool.freed_total

    # (y) varied-length arrivals (10 distinct lengths vs the 4-rung auto
    # ladder): still bit-identical, compile counts cross over, and the
    # TTFT comparison lands in BENCH_serve.json ("prefill_batch").
    vreqs = _varied_workload(cfg, 10, rate=2.0)
    eng_b = PagedEngine(params, ms, psv)
    mb = _drive(eng_b, vreqs)
    eng_e = PagedEngine(params, ms, psv_exact)
    me = _drive(eng_e, vreqs)
    assert sorted(eng_e.results) == sorted(eng_b.results)
    for rid in eng_b.results:
        assert (eng_e.results[rid] == eng_b.results[rid]).all(), rid
    n_bucket = sum(1 for k in eng_b.telemetry.compiles
                   if k[1] == "prefill_bucket")
    n_exact = sum(1 for k in eng_e.telemetry.compiles
                  if k[1] == "prefill_full")
    assert n_bucket <= len(eng_b._buckets) < n_exact, (n_bucket, n_exact)
    pb = {
        "ttft_p50_ms": mb["ttft_p50_ms"], "ttft_p99_ms": mb["ttft_p99_ms"],
        "exact_ttft_p50_ms": me["ttft_p50_ms"],
        "exact_ttft_p99_ms": me["ttft_p99_ms"],
        "bucket_groups": int(eng_b.counters["bucket_groups"]),
        "bucket_prefills": int(eng_b.counters["bucket_prefills"]),
        "pad_tokens": int(eng_b.counters["pad_tokens"]),
        "compiles_prefill": n_bucket,
        "exact_compiles_prefill": n_exact,
        "n_buckets": len(eng_b._buckets),
    }
    _bench_summary("prefill_batch", pb)

    # (r) valid Chrome trace + metrics snapshot as CI artifacts.
    trace_path = _dump_run_artifacts(eng, "structural")
    snap = eng.metrics_snapshot()
    print("structural OK:", rows,
          f"| {len(reqs)} staggered requests bit-identical "
          f"(telemetry on == off, bucketed == exact-length), "
          f"bucket groups={c['bucket_groups']} "
          f"prefill compiles {len(bucket_compiles)} (ladder "
          f"{len(eng._buckets)}) vs {len(exact_compiles)} exact | "
          f"pages alloc={eng.pool.allocated_total} "
          f"freed={eng.pool.freed_total} | trace -> {trace_path}")
    _bench_summary("tp1", _drive_summary(
        m, telemetry=_snapshot_summary(snap)))
    return {"rows": rows, "drive": m, "prefill_batch": pb,
            "telemetry": _snapshot_summary(snap)}


# ---------------------------------------------------------------------------
# Sharded structural gate (tp > 1 paged engine)
# ---------------------------------------------------------------------------

def _sharded_launch_and_write_counts(ms, mesh, n_slots: int):
    """(pallas launches, cache-tensor scatters) in ONE traced SHARD_MAP'd
    paged decode step — the per-rank counts of the tp>1 program (the
    counter recurses into the shard_map jaxpr, scans weighted by trip
    count)."""
    psv = PagedServeConfig(n_slots=n_slots, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, max_len=MAX_LEN,
                           cache_dtype=jnp.float32)
    prev = A.get_decode_impl()
    A.set_decode_impl("pallas")
    try:
        fn, c_abs, _, _ = make_sharded_serve_step(ms, mesh, None,
                                                  batch=n_slots, paged=psv)
        p_abs = jax.eval_shape(lambda: T.init_params(ms, jax.random.PRNGKey(0)))
        i32 = jnp.int32
        jaxpr = jax.make_jaxpr(fn)(
            p_abs, c_abs, jax.ShapeDtypeStruct((n_slots,), i32),
            jax.ShapeDtypeStruct((n_slots,), i32),
            jax.ShapeDtypeStruct((n_slots, MAX_LEN // PAGE_SIZE), i32),
            jax.ShapeDtypeStruct((n_slots,), jnp.bool_),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
    finally:
        A.set_decode_impl(prev)
    return (jaxpr_primitive_count(jaxpr, "pallas_call"),
            jaxpr_primitive_count(jaxpr, "scatter"))


def structural_sharded(mesh_spec: str = "1x2", seed: int = 17) -> dict:
    """The sharded-structural CI gate — see the module docstring, items
    (g)-(j): per-rank launch counts, tp-invariant page accounting, tp>1
    vs tp=1 vs one-shot sharded bit-identity, prefix auto-disable."""
    mesh, m = make_serving_mesh(mesh_spec)
    assert m > 1, f"--structural --mesh needs a model axis > 1, got {mesh_spec}"

    # (g) one fused attention launch + 2 scatters per paired phase PER RANK.
    rows = []
    for n_pairs in (0, 3):
        _, ms = _structure(n_pairs, tp=m)
        launches, writes = _sharded_launch_and_write_counts(ms, mesh, N_SLOTS)
        groups = N_LAYERS - n_pairs
        assert launches == groups, (n_pairs, launches, groups)
        assert writes == 2 * groups, (n_pairs, writes, groups)
        rows.append({"pairs": n_pairs, "launches": launches,
                     "cache_writes": writes})

    # (h)+(i): identical staggered workload through the tp=1 and tp=m
    # engines; every request's greedy stream must agree BITWISE, and both
    # pools must drain with balanced accounting (checked every step).
    cfg, ms1, params = _build(3, tp=1)
    _, ms_tp = _structure(3, tp=m)
    psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, max_len=MAX_LEN,
                           cache_dtype=jnp.float32)
    reqs = _workload(cfg, 12, rate=4.0, seed=seed)
    eng1 = PagedEngine(params, ms1, psv)
    m1 = _drive(eng1, reqs)
    eng2 = PagedEngine(params, ms_tp, psv, mesh=mesh)
    m2 = _drive(eng2, reqs)
    for rid in sorted(eng1.results):
        assert (eng1.results[rid] == eng2.results[rid]).all(), rid
    assert eng2.pool.live == 0
    assert eng2.pool.allocated_total == eng2.pool.freed_total > 0
    assert eng2.pool.allocated_total == eng1.pool.allocated_total

    # (i) cross-check a subset against one-shot sharded generate() (the
    # ring-cache reference under the same mesh).
    sv = ServeConfig(max_len=MAX_LEN, temperature=0.0,
                     cache_dtype=jnp.float32)
    for rid, (_, prompt, max_new) in list(zip(sorted(eng2.results), reqs))[:4]:
        ref = sharded_generate(params, prompt[None], max_new, ms=ms_tp,
                               mesh=mesh, sv=sv)[0]
        assert (eng2.results[rid] == ref).all(), rid

    # (j) prefix sharing stays ON under tp>1 — the radix tree builds under
    # the mesh engine exactly as at tp=1 (the full sharded-prefix workload
    # gate is structural_sharded_prefix; this is the cheap config check).
    psv_px = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                              n_pages=N_PAGES, max_len=MAX_LEN,
                              cache_dtype=jnp.float32, prefix_cache=True)
    assert PagedEngine(params, ms_tp, psv_px, mesh=mesh).prefix is not None
    assert PagedEngine(params, ms1, psv_px).prefix is not None

    out = {"mesh": mesh_spec, "rows": rows, "tp1": m1, f"tp{m}": m2}
    print(f"sharded-structural OK (mesh {mesh_spec}): launches==groups "
          f"{rows} | {len(reqs)} staggered requests bit-identical at "
          f"tp={m} vs tp=1 vs sharded one-shot | prefix cache live "
          f"under the mesh")
    _bench_summary(f"tp{m}", _drive_summary(m2))
    C.save_result("serve_throughput_sharded", {"structural": out})
    return out


def structural_sharded_prefix(mesh_spec: str = "1x2",
                              seed: int = 17) -> dict:
    """The sharded-prefix CI gate — module docstring items (z)-(z4): the
    family workload through the SHARDED engine with the radix cache ON.
    Radix-hit members prefill only their suffix via the per-row ctx-page
    gather on the bucket path; everything must stay bit-identical to the
    tp=1 prefix-on engine."""
    mesh, m = make_serving_mesh(mesh_spec)
    assert m > 1, (
        f"--shared-prefix --mesh needs a model axis > 1, got {mesh_spec}")

    cfg, ms1, params = _build(3, tp=1)
    _, ms_tp = _structure(3, tp=m)
    psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, max_len=MAX_LEN,
                           cache_dtype=jnp.float32, prefix_cache=True)
    reqs = _shared_prefix_workload(cfg, rate=1.0, seed=seed)

    # tp=1 prefix-ON reference (its own bit-identity to one-shot generate
    # is gated by structural_shared_prefix; here it anchors the tp sweep).
    eng1 = PagedEngine(params, ms1, psv)
    m1 = _drive(eng1, reqs)
    s1 = _prefix_stats(eng1)

    eng2 = PagedEngine(params, ms_tp, psv, mesh=mesh)
    m2 = _drive(eng2, reqs)
    s2 = _prefix_stats(eng2)

    # (z) the sharded radix tree actually hit, and hit members prefilled
    # only their suffix — sharing works under the mesh, not merely "on".
    assert eng2.prefix is not None
    assert s2["prefix_hits"] > 0, s2
    assert s2["hit_rate"] > 0, s2
    assert eng2.counters["suffix_prefills"] > 0, dict(eng2.counters)
    # Sharing decisions are host-side and tp-invariant: identical stats.
    assert s2 == s1, (s2, s1)

    # (z2) per-request greedy streams bit-identical to the tp=1 engine.
    assert sorted(eng2.results) == sorted(eng1.results)
    for rid in sorted(eng1.results):
        assert (eng2.results[rid] == eng1.results[rid]).all(), rid
    assert eng2.step_count == eng1.step_count

    # (z3) page accounting balances and drains to the tree's residents,
    # tp-invariantly.
    assert eng2.pool.live == eng2.prefix.resident_pages
    eng2.pool.check_balance()
    assert eng2.pool.allocated_total == eng1.pool.allocated_total
    assert eng2.pool.freed_total == eng1.pool.freed_total

    # (z4) heterogeneous (ctx_pages, suffix_len) rows shared launches: the
    # prefill compile count stays bounded by the LADDER — no exact-length
    # suffix program, no exact-length full program, on either engine.
    for eng in (eng1, eng2):
        bucket_compiles = [k for k in eng.telemetry.compiles
                           if k[1] == "prefill_bucket"]
        assert 0 < len(bucket_compiles) <= len(eng._buckets), bucket_compiles
        assert not any(k[1] in ("prefill_full", "prefill_suffix")
                       for k in eng.telemetry.compiles), (
            dict(eng.telemetry.compiles))

    out = {"mesh": mesh_spec, "tp1": dict(m1, **s1), f"tp{m}": dict(m2, **s2)}
    print(f"sharded-prefix OK (mesh {mesh_spec}): hit_rate={s2['hit_rate']} "
          f"hits={s2['prefix_hits']} suffix_prefills="
          f"{eng2.counters['suffix_prefills']} | {len(reqs)} family "
          f"requests bit-identical at tp={m} vs tp=1 (prefix ON both) | "
          f"prefill compiles <= ladder on both engines")
    _bench_summary("sharded_prefix", _drive_summary(
        m2, hit_rate=s2["hit_rate"], prefix_hits=s2["prefix_hits"],
        prefill_tokens=s2["prefill_tokens"], hit_tokens=s2["hit_tokens"],
        suffix_prefills=int(eng2.counters["suffix_prefills"]),
        compiles_prefill=sum(1 for k in eng2.telemetry.compiles
                             if k[1] == "prefill_bucket"),
        n_buckets=len(eng2._buckets), tp=m))
    C.save_result("serve_throughput_sharded_prefix", {"structural": out})
    return out


def structural_shared_prefix(seed: int = 17) -> dict:
    """Prefix-structural gate: hit rate, prefill-token reduction, zero
    extra launches, refcount balance, and bit-identity of prefix-hit and
    preempted-then-resumed requests."""
    cfg, ms, params = _build(3)
    sv = ServeConfig(max_len=MAX_LEN, temperature=0.0,
                     cache_dtype=jnp.float32)

    def one_shot(prompt, n_new):
        return np.asarray(generate(params, jnp.asarray(prompt)[None], n_new,
                                   ms=ms, pc=PC, sv=sv)[0])

    # (d) launches: prefix sharing changes ONLY admission — the decode
    # program is byte-for-byte the PR 2 program, so sharing may not add a
    # single kernel launch or cache write.
    launches, writes = _launch_and_write_counts(ms, N_SLOTS)
    groups = N_LAYERS - 3
    assert launches == groups and writes == 2 * groups, (launches, writes)

    psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, max_len=MAX_LEN,
                           cache_dtype=jnp.float32, prefix_cache=True,
                           preempt_after=4)
    eng = PagedEngine(params, ms, psv)
    reqs = _shared_prefix_workload(cfg, rate=1.0, seed=seed)
    m = _drive(eng, reqs)
    stats = _prefix_stats(eng)
    # (d) hit rate / prefill-token reduction: >= 30% of prompt tokens must
    # come from the radix cache instead of the prefill forward.
    assert stats["prefix_hits"] > 0, stats
    assert stats["hit_rate"] >= 0.30, stats
    # (b) refcount balance held at every step (engine.step); at drain the
    # only live pages are the tree's residents.
    assert eng.pool.live == eng.prefix.resident_pages
    eng.pool.check_balance()
    # (e) every request (hit or cold) bit-identical to one-shot.
    for rid, (_, prompt, max_new) in zip(sorted(eng.results), reqs):
        assert (eng.results[rid] == one_shot(prompt, max_new)).all(), rid

    # (f) preemption: a pool sized for two page-hungry requests forces the
    # third to preempt the youngest; resumed output must be bit-identical
    # (the engine also asserts each replayed token internally).
    psv_p = PagedServeConfig(n_slots=4, page_size=PAGE_SIZE, n_pages=9,
                             max_len=32, cache_dtype=jnp.float32,
                             prefix_cache=True, preempt_after=2)
    eng_p = PagedEngine(params, ms, psv_p)
    key = jax.random.PRNGKey(seed)
    pr = [np.asarray(jax.random.randint(jax.random.fold_in(key, 70 + i),
                                        (8,), 0, cfg.vocab_size))
          for i in range(3)]
    rids = [eng_p.add_request(pr[0], 20), eng_p.add_request(pr[1], 20)]
    for _ in range(4):
        eng_p.step()
    rids.append(eng_p.add_request(pr[2], 4))
    eng_p.drain()
    assert eng_p.sched.preemptions_total >= 1
    assert eng_p.counters["replay_tokens"] > 0
    for rid, (p, n) in zip(rids, [(pr[0], 20), (pr[1], 20), (pr[2], 4)]):
        assert (eng_p.results[rid] == one_shot(p, n)).all(), rid
    out = {"drive": m, "prefix": stats,
           "preemptions": eng_p.sched.preemptions_total,
           "replay_tokens": eng_p.counters["replay_tokens"]}
    _dump_run_artifacts(eng, "prefix")
    _bench_summary("shared_prefix",
                   _drive_summary(m, hit_rate=stats["hit_rate"],
                                  telemetry=_snapshot_summary(
                                      eng.metrics_snapshot())))
    print(f"prefix-structural OK: hit_rate={stats['hit_rate']} "
          f"hits={stats['prefix_hits']} "
          f"prefill={stats['prefill_tokens']} saved={stats['hit_tokens']} | "
          f"preemptions={out['preemptions']} "
          f"replay={out['replay_tokens']} — all bit-identical")
    return out


# ---------------------------------------------------------------------------
# Chaos + degradation gate (deterministic fault injection soak)
# ---------------------------------------------------------------------------

CHAOS_STEPS = 200          # fault-injection horizon (soak runs past it)
CHAOS_REQUESTS = 100       # enough arrivals to keep slots busy all horizon
CHAOS_RATE = 0.5           # requests per engine step
CHAOS_CANCEL_STEP = 60     # exercise cancel() mid-soak, deterministically
DEG_EFF_DEPTH = 3          # aggressive-Δ cohort depth (base soaks at 5)


def _chaos_drive(eng: PagedEngine, reqs, *, cancel_step: int = -1,
                 queue_cap: int = 0, max_steps: int = 3000):
    """Submit on the arrival schedule and step to drain, tolerating
    faults. Returns (rids aligned with ``reqs`` — a shed submission gets
    rid -1 — , rids cancelled by the driver). Deterministic: the only
    inputs are the engine (with its seeded FaultPlan) and the schedule."""
    rids, cancelled = [], []
    nxt = 0
    while nxt < len(reqs) or eng.sched.n_queued or eng.sched.n_running:
        while nxt < len(reqs) and reqs[nxt][0] <= eng.step_count:
            _, prompt, max_new, deadline = reqs[nxt]
            try:
                rids.append(eng.add_request(prompt, max_new,
                                            deadline=deadline))
            except QueueFullError:
                rids.append(-1)
            nxt += 1
        if eng.step_count == cancel_step and eng.sched.running:
            victim = max(r.rid for r in eng.sched.running.values())
            eng.cancel(victim)
            cancelled.append(victim)
        eng.step()
        if queue_cap:
            # The bounded queue may NEVER exceed its cap, at any step.
            assert eng.sched.n_queued <= queue_cap, (
                eng.step_count, eng.sched.n_queued, queue_cap)
        assert eng.step_count <= max_steps, "chaos drive failed to drain"
    return rids, cancelled


def _chaos_workload(cfg, n: int, rate: float, seed: int):
    """Like _workload but with an explicit no-deadline column (the storm
    fault is what sets deadlines in the soak)."""
    return [(a, p, m, None) for a, p, m in _workload(cfg, n, rate, seed)]


def structural_chaos(seed: int = 0) -> dict:
    """The chaos-structural CI gate: a >= CHAOS_STEPS-step soak with all
    five deterministic fault kinds live, then a sustained-overload run with
    the bounded queue and the aggressive-Δ degraded cohort. Gates:

      (k) the engine never crashes across the soak; page accounting
          balances at EVERY step (engine.step self-checks) and at drain;
      (l) every one of the five fault kinds actually fired, and every
          faulted request landed in a TYPED terminal state carrying a
          ServeError — faults never leak as bare asserts;
      (m) SURVIVORS are bit-identical to the same workload on a
          faults-disabled engine (fault isolation: a poisoned slot never
          perturbs a healthy one);
      (n) the whole soak is reproducible from (seed): a second engine with
          a fresh FaultPlan(seed) produces the identical fault log,
          terminal states, and token streams;
      (o) under sustained overload the bounded submit queue NEVER exceeds
          max_queue (shedding is deadline-aware and typed), and every
          FINISHED degraded-cohort request is bit-identical to a
          fixed-aggressive-Δ engine built from the same weights by
          LP.replan — degradation trades depth for capacity, never
          correctness.
    """
    cfg, ms, params = _build(1)           # eff depth 5: room to degrade
    psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, max_len=MAX_LEN,
                           cache_dtype=jnp.float32, prefix_cache=True)
    reqs = _chaos_workload(cfg, CHAOS_REQUESTS, CHAOS_RATE, seed)

    # Clean reference first (same workload, no FaultPlan).
    eng0 = PagedEngine(params, ms, psv)
    rids0, _ = _chaos_drive(eng0, reqs)
    assert all(eng0.request(r).state == FINISHED for r in rids0)

    # (k)+(l): the seeded soak. FaultPlan draws every event up front.
    plan = FaultPlan(seed, n_steps=CHAOS_STEPS)
    assert plan.events == FaultPlan(seed, n_steps=CHAOS_STEPS).events
    eng1 = PagedEngine(params, ms, psv, fault_plan=plan)
    rids1, cancelled = _chaos_drive(eng1, reqs,
                                    cancel_step=CHAOS_CANCEL_STEP)
    assert eng1.step_count >= CHAOS_STEPS, eng1.step_count
    assert eng1.pool.allocated_total - eng1.pool.freed_total == \
        eng1.pool.live                      # balanced at drain too
    applied = {k: eng1.fault_counts[k] for k in ALL_FAULT_KINDS}
    assert all(v > 0 for v in applied.values()), applied
    assert eng1.pool.alloc_faults > 0       # refusals actually served
    for rid in rids1:
        r = eng1.request(rid)
        assert r.state in TERMINAL_STATES, (rid, r.state)
        if r.state in (FAILED, EXPIRED):
            assert r.error is not None, rid
        if r.state == EXPIRED:              # within one step of deadline
            assert r.finished_step <= r.deadline + 1, (rid, r.finished_step)
    assert all(eng1.request(r).state == CANCELLED for r in cancelled)

    # (m) survivors bit-identical to the fault-free run.
    survivors = [r for r in rids1 if eng1.request(r).state == FINISHED]
    victims = [r for r in rids1 if eng1.request(r).state != FINISHED]
    assert victims, "soak injected faults but no request was hit"
    assert len(survivors) >= len(rids1) // 2, (len(survivors), len(rids1))
    for rid in survivors:
        assert (eng1.results[rid] == eng0.results[rid]).all(), rid

    # (n) determinism: fresh plan, fresh engine, identical everything —
    # including telemetry: the wall-stripped Chrome traces (every span,
    # gauge sample, fault instant, step-stamped) must be BYTE-identical,
    # and the soak's trace/metrics land in results/ as CI artifacts.
    eng2 = PagedEngine(params, ms, psv, fault_plan=FaultPlan(
        seed, n_steps=CHAOS_STEPS))
    rids2, _ = _chaos_drive(eng2, reqs, cancel_step=CHAOS_CANCEL_STEP)
    assert rids2 == rids1
    assert eng2.fault_log == eng1.fault_log
    for rid in rids1:
        assert eng2.request(rid).state == eng1.request(rid).state, rid
        assert (eng2.results[rid] == eng1.results[rid]).all(), rid
    t1 = dumps_trace(eng1.telemetry, n_slots=N_SLOTS, wall=False)
    assert t1 == dumps_trace(eng2.telemetry, n_slots=N_SLOTS, wall=False), \
        "same-seed chaos runs produced different wall-stripped traces"
    trace_path = _dump_run_artifacts(eng1, "chaos")

    # (o) sustained overload: bounded queue + degraded cohort.
    cap = 4
    psv_deg = PagedServeConfig(
        n_slots=N_SLOTS, page_size=PAGE_SIZE, n_pages=N_PAGES,
        max_len=MAX_LEN, cache_dtype=jnp.float32, max_queue=cap,
        degrade_delta=True, degrade_slots=N_SLOTS // 2,
        degrade_queue_depth=1, degrade_eff_depth=DEG_EFF_DEPTH)
    eng_d = PagedEngine(params, ms, psv_deg)
    burst = _chaos_workload(cfg, 32, rate=4.0, seed=seed + 1)
    # Deadline mix: mostly patient, every 5th urgent — urgent newcomers
    # shed the most-patient queued victim; the rest ride out the queue.
    burst = [(a, p, m, (a + 10 if i % 5 == 4 else a + 400))
             for i, (a, p, m, _) in enumerate(burst)]
    rids_d, _ = _chaos_drive(eng_d, burst, queue_cap=cap)
    shed = eng_d.counters["shed"] + sum(1 for r in rids_d if r == -1)
    assert shed > 0, "overload burst never exercised the shed policy"
    assert eng_d.counters["degraded_admissions"] > 0
    deg_done = [(i, r) for i, r in enumerate(rids_d) if r >= 0
                and eng_d.request(r).cohort == COHORT_DEGRADED
                and eng_d.request(r).state == FINISHED]
    assert deg_done, "no degraded request ran to completion"

    # Fixed-aggressive-Δ reference engine: SAME weights, re-paired by
    # LP.replan to the degraded plan — the cohort must match it bitwise.
    deg_plan = plan_for_depth(cfg, DEG_EFF_DEPTH, end=N_LAYERS)
    _, seg_params = replan(cfg, params["segments"], ms.segments, deg_plan)
    ms_ref = T.build_structure(cfg, plan=deg_plan, tp=1)
    eng_ref = PagedEngine(dict(params, segments=seg_params), ms_ref,
                          PagedServeConfig(n_slots=N_SLOTS,
                                           page_size=PAGE_SIZE,
                                           n_pages=N_PAGES, max_len=MAX_LEN,
                                           cache_dtype=jnp.float32))
    ref_rids = [eng_ref.add_request(burst[i][1], burst[i][2])
                for i, _ in deg_done]
    eng_ref.drain()
    for (_, rid), ref_rid in zip(deg_done, ref_rids):
        assert (eng_d.results[rid] == eng_ref.results[ref_rid]).all(), rid

    out = {
        "soak_steps": eng1.step_count,
        "faults_applied": applied,
        "alloc_faults": eng1.pool.alloc_faults,
        "survivors": len(survivors),
        "victims": {s: sum(1 for r in rids1
                           if eng1.request(r).state == s)
                    for s in (FAILED, EXPIRED, CANCELLED)},
        "overload": {
            "queue_cap": cap, "shed": shed,
            "degraded_admissions": eng_d.counters["degraded_admissions"],
            "degraded_finished": len(deg_done),
            "deg_eff_depth": DEG_EFF_DEPTH,
            "base_eff_depth": ms.effective_depth,
        },
    }
    _bench_summary("chaos", out)
    C.save_result("serve_throughput_chaos", {"structural": out})
    print(f"chaos-structural OK: {eng1.step_count}-step soak, faults "
          f"{applied} (+{eng1.pool.alloc_faults} alloc refusals) | "
          f"{len(survivors)} survivors bit-identical, victims "
          f"{out['victims']} | deterministic replay exact "
          f"(wall-stripped traces byte-identical -> {trace_path}) | "
          f"overload: queue<= {cap} held, shed={shed}, "
          f"{len(deg_done)} degraded requests bit-identical to the "
          f"fixed-Δ reference (depth {ms.effective_depth}->"
          f"{DEG_EFF_DEPTH})")
    return out


# ---------------------------------------------------------------------------
# Speculative structural gate (self-speculative decoding)
# ---------------------------------------------------------------------------

SPEC_K = 3           # draft tokens per verify in the spec-structural gate
SPEC_HOT_SCALE = 0.1  # segment scale emulating trained-model agreement


def _scaled_params(params, scale: float):
    """Shrink every segment weight by ``scale``: the shallow re-paired
    draft and the full-depth verify then agree greedily almost everywhere
    — the trained-model regime the acceptance gate needs, without real
    weights (the paper's premise is that TRAINED deep halves barely move
    the residual stream; raw PRNG weights agree only at chance level, so
    they exercise the rejection/rewind path instead)."""
    return dict(params, segments=jax.tree.map(lambda x: x * scale,
                                              params["segments"]))


def structural_spec(spec_k: int = SPEC_K, seed: int = 17) -> dict:
    """The spec-structural CI gate — module docstring items (s)-(v)."""
    assert spec_k >= 1, spec_k

    # (s) program shapes. The verifier IS the regular paged decode program
    # at batch n_slots*(k+1): widening the batch may not add a single
    # launch (one fused attention launch + 2 cache writes per paired
    # phase). The drafter is the same program over the re-paired shallow
    # structure at the main batch, keeping the per-pair savings of (a).
    _, ms_base = _structure(0)            # base engine: vanilla full depth
    launches, writes = _launch_and_write_counts(ms_base,
                                               N_SLOTS * (spec_k + 1))
    assert launches == N_LAYERS, (launches, N_LAYERS)
    assert writes == 2 * N_LAYERS, (writes, N_LAYERS)
    _, ms_draft = _structure(N_LAYERS // 2)   # == draft_plan_for(Δ=0)
    d_groups = N_LAYERS - N_LAYERS // 2
    d_launches, d_writes = _launch_and_write_counts(ms_draft, N_SLOTS)
    assert d_launches == d_groups and d_writes == 2 * d_groups, (
        d_launches, d_writes, d_groups)

    cfg, ms, params = _build(0)
    psv_plain = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                                 n_pages=N_PAGES, max_len=MAX_LEN,
                                 cache_dtype=jnp.float32)
    psv_spec = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                                n_pages=N_PAGES, max_len=MAX_LEN,
                                cache_dtype=jnp.float32, spec_k=spec_k)
    reqs = _workload(cfg, 12, rate=4.0, seed=seed)

    # (t) RAW random weights: chance-level draft agreement, so this half
    # hammers rejection + rewind — and the streams must STILL be
    # bit-identical to the plain engine (speculation is a schedule change,
    # never a model change).
    eng_p = PagedEngine(params, ms, psv_plain)
    _drive(eng_p, reqs)
    eng_s = PagedEngine(params, ms, psv_spec)
    _drive(eng_s, reqs)
    for rid in sorted(eng_p.results):
        assert (eng_s.results[rid] == eng_p.results[rid]).all(), rid
    c = eng_s.counters
    assert c["verify_steps"] > 0, dict(c)
    assert c["draft_steps"] == spec_k * c["verify_steps"], dict(c)
    assert c["spec_rejected"] > 0, dict(c)    # raw weights DO reject...
    assert c["spec_rewound"] > 0, dict(c)     # ...and rejections rewind
    # launches-per-verify == 1: exactly one verify program exists,
    # compiled once at the wide batch (and one shallow draft program).
    comp = eng_s.telemetry.compiles
    assert comp[(COHORT_SPEC_VERIFY, "decode",
                 N_SLOTS * (spec_k + 1))] == 1, comp
    assert comp[(COHORT_SPEC_DRAFT, "decode", N_SLOTS)] == 1, comp
    # Rewind page accounting: both trees drained, pool balanced (also
    # self-checked inside every engine.step).
    assert eng_s.pool.live == 0 and eng_p.pool.live == 0
    assert eng_s.pool.allocated_total == eng_s.pool.freed_total > 0
    eng_s.pool.check_balance()
    raw = {"counters": {k: c[k] for k in ("draft_steps", "verify_steps",
                                          "spec_accepted", "spec_rejected",
                                          "spec_rewound", "decoded")},
           "accept_per_verify":
               eng_s.metrics_snapshot()["spec"]["accept_per_verify"]}

    # (u) trained-model agreement regime: scaled segments make the draft
    # agree with full depth, so acceptance must actually PAY — accepted
    # tokens per verify > 1 and net tok/s at or above the non-speculative
    # engine on the same workload (both engines warmed first so XLA
    # compile time stays out of the clock).
    params_hot = _scaled_params(params, SPEC_HOT_SCALE)
    # Decode-heavy variant of the workload (each request decodes to its
    # slot horizon): speculation pays a one-off draft prefill per
    # admission, so the win lives in the decode phase — the 16-token
    # smoke requests above never amortize it on this host-dispatch-bound
    # smoke model. Prefill bucketing is OFF on both engines: the wall
    # comparison isolates the SPECULATION subsystem (bucketed prefill's
    # wall behavior is gated in the serve-structural (w)/(x)/(y) items,
    # and the spec x bucket interaction is bit-gated in (t) above);
    # fixed-row bucket launches would bill padded-row compute — free on
    # an accelerator, real on this serial-CPU host — twice to the spec
    # engine (draft mirror + main), drowning the margin in smoke noise.
    reqs_long = [(a, p, MAX_LEN - len(p)) for a, p, _ in reqs]
    psv_plain_x = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                                   n_pages=N_PAGES, max_len=MAX_LEN,
                                   cache_dtype=jnp.float32,
                                   prefill_buckets=())
    psv_spec_x = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                                  n_pages=N_PAGES, max_len=MAX_LEN,
                                  cache_dtype=jnp.float32, spec_k=spec_k,
                                  prefill_buckets=())
    eng_hp = PagedEngine(params_hot, ms, psv_plain_x)
    _warm(eng_hp, PROMPT_LENS)
    m_base = _drive(eng_hp, reqs_long)
    eng_hs = PagedEngine(params_hot, ms, psv_spec_x)
    _warm(eng_hs, PROMPT_LENS)
    m_spec = _drive(eng_hs, reqs_long)
    for rid in sorted(eng_hp.results):
        assert (eng_hs.results[rid] == eng_hp.results[rid]).all(), rid
    snap = eng_hs.metrics_snapshot()
    spec = snap["spec"]
    assert spec["accept_per_verify"] > 1.0, spec
    assert eng_hs.counters["spec_accepted"] > 0
    # Fewer engine steps is the deterministic form of the win (and the
    # strict gate); wall tok/s is the deployment-facing form
    # BENCH_serve.json tracks, but on this host-dispatch-bound smoke
    # model its run-to-run jitter exceeds the spec margin, so it only
    # gates against a gross regression.
    assert eng_hs.step_count < eng_hp.step_count, (
        eng_hs.step_count, eng_hp.step_count)
    assert m_spec["tok_per_s"] >= 0.85 * m_base["tok_per_s"], (
        m_spec, m_base)

    # (v) artifacts + the BENCH_serve.json "spec" section.
    trace_path = _dump_run_artifacts(eng_hs, "spec")
    _bench_summary("spec", _drive_summary(
        m_spec, spec_k=spec_k, draft_eff_depth=spec["draft_eff_depth"],
        accept_per_verify=spec["accept_per_verify"],
        accept_rate=spec["accept_rate"],
        spec_tok_per_s=m_spec["tok_per_s"],
        base_tok_per_s=m_base["tok_per_s"],
        telemetry=_snapshot_summary(snap)))
    out = {"spec_k": spec_k, "raw": raw,
           "hot": {"spec": spec, "drive": m_spec, "base_drive": m_base,
                   "speedup": round(m_spec["tok_per_s"]
                                    / m_base["tok_per_s"], 3)}}
    C.save_result("serve_throughput_spec", {"structural": out})
    print(f"spec-structural OK (k={spec_k}): verify launches==groups at "
          f"batch {N_SLOTS * (spec_k + 1)} | raw weights: "
          f"{raw['counters']['spec_rejected']} rejected / "
          f"{raw['counters']['spec_rewound']} rewound, bit-identical | "
          f"scaled weights: accept/verify="
          f"{spec['accept_per_verify']} accept_rate={spec['accept_rate']} "
          f"tok/s {m_spec['tok_per_s']} vs base {m_base['tok_per_s']} "
          f"({out['hot']['speedup']}x), bit-identical | "
          f"trace -> {trace_path}")
    return out


# ---------------------------------------------------------------------------
# Wall-clock serving runs
# ---------------------------------------------------------------------------

def _reset_after_warm(eng: PagedEngine):
    """Zero everything the measured run reports (results, clock, every
    telemetry channel, preemption count) so warmup activity never leaks
    into it. ``telemetry.reset()`` replaces the per-dict zeroing the
    pre-telemetry benchmark did — counters, spans, gauges, histograms,
    step wall marks all drop through the one registry."""
    eng.results.clear()
    eng.step_count = 0
    eng.sched.preemptions_total = 0
    eng.telemetry.reset()


def _warm(eng: PagedEngine, lens):
    """Warm THIS engine's compiled programs (jit caches are per engine) so
    wall time measures serving, not XLA; then reset the clock/results."""
    for L in lens:
        eng.add_request(np.zeros(L, np.int32), 2)
    eng.drain()
    _reset_after_warm(eng)


def _warm_shared(eng: PagedEngine, cfg, seed: int):
    """Family-shaped warmup with THROWAWAY tokens: compiles the full-prompt
    program AND the suffix-prefill program shape the real families will use
    (donor first, then a member that radix-hits), without touching the real
    families' tree entries."""
    key = jax.random.PRNGKey(seed + 999)
    shared = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 0), (SHARED_LEN,), 0, cfg.vocab_size))
    for i in (1, 2):
        tail = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (TAIL_LEN,), 0, cfg.vocab_size))
        eng.add_request(np.concatenate([shared, tail]), 2)
        eng.drain()
    if eng.prefix is not None:
        # Drop the throwaway donations: leaving them resident would start
        # the measured run short of allocatable pages — a handicap the
        # cache-off engine does not pay.
        eng.prefix.evict(eng.prefix.resident_pages, eng.pool)
        eng.pool.check_balance()
    _reset_after_warm(eng)


def run(structural_only: bool = False, *, n_requests: int = 32,
        rate: float = 2.0, shared_prefix: bool = False, seed: int = 17,
        preempt_after: int = 0, pages: int = 0, mesh: str = "",
        chaos: bool = False, spec_k: int = 0):
    n_pages = pages if pages > 0 else N_PAGES
    if chaos:
        # --chaos is its own CI step (chaos-structural): the soak + overload
        # gate is deterministic in --seed, so it always runs structural.
        return structural_chaos(seed)
    if spec_k and not structural_only:
        raise SystemExit("--spec-k is a structural gate; add --structural")
    if structural_only:
        # --structural, --structural --shared-prefix, --structural
        # --mesh AxB (plus --shared-prefix for the sharded-prefix gate)
        # and --structural --spec-k K are SEPARATE CI steps; each gates
        # only its own half so no job pays another's assertions twice.
        if mesh and shared_prefix:
            return structural_sharded_prefix(mesh, seed)
        if mesh:
            return structural_sharded(mesh, seed)
        if spec_k:
            return structural_spec(spec_k, seed)
        res = (structural_shared_prefix(seed) if shared_prefix
               else structural())
        C.save_result("serve_throughput", {"structural": res})
        return res
    if shared_prefix:
        out = {}
        cfg, ms, params = _build(3)
        for label, on in (("cache_off", False), ("cache_on", True)):
            psv = PagedServeConfig(
                n_slots=N_SLOTS, page_size=PAGE_SIZE, n_pages=n_pages,
                max_len=MAX_LEN, cache_dtype=jnp.float32, prefix_cache=on,
                preempt_after=preempt_after)
            eng = PagedEngine(params, ms, psv)
            _warm_shared(eng, cfg, seed)
            m = _drive(eng, _shared_prefix_workload(cfg, rate, seed))
            m.update(_prefix_stats(eng))
            out[label] = m
            print(f"{label:10s} tok/s={m['tok_per_s']:8.1f} "
                  f"ttft_p50={m['ttft_p50_ms']:6.1f}ms "
                  f"ttft_p99={m['ttft_p99_ms']:7.1f}ms "
                  f"hit_rate={m['hit_rate']:.2f} "
                  f"prefill={m['prefill_tokens']} saved={m['hit_tokens']}")
        out["prefix_speedup"] = round(out["cache_on"]["tok_per_s"]
                                      / out["cache_off"]["tok_per_s"], 3)
        print(f"prefix-cache serving speedup: {out['prefix_speedup']}x")
        C.save_result("serve_throughput", {"shared_prefix": out})
        return out
    # Wall-clock serving (optionally sharded: --mesh DxM runs the engine
    # under shard_map with tp = M; "1x1" keeps the plain tp=1 engine — the
    # knob the EXPERIMENTS.md tp sweep drives).
    tp = 1
    mesh_dev = None
    if mesh:
        mesh_dev, tp = make_serving_mesh(mesh)
    out = {}
    for label, n_pairs in (("vanilla", 0), ("lp", 3)):
        cfg, ms, params = _build(n_pairs, tp=tp)
        psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                               n_pages=n_pages, max_len=MAX_LEN,
                               cache_dtype=jnp.float32,
                               preempt_after=preempt_after)
        eng = PagedEngine(params, ms, psv, mesh=mesh_dev)
        reqs = _workload(cfg, n_requests, rate, seed)
        _warm(eng, PROMPT_LENS)
        m = _drive(eng, reqs)
        m["eff_depth"] = ms.effective_depth
        m["tp"] = tp
        m["preemptions"] = eng.sched.preemptions_total
        m["replay_tokens"] = eng.counters["replay_tokens"]
        out[label] = m
        print(f"{label:8s} depth={m['eff_depth']:2d} tp={tp} "
              f"tok/s={m['tok_per_s']:8.1f} p50={m['lat_p50_ms']:7.1f}ms "
              f"p99={m['lat_p99_ms']:7.1f}ms ttft50={m['ttft_p50_ms']:6.1f}ms "
              f"occ={m['occ_mean']:.2f}/{m['occ_max']:.2f} steps={m['steps']} "
              f"preempt={m['preemptions']}")
    out["lp_speedup"] = round(out["lp"]["tok_per_s"]
                              / out["vanilla"]["tok_per_s"], 3)
    print(f"LP-on vs LP-off serving throughput (tp={tp}): "
          f"{out['lp_speedup']}x")
    C.save_result("serve_throughput" + (f"_tp{tp}" if tp > 1 else ""), out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description="continuous-batching benchmark")
    ap.add_argument("--structural", action="store_true",
                    help="skip wall-clock; assert launch/write counts, page "
                         "accounting balance, and one-shot bit-identity "
                         "(CI gate)")
    ap.add_argument("--chaos", action="store_true",
                    help="chaos-structural gate: >=200-step deterministic "
                         "fault-injection soak (all five kinds) + bounded-"
                         "queue overload with the aggressive-Δ degraded "
                         "cohort; reproducible from --seed")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="family traffic with shared system prompts; with "
                         "--structural also gates hit-rate, prefill-token "
                         "reduction, and preempt-resume bit-identity; "
                         "combined with --mesh 1xM it is the sharded-"
                         "prefix gate (radix cache ON under tp=M)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate, requests per engine step")
    ap.add_argument("--seed", type=int, default=17,
                    help="seed for the Poisson arrivals and prompt tokens")
    ap.add_argument("--preempt-after", type=int, default=0,
                    help="blocked-head steps before preemption (0 = off)")
    ap.add_argument("--pages", type=int, default=0,
                    help="pool size incl. garbage page (0 = full occupancy "
                         f"default {N_PAGES}); small pools force queueing "
                         "and, with --preempt-after, preemption")
    ap.add_argument("--mesh", default="",
                    help="1xM device mesh (e.g. 1x2): run the engine under "
                         "shard_map with tp=M (needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8); with "
                         "--structural this is the sharded-structural gate")
    ap.add_argument("--spec-k", type=int, default=0, dest="spec_k",
                    help="with --structural: the spec-structural gate — "
                         "self-speculative engine drafting K tokens per "
                         "full-depth verify; gates bit-identity vs the "
                         "plain engine in both agreement regimes, "
                         "acceptance/rewind accounting, and net tok/s")
    args = ap.parse_args()
    run(structural_only=args.structural, n_requests=args.requests,
        rate=args.rate, shared_prefix=args.shared_prefix, seed=args.seed,
        preempt_after=args.preempt_after, pages=args.pages, mesh=args.mesh,
        chaos=args.chaos, spec_k=args.spec_k)
