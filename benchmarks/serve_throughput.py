"""Continuous-batching serving benchmark over the paged pair-KV cache.

Synthetic Poisson arrivals drive ``repro.serve.PagedEngine``: requests with
mixed prompt lengths arrive at exponential inter-arrival times, share the
page pool, and finish independently. Reported per run:

  tokens/s            — generated tokens over wall-clock drain time
  latency p50 / p99   — per-request submit -> finish wall time
  occupancy mean/max  — live pages / allocatable pages per engine step
  LP speedup          — tokens/s of the LP-paired model over vanilla (the
                        paper's decode win, now measured under serving load)

``--structural`` (the serve-structural CI gate) skips the wall clock and
asserts the subsystem's invariants instead:
  (a) the paged pair decode still does ONE attention kernel launch and one
      scatter per cache tensor per paired phase — each LP pair removes 1
      launch and 2 cache writes per decode step, exactly like the ring
      fast path lp_speed gates on;
  (b) page accounting balances at every step (allocated - freed == live,
      checked inside engine.step) and drains to zero;
  (c) >= 8 concurrent, staggered requests come out bit-identical to
      one-shot generate().
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.analysis.roofline import jaxpr_primitive_count
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import attention as A
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import PagedEngine, PagedServeConfig, ServeConfig, generate
from repro.serve import paged_cache as PG

PC = ParallelContext()

N_LAYERS = 6
MAX_LEN = 64
PAGE_SIZE = 8
N_SLOTS = 8
N_PAGES = 1 + N_SLOTS * (MAX_LEN // PAGE_SIZE)   # full occupancy + garbage
PROMPT_LENS = (8, 16, 24)
MAX_NEW = 16


def _structure(n_pairs: int):
    cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=N_LAYERS)
    plan = LPPlan(plan_range(cfg, 0, N_LAYERS).pairs[:n_pairs])
    return cfg, T.build_structure(cfg, plan=plan, tp=1)


def _build(n_pairs: int):
    cfg, ms = _structure(n_pairs)
    return cfg, ms, T.init_params(ms, jax.random.PRNGKey(0))


def _workload(cfg, n_requests: int, rate: float, seed: int = 17):
    """(arrival_step, prompt, max_new) triples: Poisson arrivals (rate
    requests per engine step), prompt lengths cycled over PROMPT_LENS."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n_requests):
        L = PROMPT_LENS[i % len(PROMPT_LENS)]
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (L,), 0, cfg.vocab_size))
        reqs.append((int(arrivals[i]), prompt, MAX_NEW))
    return reqs


def _drive(eng: PagedEngine, reqs):
    """Run the arrival schedule to drain; returns per-request metrics."""
    submit_t, finish_t, rids = {}, {}, []
    occupancy = []
    nxt = 0
    t0 = time.perf_counter()
    while nxt < len(reqs) or eng.sched.n_queued or eng.sched.n_running:
        while nxt < len(reqs) and reqs[nxt][0] <= eng.step_count:
            _, prompt, max_new = reqs[nxt]
            rid = eng.add_request(prompt, max_new)
            submit_t[rid] = time.perf_counter()
            rids.append(rid)
            nxt += 1
        done_before = set(eng.results)
        eng.step()
        occupancy.append(eng.occupancy)
        now = time.perf_counter()
        for rid in set(eng.results) - done_before:
            finish_t[rid] = now
    wall = time.perf_counter() - t0
    tokens = sum(len(eng.results[r]) for r in rids)
    lat = np.array([finish_t[r] - submit_t[r] for r in rids])
    return {
        "wall_s": round(wall, 3),
        "tokens": int(tokens),
        "tok_per_s": round(tokens / wall, 1),
        "lat_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 1),
        "lat_p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 1),
        "occ_mean": round(float(np.mean(occupancy)), 3),
        "occ_max": round(float(np.max(occupancy)), 3),
        "steps": eng.step_count,
    }


# ---------------------------------------------------------------------------
# Structural assertions (CI gate)
# ---------------------------------------------------------------------------

def _launch_and_write_counts(ms, n_slots: int):
    """(pallas launches, cache-tensor scatters) in ONE traced paged decode
    step, scan bodies weighted by trip count."""
    params = jax.eval_shape(lambda: T.init_params(ms, jax.random.PRNGKey(0)))
    c_abs, _ = PG.paged_cache_meta(ms, n_slots=n_slots,
                                   n_pages=N_PAGES, page_size=PAGE_SIZE,
                                   dtype=jnp.float32)
    bt = jnp.zeros((n_slots, MAX_LEN // PAGE_SIZE), jnp.int32)
    tv = jnp.zeros((n_slots,), jnp.int32)
    prev = A.get_decode_impl()
    A.set_decode_impl("pallas")
    try:
        jaxpr = jax.make_jaxpr(
            lambda p, c: T.decode_step(
                p, jnp.zeros((n_slots,), jnp.int32), c, tv, ms=ms, pc=PC,
                cache_layout="paged", block_tables=bt))(params, c_abs)
    finally:
        A.set_decode_impl(prev)
    return (jaxpr_primitive_count(jaxpr, "pallas_call"),
            jaxpr_primitive_count(jaxpr, "scatter"))


def structural() -> dict:
    rows = []
    for n_pairs in (0, 1, 3):
        _, ms = _structure(n_pairs)   # launch counting needs shapes only
        launches, writes = _launch_and_write_counts(ms, N_SLOTS)
        groups = N_LAYERS - n_pairs
        # One attention launch + one scatter per cache tensor (k and v)
        # per phase; a fused pair IS one phase for two layers.
        assert launches == groups, (n_pairs, launches, groups)
        assert writes == 2 * groups, (n_pairs, writes, groups)
        rows.append({"pairs": n_pairs, "launches": launches,
                     "cache_writes": writes})
    base = rows[0]
    for row in rows[1:]:
        assert base["launches"] - row["launches"] == row["pairs"], (base, row)
        assert base["cache_writes"] - row["cache_writes"] == 2 * row["pairs"]

    # Accounting balance + bit-identity under staggered continuous batching.
    # (engine.step checks allocated - freed == live at EVERY step.)
    cfg, ms, params = _build(3)
    psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                           n_pages=N_PAGES, max_len=MAX_LEN,
                           cache_dtype=jnp.float32)
    eng = PagedEngine(params, ms, psv)
    reqs = _workload(cfg, 12, rate=4.0)
    m = _drive(eng, reqs)
    assert eng.pool.live == 0
    assert eng.pool.allocated_total == eng.pool.freed_total > 0
    sv = ServeConfig(max_len=MAX_LEN, temperature=0.0,
                     cache_dtype=jnp.float32)
    for rid, (_, prompt, max_new) in zip(sorted(eng.results), reqs):
        ref = np.asarray(generate(params, jnp.asarray(prompt)[None],
                                  max_new, ms=ms, pc=PC, sv=sv)[0])
        assert (eng.results[rid] == ref).all(), rid
    print("structural OK:", rows,
          f"| {len(reqs)} staggered requests bit-identical, "
          f"pages alloc={eng.pool.allocated_total} freed={eng.pool.freed_total}")
    return {"rows": rows, "drive": m}


# ---------------------------------------------------------------------------
# Wall-clock serving run
# ---------------------------------------------------------------------------

def run(structural_only: bool = False, *, n_requests: int = 32,
        rate: float = 2.0):
    if structural_only:
        res = structural()
        C.save_result("serve_throughput", {"structural": res})
        return res
    out = {}
    for label, n_pairs in (("vanilla", 0), ("lp", 3)):
        cfg, ms, params = _build(n_pairs)
        psv = PagedServeConfig(n_slots=N_SLOTS, page_size=PAGE_SIZE,
                               n_pages=N_PAGES, max_len=MAX_LEN,
                               cache_dtype=jnp.float32)
        eng = PagedEngine(params, ms, psv)
        reqs = _workload(cfg, n_requests, rate)
        # Warm THIS engine's compiled programs (jit caches are per engine)
        # so wall time measures serving, not XLA; then reset the clock.
        for L in PROMPT_LENS:
            eng.add_request(np.zeros(L, np.int32), 2)
        eng.drain()
        eng.results.clear()
        eng.step_count = 0
        m = _drive(eng, reqs)
        m["eff_depth"] = ms.effective_depth
        out[label] = m
        print(f"{label:8s} depth={m['eff_depth']:2d} "
              f"tok/s={m['tok_per_s']:8.1f} p50={m['lat_p50_ms']:7.1f}ms "
              f"p99={m['lat_p99_ms']:7.1f}ms occ={m['occ_mean']:.2f}"
              f"/{m['occ_max']:.2f} steps={m['steps']}")
    out["lp_speedup"] = round(out["lp"]["tok_per_s"]
                              / out["vanilla"]["tok_per_s"], 3)
    print(f"LP-on vs LP-off serving throughput: {out['lp_speedup']}x")
    C.save_result("serve_throughput", out)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description="continuous-batching benchmark")
    ap.add_argument("--structural", action="store_true",
                    help="skip wall-clock; assert launch/write counts, page "
                         "accounting balance, and one-shot bit-identity "
                         "(CI gate)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="Poisson arrival rate, requests per engine step")
    args = ap.parse_args()
    run(structural_only=args.structural, n_requests=args.requests,
        rate=args.rate)
