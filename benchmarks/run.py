"""Benchmark aggregator — one experiment per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Order: the shared bench model trains once (cached), then each experiment
reads it. Emits a CSV summary line per experiment plus JSON artifacts under
benchmarks/results/.
"""
from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer training / denser grids (hours on 1 CPU)")
    ap.add_argument("--fast", action="store_true")  # alias of the default
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    train_steps = 1200 if args.full else 150
    ft_steps = 300 if args.full else 40

    from benchmarks import (effective_depth, finetune_recovery, icl_depth,
                            lp_ppl_sweep, lp_speed)
    experiments = {
        # paper Fig. 3/4
        "effective_depth": lambda: effective_depth.run(
            stride=2 if args.full else 8, train_steps=train_steps),
        # paper Fig. 6
        "lp_ppl_sweep": lambda: lp_ppl_sweep.run(train_steps=train_steps),
        # paper Table 1
        "icl_depth": lambda: icl_depth.run(train_steps=train_steps),
        # paper Table 2
        "finetune_recovery": lambda: finetune_recovery.run(
            train_steps=train_steps, ft_steps=ft_steps),
        # paper Fig. 7/8 + Table 3 / Appendix C
        "lp_speed": lambda: lp_speed.run(),
    }
    print("name,seconds,status")
    rows = []
    for name, fn in experiments.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        try:
            fn()
            status = "ok"
        except Exception:
            traceback.print_exc()
            status = "FAILED"
        dt = time.time() - t0
        rows.append((name, dt, status))
        print(f"{name},{dt:.1f},{status}", flush=True)
    print("\nSUMMARY")
    for name, dt, status in rows:
        print(f"  {name:24s} {dt:8.1f}s  {status}")
    if any(s == "FAILED" for _, _, s in rows):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
