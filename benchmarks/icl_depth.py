"""Paper Table 1 — in-context-learning accuracy vs effective depth.

The ICL proxy: per-sequence random feature->class maps demonstrated
in-context (repro.data.synthetic); accuracy on the late answer slots is the
analogue of the 5-shot benchmark average. Reproduces the qualitative claim:
accuracy declines gradually with LP, then drops sharply past a threshold.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core.lp import plan_for_depth
from repro.model import transformer as T


def run(*, train_steps: int = 1200):
    params = C.train_bench_model(train_steps)
    n = C.BENCH_CFG.n_layers
    ms0 = T.build_structure(C.BENCH_CFG, tp=1)
    rows = [{"eff_depth": n, "kind": "base",
             "icl_acc": round(C.eval_icl(params, ms0), 4),
             "ppl": round(C.eval_ppl(params, ms0), 3)}]
    print(f"base     depth={n:2d} icl={rows[0]['icl_acc']:.4f} "
          f"ppl={rows[0]['ppl']:.3f}")
    for depth in range(n - 1, n - 6, -1):
        plan = plan_for_depth(C.BENCH_CFG, depth, end=n - 1)
        ms, p = C.params_with_plan(params, plan)
        acc = C.eval_icl(p, ms)
        ppl = C.eval_ppl(p, ms)
        rows.append({"eff_depth": depth, "kind": "lp",
                     "icl_acc": round(acc, 4), "ppl": round(ppl, 3)})
        print(f"LP       depth={depth:2d} icl={acc:.4f} ppl={ppl:.3f}")
    out = {"rows": rows}
    C.save_result("icl_depth", out)
    return out


if __name__ == "__main__":
    run()
