"""Shared benchmark substrate: a small LM trained in-container on the
structured synthetic corpus (the stand-in for pretrained Llama/Qwen — no
external weights exist offline; DESIGN.md §Hardware-adaptation).

The trained model is cached under benchmarks/results/bench_model so the
whole suite trains it exactly once.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, LayerSpec
from repro.core.lp import EMPTY_PLAN, LPPlan
from repro.data import SynthConfig, eval_ppl_batch, icl_eval_batch, lm_batch
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.train import OptConfig, TrainConfig, checkpoint as CK
from repro.train.trainer import init_state, make_train_step

PC = ParallelContext()
RESULTS = os.path.join(os.path.dirname(__file__), "results")
CACHE = os.path.join(RESULTS, "bench_model")

#: The benchmark model: llama-family, deep enough for meaningful LP sweeps.
BENCH_CFG = ArchConfig(
    name="bench-12l",
    family="dense",
    n_layers=12,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=1024,
    vocab_size=512,
    rope_theta=10_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    max_position=512,
)
SC = SynthConfig(vocab_size=BENCH_CFG.vocab_size)
SEQ = 128


def train_bench_model(steps: int = 1200, *, force: bool = False):
    """Train (or load) the shared benchmark model. Returns fp32 params."""
    ms = T.build_structure(BENCH_CFG, tp=1)
    os.makedirs(RESULTS, exist_ok=True)
    marker = os.path.join(CACHE, "DONE")
    if os.path.exists(marker) and not force:
        with open(marker) as f:
            meta = json.load(f)
        if meta.get("steps") == steps:
            logical_like = {"params": jax.tree.map(
                lambda x: np.zeros(x.shape, np.float32),
                T.init_params(ms, jax.random.PRNGKey(0)))}
            logical = CK.restore(CACHE, logical_like)
            return logical["params"]
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=50,
                                   total_steps=steps, schedule="wsd"))
    state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
    step_fn = jax.jit(make_train_step(ms, PC, tc), donate_argnums=(0,))
    key = jax.random.PRNGKey(123)
    for s in range(steps):
        batch = lm_batch(jax.random.fold_in(key, s), SC, SEQ, 16)
        state, m = step_fn(state, batch)
        if s % 50 == 0 or s == steps - 1:
            print(f"  [bench-train {s:4d}] loss={float(m['loss']):.4f}",
                  flush=True)
    from repro.train.trainer import from_flat_global, _leaf_meta
    tmpl, treedef, infos = _leaf_meta(ms)
    flats = treedef.flatten_up_to(state["master"])
    params = treedef.unflatten([
        from_flat_global(f, li.pd.shape, li.pspec, PC)
        for f, li in zip(flats, infos)])
    CK.save(CACHE, {"params": params}, steps)
    # CK.save names dirs step_<n>; relocate via manifest-less reload contract:
    import shutil
    src = os.path.join(CACHE, f"step_{steps:08d}")
    for fn in os.listdir(src):
        shutil.copy(os.path.join(src, fn), os.path.join(CACHE, fn))
    with open(os.path.join(CACHE, "DONE"), "w") as f:
        json.dump({"steps": steps}, f)
    return params


def layer_params_of(params) -> List:
    """Split the vanilla (no-LP) param tree into per-layer trees."""
    ms = T.build_structure(BENCH_CFG, tp=1)
    assert len(ms.segments) == 1 and ms.segments[0].count == BENCH_CFG.n_layers
    sp = params["segments"][0]
    return [jax.tree.map(lambda v: v[i], sp) for i in range(BENCH_CFG.n_layers)]


def params_with_plan(params, plan: LPPlan):
    """Re-pack the trained weights under an LP plan (retraining-free)."""
    from repro.core.lp import lp_convert
    layers = layer_params_of(params)
    segs, seg_params = lp_convert(BENCH_CFG, layers, plan)
    out = dict(params)
    out["segments"] = seg_params
    return T.build_structure(BENCH_CFG, plan=plan, tp=1), out


def eval_ppl(params, ms, *, n_batches: int = 2, batch: int = 8) -> float:
    """Perplexity on the held-out trigram language (the RedPajama analogue)."""
    tot, cnt = 0.0, 0
    for i in range(n_batches):
        b = eval_ppl_batch(jax.random.PRNGKey(10_000 + i), SC, SEQ, batch)
        loss, parts = T.loss_fn(params, b, ms=ms, pc=PC)
        tot += float(parts["xent"])
        cnt += 1
    return float(np.exp(tot / cnt))


def eval_icl(params, ms, *, n_batches: int = 2, batch: int = 8,
             last_k: int = 8) -> float:
    """ICL accuracy: fraction of correct answer tokens over the LAST k
    demonstrations (the model has seen enough shots by then)."""
    hits, tot = 0, 0
    for i in range(n_batches):
        b = icl_eval_batch(jax.random.PRNGKey(20_000 + i), SC, SEQ, batch)
        logits, _, _ = T.forward_full(params, b["tokens"], ms=ms, pc=PC)
        # predict the token AT ans_pos from position ans_pos-1
        pred = jnp.argmax(logits, -1)
        sel = jnp.take_along_axis(pred, b["ans_pos"] - 1, axis=1)
        ok = sel == b["ans_tok"]
        hits += int(ok[:, -last_k:].sum())
        tot += ok[:, -last_k:].size
    return hits / tot


def save_result(name: str, payload) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path
