"""Layer Parallelism (LP) — the paper's contribution.

LP rewrites the computational graph of a *pretrained* model so that pairs of
consecutive transformer layers execute as ONE wide layer under tensor
parallelism (paper Fig. 2b / Fig. 5):

    a = x + A_k(LN1_k x) + A_{k+1}(LN1_{k+1} x)     # ONE all-reduce
    y = a + F_k(LN2_k a) + F_{k+1}(LN2_{k+1} a)     # ONE all-reduce

halving the number of TP sync points over the paired range. The merge is
*retraining-free*: the pair's weights are the two layers' weights stacked on
a leading pair axis (the "stacked QKV projection" / "concatenated
up-projection" of the paper are exactly this stacking — see
repro.model.attention._proj_pair and repro.model.mlp.mlp_forward).

This module owns:
  * ``LPPlan`` — which layers pair (the paper's Δ / effective-depth knob),
  * plan constructors (contiguous range, target effective depth),
  * the retraining-free weight merge  per-layer params -> grouped/segmented
    params (and its inverse, for checkpoint interop),
  * the fine-tune mask for Table-2 style LP-only fine-tuning.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.model import blocks as B
from repro.model import stack as ST
from repro.model.params import stack_trees

PyTree = Any


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LPPlan:
    """An LP pairing plan: which consecutive layer pairs run in parallel."""

    pairs: Tuple[Tuple[int, int], ...] = ()

    @property
    def delta(self) -> int:
        """The paper's Δ — number of layers merged (2 per pair)."""
        return 2 * len(self.pairs)

    def effective_depth(self, n_layers: int) -> int:
        """Minimum sequential operations input->output (paper Table 1)."""
        return n_layers - len(self.pairs)

    def paired_layers(self) -> set:
        s = set()
        for i, j in self.pairs:
            s.update((i, j))
        return s

    def __post_init__(self):
        seen = set()
        for i, j in self.pairs:
            assert j == i + 1, f"LP pairs must be consecutive, got {(i, j)}"
            assert i not in seen and j not in seen, f"overlapping pair {(i, j)}"
            seen.update((i, j))


EMPTY_PLAN = LPPlan(())


def pairable(cfg: ArchConfig, i: int,
             specs: Optional[Sequence[LayerSpec]] = None) -> bool:
    """Can layers (i, i+1) LP-pair? Requires structurally equal templates
    (recurrentgemma's lone attention layer cannot pair with an RG-LRU layer;
    llama4's chunked/global attention CAN pair — heterogeneous attention
    kinds share a template)."""
    specs = list(specs if specs is not None else cfg.layer_specs())
    if i < 0 or i + 1 >= len(specs):
        return False
    return ST.template_compatible(cfg, specs[i], specs[i + 1])


def plan_range(cfg: ArchConfig, start: int, end: int) -> LPPlan:
    """Greedily pair consecutive compatible layers within [start, end).

    Layers whose successor is template-incompatible stay sequential and the
    scan resumes at the next index — e.g. recurrentgemma's (rec, rec, attn)
    period yields the (rec, rec) pair per period with the attention layer
    untouched.
    """
    specs = cfg.layer_specs()
    end = min(end, cfg.n_layers)
    pairs: List[Tuple[int, int]] = []
    i = max(start, 0)
    while i + 1 < end:
        if pairable(cfg, i, specs):
            pairs.append((i, i + 1))
            i += 2
        else:
            i += 1
    return LPPlan(tuple(pairs))


def plan_for_depth(cfg: ArchConfig, eff_depth: int, *,
                   end: Optional[int] = None) -> LPPlan:
    """Pick the pairing whose effective depth == ``eff_depth``, ending the
    paired range at ``end`` (paper protocol: the PPL-optimal end index, or
    the 4th-to-last layer Qwen-style by default) and growing it backwards.
    """
    n = cfg.n_layers
    want = n - eff_depth  # number of pairs
    if want <= 0:
        return EMPTY_PLAN
    if end is None:
        end = n - 4 if n >= 12 else n  # tiny (smoke) models: use the full stack
    end = min(end, n)
    # Grow the range backwards until it contains `want` pairs (compatibility
    # holes make the range longer than 2*want for hybrid archs).
    for start in range(end - 2 * want, -1, -1):
        plan = plan_range(cfg, start, end)
        if len(plan.pairs) >= want:
            return LPPlan(plan.pairs[-want:])
    plan = plan_range(cfg, 0, end)
    assert len(plan.pairs) >= want, (
        f"{cfg.name}: cannot reach effective depth {eff_depth} "
        f"(max pairs before layer {end} = {len(plan.pairs)})")
    return LPPlan(plan.pairs[-want:])


def default_plan(cfg: ArchConfig, lp_fraction: float = 0.5) -> LPPlan:
    """A sensible production default: pair the middle ``lp_fraction`` of the
    stack (the paper finds early layers and the last ~2-4 layers fragile —
    Fig. 3e / Fig. 6)."""
    n = cfg.n_layers
    span = int(n * lp_fraction)
    start = max(2, (n - span) // 2)
    end = min(n - 2, start + span)
    return plan_range(cfg, start, end)


# ---------------------------------------------------------------------------
# Retraining-free weight merge
# ---------------------------------------------------------------------------

def merge_groups(layer_params: Sequence[PyTree], groups: Sequence[B.Group]) -> List[PyTree]:
    """Per-layer trained params -> one tree per group.

    THE retraining-free merge: a pair's params are the two layers' params
    stacked on a new leading axis. Under the pair einsums this realises the
    paper's merged projections — QKV stacked along the head axis, FFN up
    projections concatenated along d_ff, per-path LayerNorms kept — without
    touching a single weight value.
    """
    out = []
    for g in groups:
        if g.pair:
            i, j = g.layer_ids
            out.append(stack_trees([layer_params[i], layer_params[j]]))
        else:
            out.append(layer_params[g.layer_ids[0]])
    return out


def segment_params(group_params: Sequence[PyTree],
                   segments: Sequence[ST.Segment]) -> List[PyTree]:
    """Group trees -> per-segment stacked trees (leading scan axis)."""
    out, k = [], 0
    for seg in segments:
        if seg.count == 1:
            out.append(group_params[k])
        else:
            out.append(stack_trees(list(group_params[k:k + seg.count])))
        k += seg.count
    return out


def lp_convert(cfg: ArchConfig, layer_params: Sequence[PyTree], plan: LPPlan
               ) -> Tuple[List[ST.Segment], List[PyTree]]:
    """End-to-end conversion of a trained layer stack to its LP form.

    Returns (segments, seg_params) ready for repro.model.stack application.
    ``plan.pairs == ()`` returns the vanilla sequential stack (bit-exact).
    """
    groups = ST.make_groups(cfg, plan.pairs)
    segments = ST.make_segments(groups)
    return segments, segment_params(merge_groups(layer_params, groups), segments)


def extract_layers(seg_params: Sequence[PyTree],
                   segments: Sequence[ST.Segment]) -> List[PyTree]:
    """Inverse of ``lp_convert``'s packing: per-layer param trees in original
    layer order (for checkpoint interop and plan changes between runs)."""
    layers: List[Tuple[int, PyTree]] = []
    for sp, seg in zip(seg_params, segments):
        for c in range(seg.count):
            gp = jax.tree.map(lambda v: v[c], sp) if seg.count > 1 else sp
            if seg.group.pair:
                base = seg.group.layer_ids[0] + 2 * c
                layers.append((base, jax.tree.map(lambda v: v[0], gp)))
                layers.append((base + 1, jax.tree.map(lambda v: v[1], gp)))
            else:
                base = seg.group.layer_ids[0] + c
                layers.append((base, gp))
    layers.sort(key=lambda t: t[0])
    assert [i for i, _ in layers] == list(range(len(layers)))
    return [p for _, p in layers]


def replan(cfg: ArchConfig, seg_params: Sequence[PyTree],
           segments: Sequence[ST.Segment], new_plan: LPPlan
           ) -> Tuple[List[ST.Segment], List[PyTree]]:
    """Re-pair an existing (possibly already LP'd) stack under a new plan —
    the elastic-depth path: serve with different Δ without reloading."""
    return lp_convert(cfg, extract_layers(seg_params, segments), new_plan)


# ---------------------------------------------------------------------------
# LP-only fine-tuning mask (paper Table 2)
# ---------------------------------------------------------------------------

def finetune_mask(seg_params: Sequence[PyTree],
                  segments: Sequence[ST.Segment]) -> List[PyTree]:
    """1.0 where a parameter belongs to an LP pair (trainable in the
    recovery fine-tune), 0.0 elsewhere. Same structure as seg_params."""
    out = []
    for sp, seg in zip(seg_params, segments):
        flag = 1.0 if seg.group.pair else 0.0
        out.append(jax.tree.map(lambda v: jnp.full((), flag, jnp.float32), sp))
    return out
