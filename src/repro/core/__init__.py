"""The paper's contribution: Layer Parallelism (retraining-free layer-pair
parallelization) + the effective-depth intervention toolkit."""
from repro.core.lp import (  # noqa: F401
    EMPTY_PLAN,
    LPPlan,
    default_plan,
    extract_layers,
    finetune_mask,
    lp_convert,
    merge_groups,
    pairable,
    plan_for_depth,
    plan_range,
    replan,
    segment_params,
)
from repro.core import interventions  # noqa: F401
