"""Effective-depth interventions (paper §3, Fig. 3/4).

Single-device reference transformations of a trained layer stack, used by
``benchmarks/effective_depth.py`` to reproduce the five heatmaps:

  (a) shuffle    — random permutation of layers [s, e]
  (b) prune      — drop layers [s, e]
  (c) merge      — average the weights of layers [s, e] into one layer
  (d) parallel   — run layers [s, e] as ONE k-way parallel group
  (e) parallel2  — run consecutive pairs inside [s, e] in parallel (LP)

Two functional forms of parallel groups are provided:
  * ``form="par"`` — the paper's eq. (PAR): each member's FFN sees only its
    OWN path's attention residual.
  * ``form="tp"``  — the implemented Fig. 2b graph: one merged residual per
    phase (what tensor parallelism actually executes; what repro.model.blocks
    runs for pairs). The k=2 "tp" form is bit-compatible with the production
    LP pair path — asserted by tests/test_lp_invariants.py.

All functions take/return a list of per-layer param trees plus an apply plan,
with no TP (pc=ParallelContext()) — interventions are an analysis tool, the
production path is repro.core.lp.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.model import attention as A
from repro.model import blocks as B
from repro.model.norms import apply_norm
from repro.parallel.context import ParallelContext

PyTree = Any


@dataclass(frozen=True)
class LayerGroup:
    """One unit of the intervened stack: ``members`` original-layer indices
    executed together. len==1 -> ordinary sequential layer."""

    members: Tuple[int, ...]
    form: str = "tp"  # tp | par (only meaningful for len(members) > 1)


def sequential_plan(n: int) -> List[LayerGroup]:
    return [LayerGroup((i,)) for i in range(n)]


def shuffle_plan(n: int, s: int, e: int, key) -> List[LayerGroup]:
    """Permute layers s..e (inclusive) uniformly at random."""
    perm = s + jax.random.permutation(key, e - s + 1)
    order = list(range(s)) + [int(p) for p in perm] + list(range(e + 1, n))
    return [LayerGroup((i,)) for i in order]


def prune_plan(n: int, s: int, e: int) -> List[LayerGroup]:
    return [LayerGroup((i,)) for i in range(n) if not s <= i <= e]


def parallel_plan(n: int, s: int, e: int, *, form="par") -> List[LayerGroup]:
    """One k-way parallel group for layers s..e (paper Fig. 3d)."""
    return ([LayerGroup((i,)) for i in range(s)]
            + [LayerGroup(tuple(range(s, e + 1)), form=form)]
            + [LayerGroup((i,)) for i in range(e + 1, n)])


def parallel2_plan(n: int, s: int, e: int, *, form="tp") -> List[LayerGroup]:
    """Consecutive pairs inside s..e (paper Fig. 3e — contiguous 2-parallel;
    a trailing unpaired layer stays sequential)."""
    groups: List[LayerGroup] = [LayerGroup((i,)) for i in range(s)]
    i = s
    while i + 1 <= e:
        groups.append(LayerGroup((i, i + 1), form=form))
        i += 2
    if i <= e:
        groups.append(LayerGroup((i,)))
    return groups + [LayerGroup((i,)) for i in range(e + 1, n)]


def merge_avg(layer_params: Sequence[PyTree], s: int, e: int
              ) -> Tuple[List[PyTree], List[LayerGroup]]:
    """Average layers s..e into ONE layer (paper Fig. 3c). Returns the new
    param list and its sequential plan."""
    merged = jax.tree.map(lambda *xs: sum(xs) / len(xs),
                          *[layer_params[i] for i in range(s, e + 1)])
    params = list(layer_params[:s]) + [merged] + list(layer_params[e + 1:])
    return params, sequential_plan(len(params))


def effective_depth_of(plan: Sequence[LayerGroup]) -> int:
    return len(plan)


# ---------------------------------------------------------------------------
# Reference evaluation
# ---------------------------------------------------------------------------

def _phase_attn(p, xn, cfg, dims, pc, *, kind, positions, prefix_len):
    """One layer's attention sub-block on a normalised input. Partial out."""
    q, k, v = A.project_qkv(p, xn, cfg, dims, pc, positions=positions,
                            kind=kind, pair=False)
    Hk, g = A.core_layout(dims)
    Bb, S = xn.shape[0], xn.shape[1]
    o = A.attention_core(q.reshape(Bb, S, Hk, g, dims.hd), k, v, kind=kind,
                         window=cfg.window, chunk=cfg.chunk,
                         prefix_len=prefix_len)
    return A.output_proj(p, o.reshape(Bb, S, dims.hq, dims.hd), dims, pair=False)


def _phase_ffn(p, xn, cfg, pc, spec):
    return B.ffn_phase(p, xn, cfg, pc,
                       group=B.Group(False, (spec,), (0,)))[0]


def apply_intervened(layer_params: Sequence[PyTree], plan: Sequence[LayerGroup],
                     x, *, cfg: ArchConfig, positions, prefix_len: int = 0,
                     pc: Optional[ParallelContext] = None):
    """Run an intervened stack (single device). x: [B,S,D] -> [B,S,D].

    Sequential groups use the production single-layer path
    (blocks.apply_group_full) so 'no intervention' is bit-exact with the
    normal model; parallel groups implement the k-way PAR / TP forms.
    """
    pc = pc or ParallelContext()
    dims = A.attn_dims(cfg, pc.tp_size)
    specs = cfg.layer_specs()
    for g in plan:
        if len(g.members) == 1:
            li = g.members[0]
            grp = B.Group(False, (specs[li],), (li,))
            x, _, _ = B.apply_group_full(
                layer_params[li], x, cfg=cfg, group=grp, dims=dims, pc=pc,
                positions=positions, prefix_len=prefix_len)
            continue

        members = list(g.members)
        kinds = [specs[li].mixer for li in members]
        if g.form == "tp":
            # Fig. 2b generalised: one merged residual per phase.
            out = 0.0
            for li, kind in zip(members, kinds):
                p = layer_params[li]
                xn = apply_norm(x, p["ln1"], cfg)
                out = out + _phase_attn(p["attn"], xn, cfg, dims, pc, kind=kind,
                                        positions=positions, prefix_len=prefix_len)
            a = x + pc.psum_tp(out).astype(x.dtype)
            out = 0.0
            for li in members:
                p = layer_params[li]
                xn2 = apply_norm(a, p["ln2"], cfg)
                out = out + _phase_ffn(p, xn2, cfg, pc, specs[li])
            x = a + pc.psum_tp(out).astype(x.dtype)
        else:
            # Paper eq. (PAR): each member applies its FULL layer to x;
            # contributions sum into the joint residual.
            out = 0.0
            for li, kind in zip(members, kinds):
                p = layer_params[li]
                xn = apply_norm(x, p["ln1"], cfg)
                att = pc.psum_tp(_phase_attn(p["attn"], xn, cfg, dims, pc,
                                             kind=kind, positions=positions,
                                             prefix_len=prefix_len))
                own = x + att.astype(x.dtype)
                xn2 = apply_norm(own, p["ln2"], cfg)
                ffn = pc.psum_tp(_phase_ffn(p, xn2, cfg, pc, specs[li]))
                out = out + att.astype(jnp.float32) + ffn.astype(jnp.float32)
            x = x + out.astype(x.dtype)
    return x
