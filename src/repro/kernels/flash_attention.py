"""Flash attention (online-softmax) Pallas kernel.

TPU adaptation of the paper's inference workloads: the prefill/train
attention is compute-bound on the MXU, so the kernel tiles (bq x bk) score
blocks through VMEM with fp32 running (m, l, acc) statistics in scratch —
HBM traffic is O(S*hd) instead of O(S^2).

Grid: (BH, nq, nk) with the kv index innermost; TPU grid iteration is
sequential over the last axis, so the scratch carry implements the online
softmax across kv tiles of one q tile. Supports causal / sliding-window /
chunked / prefix-LM masks via absolute-position arithmetic (the same
tile_mask semantics as the XLA path in repro.model.attention).

Mask kinds are compile-time constants; fully-masked tiles still run (a
future scalar-prefetch skip is noted in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import resolve_interpret, tpu_compiler_params

NEG_INF = -1e30


def _tile_mask(kind, qpos, kpos, *, window, chunk, prefix_len):
    q = qpos[:, None]
    k = kpos[None, :]
    if kind == "bidir":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = k <= q
    if kind == "causal":
        if prefix_len:
            m = m | (k < prefix_len)
        return m
    if kind == "window":
        return m & (q - k < window)
    if kind == "chunk":
        return m & (q // chunk == k // chunk)
    raise ValueError(kind)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            kind, window, chunk, prefix_len, q0, k0, bq, bk, nk, scale,
            q_group, k_limit):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                    # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                    # [bk, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # GQA folding: q rows are [position, group] interleaved (row = s*g + h),
    # so g query heads of one kv head share a kernel invocation and each
    # cache tile is read once for the whole group.
    qpos = q0 + (i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0)) // q_group
    kpos = k0 + j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    mask = _tile_mask(kind, qpos, kpos, window=window, chunk=chunk,
                      prefix_len=prefix_len)
    mask = mask & (kpos < k_limit)[None, :]  # kv padding columns
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _out():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, kind="causal", window=0, chunk=0,
                    prefix_len=0, q0=0, k0=0, q_group=1, block_q=128,
                    block_k=128, interpret=None):
    """q: [BH, S, hd]; k, v: [BH, T, hd] -> [BH, S, hd].

    ``q_group`` > 1 means q rows are GQA-folded (row = position*g + head);
    masks use position = row // g. S and T are padded to tile multiples;
    padded kv columns are masked via ``k_limit``.
    """
    BH, S, hd = q.shape
    T = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, T)
    pad_q = (-S) % bq
    pad_k = (-T) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    Sp, Tp = S + pad_q, T + pad_k
    nq, nk = Sp // bq, Tp // bk
    kern = functools.partial(
        _kernel, kind=kind, window=window, chunk=chunk, prefix_len=prefix_len,
        q0=q0, k0=k0, bq=bq, bk=bk, nk=nk, scale=hd ** -0.5,
        q_group=q_group, k_limit=k0 + T)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((BH, Sp, hd), q.dtype),
        grid=(BH, nq, nk),
        in_specs=[pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(qp, kp, vp)
    return out[:, :S] if pad_q else out
