"""Chunked selective-scan Pallas kernel (Mamba-1 / RG-LRU recurrence).

h_t = a_t * h_{t-1} + b_t, per (channel, state) element. The GPU Mamba
kernel uses warp-level shuffles; the TPU adaptation reorganises the same
work-efficient scan around VMEM tiles: within a (seq-chunk x channel-tile)
block the prefix is computed with an in-register associative scan (log-depth
on the VPU), and the carry h crosses seq chunks through VMEM scratch while
the grid walks the sequence axis sequentially.

Grid: (B, nC, nS) with S innermost ("arbitrary" = sequential), so the
scratch carry is live exactly for one (batch, channel-tile) stripe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import resolve_interpret, tpu_compiler_params


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def _kernel(a_ref, b_ref, h0_ref, y_ref, hT_ref, h_sc, *, ns):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_sc[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)                    # [bs, bc, N]
    b = b_ref[0].astype(jnp.float32)
    cum_a, cum_b = jax.lax.associative_scan(_combine, (a, b), axis=0)
    y = cum_b + cum_a * h_sc[...][None]
    y_ref[0] = y.astype(y_ref.dtype)
    h_sc[...] = y[-1]

    @pl.when(s == ns - 1)
    def _out():
        hT_ref[0] = h_sc[...].astype(hT_ref.dtype)


def ssm_scan(a, b, h0, *, block_s=256, block_c=128, interpret=None):
    """a, b: [B, S, C, N]; h0: [B, C, N] -> (y [B,S,C,N], hT [B,C,N]).

    S padded to a block multiple with identity elements (a=1, b=0) so the
    carry is unaffected; C padded with zeros.
    """
    B, S, C, N = a.shape
    bs = min(block_s, S)
    bc = min(block_c, C)
    pad_s = (-S) % bs
    pad_c = (-C) % bc
    if pad_s:
        a = jnp.concatenate(
            [a, jnp.ones((B, pad_s, C, N), a.dtype)], axis=1)
        b = jnp.concatenate(
            [b, jnp.zeros((B, pad_s, C, N), b.dtype)], axis=1)
    if pad_c:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_c), (0, 0)),
                    constant_values=1)
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_c), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_c), (0, 0)))
    Sp, Cp = S + pad_s, C + pad_c
    ns, nc = Sp // bs, Cp // bc

    kern = functools.partial(_kernel, ns=ns)
    y, hT = pl.pallas_call(
        kern,
        out_shape=(jax.ShapeDtypeStruct((B, Sp, Cp, N), a.dtype),
                   jax.ShapeDtypeStruct((B, Cp, N), jnp.float32)),
        grid=(B, nc, ns),
        in_specs=[pl.BlockSpec((1, bs, bc, N), lambda bt, c, s: (bt, s, c, 0)),
                  pl.BlockSpec((1, bs, bc, N), lambda bt, c, s: (bt, s, c, 0)),
                  pl.BlockSpec((1, bc, N), lambda bt, c, s: (bt, c, 0))],
        out_specs=(pl.BlockSpec((1, bs, bc, N), lambda bt, c, s: (bt, s, c, 0)),
                   pl.BlockSpec((1, bc, N), lambda bt, c, s: (bt, c, 0))),
        scratch_shapes=[pltpu.VMEM((bc, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(a, b, h0)
    y = y[:, :S, :C] if (pad_s or pad_c) else y
    hT = hT[:, :C] if pad_c else hT
    return y, hT
