"""Decode attention Pallas kernels — one query token against a long KV cache.

Decode (the paper's 1-token generation task) is HBM-bandwidth-bound: the
whole KV cache is read once per token while the MXU does O(L*hd) work. The
kernel streams kv tiles through VMEM with online-softmax statistics in
scratch, emitting the GQA group of q heads that share a kv head together
(one cache read serves g query heads — the GQA arithmetic-intensity win).

Two entry points share one kernel body:

  decode_attention       — single layer. Grid (B*Hkv, nL).
  decode_attention_pair  — an LP pair's two layers in ONE launch. The pair
                           caches are stacked contiguously ([2, B, L, Hkv,
                           hd], see repro.model.blocks.group_cache_meta) so
                           the kernel simply grids over (2*B*Hkv, nL): both
                           layers' caches stream through VMEM back-to-back
                           under the same online-softmax machinery, turning
                           the decode attention phase of two LP'd layers
                           into one kernel launch instead of two.

Grid: (rows, nL), L innermost/sequential. The valid horizon ``t`` is a
scalar-prefetch operand (SMEM) so cache positions beyond the current decode
step are masked without recompiling per step. ``interpret`` defaults to
auto-detection (compiled on TPU, interpreter elsewhere — repro.compat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import resolve_interpret, tpu_compiler_params

NEG_INF = -1e30


def _kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            bl, nl, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                 # [g, hd]
    k = k_ref[0].astype(jnp.float32)                 # [bl, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = j * bl + jax.lax.broadcasted_iota(jnp.int32, (bl,), 0)
    s = jnp.where((pos <= t_ref[0])[None, :], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(j == nl - 1)
    def _out():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def _launch(qr, kr, vr, t_valid, *, block_l, interpret):
    """One pallas_call over flattened rows: qr [R, g, hd]; kr, vr [R, L, hd]."""
    R, g, hd = qr.shape
    L = kr.shape[1]
    bl = min(block_l, L)
    pad = (-L) % bl
    if pad:  # padded rows have pos > t_valid -> masked
        kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pad), (0, 0)))
    nl = (L + pad) // bl
    t_arr = jnp.asarray(t_valid, jnp.int32).reshape(1)

    kern = functools.partial(_kernel, bl=bl, nl=nl, scale=hd ** -0.5)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((R, g, hd), qr.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(R, nl),
            in_specs=[pl.BlockSpec((1, g, hd), lambda b, j, t: (b, 0, 0)),
                      pl.BlockSpec((1, bl, hd), lambda b, j, t: (b, j, 0)),
                      pl.BlockSpec((1, bl, hd), lambda b, j, t: (b, j, 0))],
            out_specs=pl.BlockSpec((1, g, hd), lambda b, j, t: (b, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g, hd), jnp.float32)],
        ),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(t_arr, qr, kr, vr)


def decode_attention(q, k, v, t_valid, *, block_l=256, interpret=None):
    """q: [B, Hkv, g, hd]; k, v: [B, L, Hkv, hd]; t_valid: scalar int32.
    Returns [B, Hkv, g, hd]."""
    B, Hkv, g, hd = q.shape
    L = k.shape[1]
    qr = q.reshape(B * Hkv, g, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, L, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, L, hd)
    out = _launch(qr, kr, vr, t_valid, block_l=block_l, interpret=interpret)
    return out.reshape(B, Hkv, g, hd)


def decode_attention_pair(q, k, v, t_valid, *, block_l=256, interpret=None):
    """Fused LP-pair decode attention: ONE launch for both layers.

    q: [2, B, Hkv, g, hd]; k, v: [2, B, L, Hkv, hd] (the stacked pair
    cache); t_valid: scalar int32 shared by both halves (an LP pair is two
    layers at the SAME stream position, so their valid horizons coincide).
    Returns [2, B, Hkv, g, hd].
    """
    P2, B, Hkv, g, hd = q.shape
    assert P2 == 2 and k.shape[0] == 2, (q.shape, k.shape)
    L = k.shape[2]
    qr = q.reshape(2 * B * Hkv, g, hd)
    kr = jnp.moveaxis(k, 3, 2).reshape(2 * B * Hkv, L, hd)
    vr = jnp.moveaxis(v, 3, 2).reshape(2 * B * Hkv, L, hd)
    out = _launch(qr, kr, vr, t_valid, block_l=block_l, interpret=interpret)
    return out.reshape(2, B, Hkv, g, hd)
