"""Decode attention Pallas kernels — one query token against a long KV cache.

Decode (the paper's 1-token generation task) is HBM-bandwidth-bound: the
whole KV cache is read once per token while the MXU does O(L*hd) work. The
kernel streams kv tiles through VMEM with online-softmax statistics in
scratch, emitting the GQA group of q heads that share a kv head together
(one cache read serves g query heads — the GQA arithmetic-intensity win).

Four entry points share two kernel bodies:

  decode_attention            — single layer, contiguous ring cache.
                                Grid (B*Hkv, nL).
  decode_attention_pair       — an LP pair's two layers in ONE launch. The
                                pair caches are stacked contiguously
                                ([2, B, L, Hkv, hd], see
                                repro.model.blocks.group_cache_meta) so the
                                kernel simply grids over (2*B*Hkv, nL): both
                                layers' caches stream through VMEM
                                back-to-back under the same online-softmax
                                machinery, turning the decode attention
                                phase of two LP'd layers into one kernel
                                launch instead of two.
  decode_attention_paged      — single layer against a PAGED cache pool
                                ([n_pages, page_size, Hkv, hd]): instead of
                                a contiguous ring, each grid row streams the
                                pages its request owns, with the block
                                table as a scalar-prefetch operand feeding
                                the k/v BlockSpec index maps (the page id
                                IS the block index — no gather is ever
                                materialised).
  decode_attention_pair_paged — the paged LP pair: one launch for both
                                halves of a stacked pair pool
                                ([2, n_pages, page_size, Hkv, hd]); both
                                halves share ONE block table (an LP pair
                                sits at the same stream position) and the
                                leading pair axis folds into the page index
                                inside the index map.

Grid: (rows, nL|nPages), innermost sequential. The valid horizon ``t`` is a
scalar-prefetch operand (SMEM) so cache positions beyond the current decode
step are masked without recompiling per step; the paged kernels take a
PER-ROW horizon ``t[b]`` (continuous batching: every slot sits at its own
position) and an optional ``head_map`` (third scalar-prefetch operand)
mapping local kv heads to stored pool heads, which is how replicated-kv TP
ranks select their head in-kernel instead of deferring to the XLA gather
path. ``interpret`` defaults to auto-detection (compiled on TPU,
interpreter elsewhere — repro.compat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import resolve_interpret, tpu_compiler_params

NEG_INF = -1e30


def _kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            bl, nl, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                 # [g, hd]
    k = k_ref[0].astype(jnp.float32)                 # [bl, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = j * bl + jax.lax.broadcasted_iota(jnp.int32, (bl,), 0)
    s = jnp.where((pos <= t_ref[0])[None, :], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(j == nl - 1)
    def _out():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def _launch(qr, kr, vr, t_valid, *, block_l, interpret):
    """One pallas_call over flattened rows: qr [R, g, hd]; kr, vr [R, L, hd]."""
    R, g, hd = qr.shape
    L = kr.shape[1]
    bl = min(block_l, L)
    pad = (-L) % bl
    if pad:  # padded rows have pos > t_valid -> masked
        kr = jnp.pad(kr, ((0, 0), (0, pad), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pad), (0, 0)))
    nl = (L + pad) // bl
    t_arr = jnp.asarray(t_valid, jnp.int32).reshape(1)

    kern = functools.partial(_kernel, bl=bl, nl=nl, scale=hd ** -0.5)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((R, g, hd), qr.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(R, nl),
            in_specs=[pl.BlockSpec((1, g, hd), lambda b, j, t: (b, 0, 0)),
                      pl.BlockSpec((1, bl, hd), lambda b, j, t: (b, j, 0)),
                      pl.BlockSpec((1, bl, hd), lambda b, j, t: (b, j, 0))],
            out_specs=pl.BlockSpec((1, g, hd), lambda b, j, t: (b, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g, hd), jnp.float32)],
        ),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(t_arr, qr, kr, vr)


def decode_attention(q, k, v, t_valid, *, block_l=256, interpret=None):
    """q: [B, Hkv, g, hd]; k, v: [B, L, Hkv, hd]; t_valid: scalar int32.
    Returns [B, Hkv, g, hd]."""
    B, Hkv, g, hd = q.shape
    L = k.shape[1]
    qr = q.reshape(B * Hkv, g, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, L, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, L, hd)
    out = _launch(qr, kr, vr, t_valid, block_l=block_l, interpret=interpret)
    return out.reshape(B, Hkv, g, hd)


def decode_attention_pair(q, k, v, t_valid, *, block_l=256, interpret=None):
    """Fused LP-pair decode attention: ONE launch for both layers.

    q: [2, B, Hkv, g, hd]; k, v: [2, B, L, Hkv, hd] (the stacked pair
    cache); t_valid: scalar int32 shared by both halves (an LP pair is two
    layers at the SAME stream position, so their valid horizons coincide).
    Returns [2, B, Hkv, g, hd].
    """
    P2, B, Hkv, g, hd = q.shape
    assert P2 == 2 and k.shape[0] == 2, (q.shape, k.shape)
    L = k.shape[2]
    qr = q.reshape(2 * B * Hkv, g, hd)
    kr = jnp.moveaxis(k, 3, 2).reshape(2 * B * Hkv, L, hd)
    vr = jnp.moveaxis(v, 3, 2).reshape(2 * B * Hkv, L, hd)
    out = _launch(qr, kr, vr, t_valid, block_l=block_l, interpret=interpret)
    return out.reshape(2, B, Hkv, g, hd)


# ---------------------------------------------------------------------------
# Paged variant: grid over block tables instead of a contiguous ring
# ---------------------------------------------------------------------------

def _paged_kernel(bt_ref, t_ref, hm_ref, q_ref, k_ref, v_ref, o_ref, m_sc,
                  l_sc, acc_sc, *, ps, n_pg, B, hkv, scale):
    r = pl.program_id(0)
    j = pl.program_id(1)
    b = (r // hkv) % B  # which request's horizon gates this row

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                 # [g, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)           # [ps, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # Logical stream position of page j, slot i is j*ps + i; everything past
    # THIS ROW'S horizon (incl. the whole garbage page 0 reached through
    # unused block-table entries) masks out.
    pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (ps,), 0)
    s = jnp.where((pos <= t_ref[b])[None, :], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(j == n_pg - 1)
    def _out():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def _launch_paged(qr, k_pages, v_pages, block_tables, t_valid, *, n_half,
                  B, hkv, head_map=None, interpret):
    """qr: [R, g, hd] flattened rows (R = nP*B*hkv, pair-major); k/v_pages:
    [nP*n_half, ps, Hkv, hd] with the pair axis folded into the page axis;
    block_tables: [B, n_pg]; t_valid: [B]. The block table is a scalar-
    prefetch operand: the k/v index maps translate (row, page-step) ->
    physical page id, so each row streams exactly the pages its request
    owns — the paged analogue of the ring kernel's sequential L walk.

    ``head_map`` ([hkv] int32, default identity) maps a row's LOCAL kv-head
    index to the STORED head it streams — a third scalar-prefetch operand
    feeding the k/v index maps. This is how a TP rank with REPLICATED kv
    heads (n_kv < tp) selects its kv head(s) inside the kernel: the pool
    keeps all n_kv stored heads and each rank's rows pick theirs, so no
    per-rank kv gather is ever materialised (the selection the XLA path
    does with ``attention.select_local_kv``)."""
    R, g, hd = qr.shape
    ps = k_pages.shape[1]
    n_pg = block_tables.shape[1]
    bt = jnp.asarray(block_tables, jnp.int32)
    t_arr = jnp.asarray(t_valid, jnp.int32).reshape(B)
    if head_map is None:
        head_map = jnp.arange(hkv, dtype=jnp.int32)
    hm = jnp.asarray(head_map, jnp.int32).reshape(hkv)

    def kv_index(r, j, bt_ref, t_ref, hm_ref):
        half = r // (B * hkv)            # 0 (single / first layer) or 1
        b = (r // hkv) % B
        h = r % hkv
        return (half * n_half + bt_ref[b, j], 0, hm_ref[h], 0)

    kern = functools.partial(_paged_kernel, ps=ps, n_pg=n_pg, B=B, hkv=hkv,
                             scale=hd ** -0.5)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((R, g, hd), qr.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(R, n_pg),
            in_specs=[pl.BlockSpec((1, g, hd),
                                   lambda r, j, bt, t, hm: (r, 0, 0)),
                      pl.BlockSpec((1, ps, 1, hd), kv_index),
                      pl.BlockSpec((1, ps, 1, hd), kv_index)],
            out_specs=pl.BlockSpec((1, g, hd),
                                   lambda r, j, bt, t, hm: (r, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g, hd), jnp.float32)],
        ),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=resolve_interpret(interpret),
    )(bt, t_arr, hm, qr, k_pages, v_pages)


def decode_attention_paged(q, k_pages, v_pages, block_tables, t_valid, *,
                           head_map=None, interpret=None):
    """Paged decode attention, one layer. q: [B, Hkv, g, hd]; k_pages,
    v_pages: [n_pages, page_size, Hkv, hd]; block_tables: [B, n_pg] int32;
    t_valid: [B] int32 per-slot horizons; head_map: optional [Hkv] int32
    mapping q's local kv-head axis to stored pool heads (replicated-kv TP
    ranks — see _launch_paged). Returns [B, Hkv, g, hd]."""
    B, Hkv, g, hd = q.shape
    qr = q.reshape(B * Hkv, g, hd)
    out = _launch_paged(qr, k_pages, v_pages, block_tables, t_valid,
                        n_half=k_pages.shape[0], B=B, hkv=Hkv,
                        head_map=head_map, interpret=interpret)
    return out.reshape(B, Hkv, g, hd)


def decode_attention_pair_paged(q, k_pages, v_pages, block_tables, t_valid,
                                *, head_map=None, interpret=None):
    """Fused paged LP-pair decode: ONE launch for both halves.

    q: [2, B, Hkv, g, hd]; k_pages, v_pages: [2, n_pages, page_size, Hkv,
    hd] (the stacked pair pool); block_tables: [B, n_pg] SHARED by both
    halves (an LP pair sits at the same stream position, so its two layers
    occupy the same page indices of their own half); t_valid: [B] int32;
    head_map: optional [Hkv] int32 local-head -> stored-head selection,
    shared by both halves. Returns [2, B, Hkv, g, hd].
    """
    P2, B, Hkv, g, hd = q.shape
    assert P2 == 2 and k_pages.shape[0] == 2, (q.shape, k_pages.shape)
    n_half = k_pages.shape[1]
    qr = q.reshape(2 * B * Hkv, g, hd)
    kf = k_pages.reshape(2 * n_half, *k_pages.shape[2:])
    vf = v_pages.reshape(2 * n_half, *v_pages.shape[2:])
    out = _launch_paged(qr, kf, vf, block_tables, t_valid, n_half=n_half,
                        B=B, hkv=Hkv, head_map=head_map, interpret=interpret)
    return out.reshape(2, B, Hkv, g, hd)
