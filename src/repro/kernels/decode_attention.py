"""Decode attention Pallas kernel — one query token against a long KV cache.

Decode (the paper's 1-token generation task) is HBM-bandwidth-bound: the
whole KV cache is read once per token while the MXU does O(L*hd) work. The
kernel streams kv tiles through VMEM with online-softmax statistics in
scratch, emitting the GQA group of q heads that share a kv head together
(one cache read serves g query heads — the GQA arithmetic-intensity win).

Grid: (B * Hkv, nL), L innermost/sequential. The valid horizon ``t`` is a
scalar-prefetch operand (SMEM) so cache positions beyond the current decode
step are masked without recompiling per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(t_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            bl, nl, scale):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)                 # [g, hd]
    k = k_ref[0].astype(jnp.float32)                 # [bl, hd]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = j * bl + jax.lax.broadcasted_iota(jnp.int32, (bl,), 0)
    s = jnp.where((pos <= t_ref[0])[None, :], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * corr + p.sum(axis=-1)
    acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(j == nl - 1)
    def _out():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q, k, v, t_valid, *, block_l=256, interpret=True):
    """q: [B, Hkv, g, hd]; k, v: [B, L, Hkv, hd]; t_valid: scalar int32.
    Returns [B, Hkv, g, hd]."""
    B, Hkv, g, hd = q.shape
    L = k.shape[1]
    bl = min(block_l, L)
    pad = (-L) % bl
    if pad:  # padded rows have pos > t_valid -> masked
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nl = Lp // bl
    qr = q.reshape(B * Hkv, g, hd)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * Hkv, Lp, hd)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * Hkv, Lp, hd)
    t_arr = jnp.asarray(t_valid, jnp.int32).reshape(1)

    kern = functools.partial(_kernel, bl=bl, nl=nl, scale=hd ** -0.5)
    out = pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, g, hd), q.dtype),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * Hkv, nl),
            in_specs=[pl.BlockSpec((1, g, hd), lambda b, j, t: (b, 0, 0)),
                      pl.BlockSpec((1, bl, hd), lambda b, j, t: (b, j, 0)),
                      pl.BlockSpec((1, bl, hd), lambda b, j, t: (b, j, 0))],
            out_specs=pl.BlockSpec((1, g, hd), lambda b, j, t: (b, 0, 0)),
            scratch_shapes=[pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g,), jnp.float32),
                            pltpu.VMEM((g, hd), jnp.float32)],
        ),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(t_arr, qr, kr, vr)
    return out.reshape(B, Hkv, g, hd)
