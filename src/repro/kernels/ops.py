"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to auto-detection inside each kernel (compiled on
TPU, interpreter on CPU — repro.compat.resolve_interpret) so the same call
sites run everywhere. The model layers call these when their
``*_impl="pallas"`` knobs are set; the XLA fallbacks in repro.model remain
the default for the CPU dry-run.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.decode_attention import decode_attention_pair as _decode_pair
from repro.kernels.decode_attention import (
    decode_attention_paged as _decode_paged,
    decode_attention_pair_paged as _decode_pair_paged,
)
from repro.kernels.dual_rmsnorm import dual_rmsnorm as _dual
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.ssm_scan import ssm_scan as _scan


@partial(jax.jit, static_argnames=("eps", "plus_one", "block_m"))
def dual_rmsnorm(x, sa, sb, *, eps=1e-6, plus_one=False, block_m=128):
    """x: [..., D] -> (ya, yb) with per-path scales (LP pair norms)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    ya, yb = _dual(x2, sa, sb, eps=eps, plus_one=plus_one, block_m=block_m)
    return ya.reshape(shape), yb.reshape(shape)


@partial(jax.jit, static_argnames=("kind", "window", "chunk", "prefix_len",
                                   "q0", "k0", "q_group", "block_q", "block_k"))
def flash_attention(q, k, v, *, kind="causal", window=0, chunk=0,
                    prefix_len=0, q0=0, k0=0, q_group=1, block_q=128,
                    block_k=128):
    """q: [BH, S, hd]; k, v: [BH, T, hd] -> [BH, S, hd]."""
    return _flash(q, k, v, kind=kind, window=window, chunk=chunk,
                  prefix_len=prefix_len, q0=q0, k0=k0, q_group=q_group,
                  block_q=block_q, block_k=block_k)


@partial(jax.jit, static_argnames=("block_l",))
def decode_attention(q, k, v, t_valid, *, block_l=256):
    """q: [B, Hkv, g, hd]; k, v: [B, L, Hkv, hd] -> [B, Hkv, g, hd]."""
    return _decode(q, k, v, t_valid, block_l=block_l)


@partial(jax.jit, static_argnames=("block_l",))
def decode_attention_pair(q, k, v, t_valid, *, block_l=256):
    """Fused LP-pair decode: q [2, B, Hkv, g, hd]; k, v [2, B, L, Hkv, hd]
    (stacked pair cache) -> [2, B, Hkv, g, hd] in ONE kernel launch."""
    return _decode_pair(q, k, v, t_valid, block_l=block_l)


@jax.jit
def decode_attention_paged(q, k_pages, v_pages, block_tables, t_valid,
                           head_map=None):
    """Paged decode: q [B, Hkv, g, hd]; k/v_pages [n_pages, ps, Hkv, hd];
    block_tables [B, n_pg]; t_valid [B]; head_map optional [Hkv] local ->
    stored kv-head selection (replicated-kv TP) -> [B, Hkv, g, hd]."""
    return _decode_paged(q, k_pages, v_pages, block_tables, t_valid,
                         head_map=head_map)


@jax.jit
def decode_attention_pair_paged(q, k_pages, v_pages, block_tables, t_valid,
                                head_map=None):
    """Fused paged LP-pair decode: q [2, B, Hkv, g, hd]; k/v_pages
    [2, n_pages, ps, Hkv, hd]; one shared block table (and one optional
    head_map) for both halves -> [2, B, Hkv, g, hd] in ONE kernel launch."""
    return _decode_pair_paged(q, k_pages, v_pages, block_tables, t_valid,
                              head_map=head_map)


@partial(jax.jit, static_argnames=("block_s", "block_c"))
def ssm_scan(a, b, h0, *, block_s=256, block_c=128):
    """Selective scan: (y, hT) for h_t = a_t h_{t-1} + b_t."""
    return _scan(a, b, h0, block_s=block_s, block_c=block_c)
