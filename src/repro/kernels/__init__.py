"""Pallas TPU kernels for the perf-critical compute layers (validated in
interpret mode on CPU; compiled on TPU). ops.py holds the jit'd wrappers,
ref.py the pure-jnp oracles the tests allclose against."""
from repro.kernels import ops, ref  # noqa: F401
