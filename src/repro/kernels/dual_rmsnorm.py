"""LP dual RMSNorm — the paper-specific fusion kernel.

An LP pair needs BOTH layers' norms of the SAME residual tensor at each
phase entry. Fusing them reads x from HBM once and writes two outputs —
on TPU v5e this halves the HBM traffic of the norm step (the decode phases
of LP blocks are bandwidth-bound, so the dual norm is pure win; this is the
TPU analogue of the paper's kernel-fusion remark in Appendix C).

Tiling: grid over row-tiles of the flattened [M, D] view; the full feature
dim stays resident (D <= 8192 fp32 = 32 KB/row-tile of VMEM at bm=128 —
well inside the ~16 MB/core budget). fp32 statistics regardless of x dtype.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import resolve_interpret


def _kernel(x_ref, sa_ref, sb_ref, ya_ref, yb_ref, *, eps, plus_one):
    x = x_ref[...].astype(jnp.float32)                      # [bm, D]
    inv = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    xn = x * inv
    sa = sa_ref[...].astype(jnp.float32)
    sb = sb_ref[...].astype(jnp.float32)
    if plus_one:
        sa = sa + 1.0
        sb = sb + 1.0
    ya_ref[...] = (xn * sa[None, :]).astype(ya_ref.dtype)
    yb_ref[...] = (xn * sb[None, :]).astype(yb_ref.dtype)


def dual_rmsnorm(x, sa, sb, *, eps=1e-6, plus_one=False, block_m=128,
                 interpret=None):
    """x: [M, D]; sa, sb: [D] -> (ya, yb). Pads M up to a block multiple."""
    M, D = x.shape
    bm = min(block_m, M)
    pad = (-M) % bm
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    Mp = M + pad
    grid = (Mp // bm,)
    ya, yb = pl.pallas_call(
        partial(_kernel, eps=eps, plus_one=plus_one),
        out_shape=(jax.ShapeDtypeStruct((Mp, D), x.dtype),
                   jax.ShapeDtypeStruct((Mp, D), x.dtype)),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=(pl.BlockSpec((bm, D), lambda i: (i, 0)),
                   pl.BlockSpec((bm, D), lambda i: (i, 0))),
        interpret=resolve_interpret(interpret),
    )(xp, sa, sb)
    return (ya[:M], yb[:M]) if pad else (ya, yb)
