"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def dual_rmsnorm_ref(x, sa, sb, *, eps=1e-6, plus_one=False):
    """x: [M, D]; sa, sb: [D] -> (ya, yb) both [M, D]."""
    x32 = x.astype(jnp.float32)
    inv = jnp.reciprocal(jnp.sqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps))
    xn = x32 * inv
    a = (1.0 + sa.astype(jnp.float32)) if plus_one else sa.astype(jnp.float32)
    b = (1.0 + sb.astype(jnp.float32)) if plus_one else sb.astype(jnp.float32)
    return (xn * a).astype(x.dtype), (xn * b).astype(x.dtype)


def _mask(kind, qpos, kpos, *, window=0, chunk=0, prefix_len=0):
    q = qpos[:, None]
    k = kpos[None, :]
    if kind == "bidir":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = k <= q
    if kind == "causal":
        if prefix_len:
            m = m | (k < prefix_len)
        return m
    if kind == "window":
        return m & (q - k < window)
    if kind == "chunk":
        return m & (q // chunk == k // chunk)
    raise ValueError(kind)


def flash_attention_ref(q, k, v, *, kind="causal", window=0, chunk=0,
                        prefix_len=0, q0=0, k0=0):
    """q: [BH, S, hd]; k, v: [BH, T, hd] -> [BH, S, hd] (fp32 math)."""
    S, T = q.shape[1], k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m = _mask(kind, q0 + jnp.arange(S), k0 + jnp.arange(T),
              window=window, chunk=chunk, prefix_len=prefix_len)
    s = jnp.where(m[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,bth->bsh", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, t_valid):
    """q: [B, Hkv, g, hd]; k, v: [B, L, Hkv, hd]; entries with index > t_valid
    masked. Returns [B, Hkv, g, hd]."""
    L = k.shape[1]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bngh,btnh->bngt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = jnp.arange(L)[None, None, None, :] <= t_valid
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bngt,btnh->bngh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t. a, b: [B, S, C, N]; h0: [B, C, N].
    Returns (h_1..S [B,S,C,N], h_S)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    aT = jnp.moveaxis(a, 1, 0)
    bT = jnp.moveaxis(b, 1, 0)
    hT, ys = jax.lax.scan(step, h0, (aT, bT))
    return jnp.moveaxis(ys, 0, 1), hT
