"""JAX API compatibility shim.

The repo targets the current JAX API surface but must run on older
releases baked into the container. Everything version-dependent is
resolved HERE, once, so the rest of the codebase imports stable names:

  shard_map            — ``jax.shard_map`` (new) vs
                         ``jax.experimental.shard_map.shard_map`` (old);
                         also translates the ``check_vma=`` kwarg (new
                         name) to ``check_rep=`` (old name).
  tpu_compiler_params  — ``pltpu.CompilerParams`` (new) vs
                         ``pltpu.TPUCompilerParams`` (old).
  default_interpret    — Pallas ``interpret`` auto-detection: compiled on
                         TPU, interpreter everywhere else, so the same
                         kernel call sites run on CPU CI and on hardware.

Keep this module dependency-light: it is imported by the kernels and the
sharded entry points before anything else in the package.
"""
from __future__ import annotations

import functools
from typing import Any

import jax

# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # pre-0.6 JAX: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def _accepts_kwarg(fn, name: str) -> bool:
    import inspect
    try:
        return name in inspect.signature(fn).parameters
    except (TypeError, ValueError):  # C accelerated / wrapped callables
        return False


_HAS_CHECK_VMA = _accepts_kwarg(_shard_map_impl, "check_vma")
_HAS_CHECK_REP = _accepts_kwarg(_shard_map_impl, "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """Version-stable ``shard_map``.

    ``check_vma`` follows the new-JAX spelling; on older releases it is
    forwarded as ``check_rep`` (same semantics: disable the replication /
    varying-manual-axes check), and dropped entirely if neither kwarg
    exists.
    """
    kw: dict[str, Any] = {}
    if check_vma is not None:
        if _HAS_CHECK_VMA:
            kw["check_vma"] = check_vma
        elif _HAS_CHECK_REP:
            kw["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


# Install the modern alias so call sites (and REPL snippets) written against
# new JAX — ``jax.shard_map(..., check_vma=False)`` — run unchanged.
if not hasattr(jax, "shard_map"):
    jax.shard_map = shard_map


# ---------------------------------------------------------------------------
# Pallas TPU compiler params
# ---------------------------------------------------------------------------

def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` on new JAX, ``pltpu.TPUCompilerParams`` on
    old; kwargs (e.g. ``dimension_semantics``) are identical across both."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Pallas interpret auto-detection
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True (interpreter) off-TPU, False (compiled) on TPU. Used as the
    default for every kernel's ``interpret=None``."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)
