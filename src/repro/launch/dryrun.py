import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell over the production mesh, prove the memory/sharding story, and emit
the roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--no-lp]

Cost-accounting notes (XLA cost_analysis hides lax.scan trip counts):
  * segment scans are UNROLLED for the dry-run (set_scan_unroll) — exact;
  * train cells lower the accumulation MICRO-step (accum=1, batch/accum)
    and scale the forward/backward terms by ``accum`` analytically; the
    once-per-step optimizer/grad-reduction collectives are separated with
    an exact byte model of the ZeRO schedule;
  * the tiled attention core hides its kv loop -> the true core FLOPs are
    added analytically (repro.analysis.roofline.attention_flops).

Results append to benchmarks/results/dryrun*.json incrementally so a
partial sweep survives interruption.
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import (Roofline, attention_flops,
                                     collective_bytes, model_flops)
from repro.configs import ASSIGNED_ARCHS, SHAPES, applicable, get_config
from repro.configs.shapes import ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (batch_specs, build_cell_structure, cell_policy,
                                decode_specs)
from repro.model import stack as STK
from repro.model import transformer as T
from repro.serve.engine import ServeConfig, make_sharded_prefill, make_sharded_serve_step
from repro.train import OptConfig, TrainConfig
from repro.train.trainer import _leaf_meta, abstract_state, make_sharded_train_step

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")


def _attach(mesh, abs_tree, spec_tree):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=NamedSharding(mesh, s)),
        abs_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _grad_reduction_bytes(ms, pc, tc) -> float:
    """Exact per-device wire bytes of the once-per-step ZeRO schedule:
    psum_scatter(fp32 grads) + all_gather(bf16 params) for regular leaves,
    cross-pod psum for FSDP leaves, tp-psum for replicated leaves."""
    _, _, infos = _leaf_meta(ms)
    pdt = jnp.dtype(tc.param_dtype).itemsize
    pod = pc.pod_size if "pod" in pc.dp_axes else 1
    total = 0.0
    for li in infos:
        n_loc = 1
        for d in li.pd.shape:
            n_loc *= d
        if li.fsdp:
            # stored local size = count*chunk (per (data, tp) rank)
            n_rank = li.pd.shape[0] * li.pd.shape[3]
            if pod > 1:
                total += 2 * 4 * n_rank  # cross-pod fp32 psum (ring 2x)
            if not li.tp_sharded:
                total += 2 * 4 * n_rank
        else:
            from repro.train.trainer import _chunk, _local_shape
            loc = _local_shape(li.pd.shape, li.pspec, pc.tp_size)
            n_rank = 1
            for d in loc:
                n_rank *= d
            total += 4 * n_rank          # psum_scatter fp32 grads
            total += pdt * n_rank        # all_gather fresh params
            if not li.tp_sharded:
                total += 2 * 4 * n_rank  # tp psum of replicated grads
    return total


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               lp: bool = True, tp: int = 16,
               policy_override=None) -> Dict[str, Any]:
    """Lower + compile one cell; return the dry-run record."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    data = mesh.shape["data"]
    dp = data * (mesh.shape["pod"] if "pod" in mesh.axis_names else 1)
    dp_ax = ("pod", "data") if "pod" in mesh.axis_names else "data"
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    pol = cell_policy(cfg, shape, tp=tp, dp=dp, lp=lp)
    if policy_override:
        pol = policy_override(pol)
    ms = build_cell_structure(cfg, shape, pol, tp=tp, data=data)
    row = dp_ax if pol.shard_batch else None

    def build_lowered():
        accum = 1
        extra_mem_gb = 0.0
        if shape.step == "train":
            accum = pol.accum
            micro_shape = dataclasses.replace(
                shape, global_batch=max(shape.global_batch // accum, 1))
            tc = TrainConfig(opt=OptConfig(), accum=1, remat=True,
                             param_dtype=jnp.bfloat16)
            babs = batch_specs(cfg, micro_shape, pol)
            fn, s_specs, b_specs, pc = make_sharded_train_step(
                ms, mesh, tc, babs, sp=pol.sp, donate=False)
            st_abs = _attach(mesh, abstract_state(ms, pc, tc), s_specs)
            b_abs = batch_specs(cfg, micro_shape, pol, mesh=mesh, dp_ax=row)
            lowered = fn.lower(st_abs, b_abs)
            # fp32 grad-accumulation carry lives across micros in the real
            # accum'd program; account for it on top of the micro peak.
            if accum > 1:
                _, _, infos = _leaf_meta(ms)
                n_loc = 0
                from repro.train.trainer import _local_shape
                for li in infos:
                    if li.fsdp:
                        n_loc += li.pd.shape[0] * li.pd.shape[3]
                    else:
                        loc = _local_shape(li.pd.shape, li.pspec, pc.tp_size)
                        k = 1
                        for d in loc:
                            k *= d
                        n_loc += k
                extra_mem_gb = 4 * n_loc / 2**30
            return lowered, pc, tc, accum, extra_mem_gb
        elif shape.step == "prefill":
            sv = ServeConfig(max_len=shape.seq_len, kv_mode=pol.kv_mode)
            fn, _, pc = make_sharded_prefill(ms, mesh, sv,
                                             batch=shape.global_batch,
                                             prompt_len=shape.seq_len,
                                             sp=pol.sp)
            p_abs = _attach(mesh, T.abstract_params(ms), T.param_pspecs(ms))
            b = batch_specs(cfg, shape, pol, mesh=mesh, dp_ax=row)
            args = [p_abs, b["tokens"]]
            if cfg.prefix_len:
                args.append(b["prefix"])
            if cfg.enc_layers:
                args.append(b["frames"])
            lowered = fn.lower(*args)
            return lowered, pc, None, accum, extra_mem_gb
        else:  # decode
            sv = ServeConfig(max_len=shape.seq_len, kv_mode=pol.kv_mode)
            fn, c_abs, c_specs, pc = make_sharded_serve_step(
                ms, mesh, sv, batch=shape.global_batch,
                shard_batch=pol.shard_batch)
            p_abs = _attach(mesh, T.abstract_params(ms), T.param_pspecs(ms))
            tok, caches, t, key = decode_specs(cfg, shape, pol, ms,
                                               mesh=mesh, dp_ax=row)
            lowered = fn.lower(p_abs, tok, caches, t, key)
            return lowered, pc, None, accum, extra_mem_gb

    # Phase 1 (cost): segment scans UNROLLED so cost_analysis sees every
    # layer; memory of this form is NOT representative (no buffer reuse).
    # The multi-pod pass proves the pod axis shards and the program still
    # fits — its roofline terms come from the single-pod table, so it
    # compiles the (faster) scan form only.
    if multi_pod:
        lowered, pc, tc, accum, extra_mem_gb = build_lowered()
        compiled = compiled_scan = lowered.compile()
    else:
        STK.set_scan_unroll(True)
        try:
            lowered, pc, tc, accum, extra_mem_gb = build_lowered()
            compiled = lowered.compile()
        finally:
            STK.set_scan_unroll(False)

        # Phase 2 (memory): the production scan form — the fits-proof.
        lowered_scan, _, _, _, _ = build_lowered()
        compiled_scan = lowered_scan.compile()

    try:
        mem = compiled_scan.memory_analysis()
        mem_row = {
            "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 2**30,
            "output_gb": getattr(mem, "output_size_in_bytes", 0) / 2**30,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
            "accum_buffer_gb": extra_mem_gb,
            "peak_gb": (getattr(mem, "argument_size_in_bytes", 0)
                        + getattr(mem, "temp_size_in_bytes", 0)) / 2**30
                       + extra_mem_gb,
        }
    except Exception as e:  # pragma: no cover
        mem_row = {"error": str(e)[:200]}
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = collective_bytes(text)

    chips = dp * tp
    f_parsed = float(cost.get("flops", 0.0))
    b_parsed = float(cost.get("bytes accessed", 0.0))
    c_parsed = coll.get("total", 0.0)
    f_attn = attention_flops(cfg, shape, tp=tp) / chips
    if shape.step == "train":
        c_grad = _grad_reduction_bytes(ms, pc, tc)
        b_opt = 32.0 * (extra_mem_gb / 4 * 2**30 if accum > 1 else 0.0)
        f_step = accum * f_parsed + f_attn
        b_step = accum * max(b_parsed - b_opt, 0.0) + b_opt
        c_step = accum * max(c_parsed - c_grad, 0.0) + c_grad
        coll = dict(coll)
        coll["total"] = c_step
        coll["grad_reduction"] = c_grad
        coll["n_ops"] = accum * coll.get("n_ops", 0)  # fwd colls per micro
    else:
        f_step = f_parsed + f_attn
        b_step = b_parsed
        c_step = c_parsed

    # Per-device payload bytes: weights touched once per step (+cache for
    # serving shapes). Train touches weights fwd+bwd+optimizer.
    p_loc = sum(
        int(jnp.prod(jnp.array(l.shape)))
        for l in jax.tree.leaves(T.abstract_params(ms))) // (
            1 if ms.fsdp else 1)
    p_dev = p_loc / (tp if not ms.fsdp else tp * data)
    if shape.step == "train":
        useful = 38.0 * p_dev  # bf16 fwd+bwd + fp32 m/v/master r+w + grads
    elif shape.step == "prefill":
        useful = 2.0 * p_dev
    else:
        cache_n = sum(int(jnp.prod(jnp.array(l.shape)))
                      for l in jax.tree.leaves(
                          T.cache_meta(ms, batch=shape.global_batch,
                                       max_len=shape.seq_len,
                                       kv_mode=pol.kv_mode)[0]))
        useful = 2.0 * p_dev + 2.0 * cache_n / chips  # bf16 read (+write)
    rl = Roofline(flops=f_step, bytes_accessed=b_step, coll=coll,
                  model_flops=model_flops(cfg, shape), chips=chips,
                  useful_bytes=useful)
    rec = {
        "arch": arch, "shape": shape_name, "lp": lp,
        "multi_pod": multi_pod, "chips": chips,
        "eff_depth": ms.effective_depth, "n_layers": cfg.n_layers,
        "n_pairs": len(ms.plan.pairs),
        "fsdp": pol.fsdp, "kv_mode": pol.kv_mode, "accum": accum,
        "memory": mem_row,
        "coll": {k: v for k, v in coll.items()},
        "cost_raw": {"flops": f_parsed, "bytes": b_parsed,
                     "attn_correction_flops": f_attn},
        "roofline": rl.row(),
        "compile_s": round(time.time() - t0, 1),
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-lp", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(RESULTS, exist_ok=True)
    suffix = "_mp" if args.multi_pod else ""
    suffix += "_nolp" if args.no_lp else ""
    out_path = args.out or os.path.join(RESULTS, f"dryrun{suffix}.json")
    done: Dict[str, Any] = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            done = json.load(f)

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for arch in archs:
        for shape in shapes:
            k = f"{arch}/{shape}"
            if k in done and "error" not in done[k] and not args.force:
                print(f"[skip cached] {k}")
                continue
            print(f"[lower] {k} multi_pod={args.multi_pod} lp={not args.no_lp}",
                  flush=True)
            try:
                rec = lower_cell(arch, shape, multi_pod=args.multi_pod,
                                 lp=not args.no_lp)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "error": str(e)[:500]}
            done[k] = rec
            with open(out_path, "w") as f:
                json.dump(done, f, indent=1)
            if "roofline" in rec:
                r = rec["roofline"]
                print(f"  ok: bottleneck={r['bottleneck']} "
                      f"t=({r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
                      f"{r['t_collective_s']:.4f})s "
                      f"roofline={r['roofline_fraction']:.3f} "
                      f"peak={rec['memory'].get('peak_gb', -1):.2f}GB "
                      f"compile={rec['compile_s']}s", flush=True)
            elif "skipped" in rec:
                print(f"  skipped: {rec['skipped']}")
            else:
                print(f"  ERROR: {rec.get('error', '?')[:200]}")


if __name__ == "__main__":
    main()
