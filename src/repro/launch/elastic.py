"""Elastic / fault-tolerant launcher: bounded-retry supervision around the
training loop + the failure-injection used by tests.

At 1000+-node scale the failure model is: a host dies mid-step, the
coordinator tears the slice down, brings up a (possibly smaller) slice and
the job must resume from the last committed checkpoint with zero manual
intervention. The pieces that make that true here:

  * checkpoints are atomic + mesh-agnostic (repro.train.checkpoint) — a
    restart on a DIFFERENT dp/tp geometry (or FSDP toggled) re-flattens the
    same logical arrays;
  * batches are pure functions of the step index — the resumed run consumes
    exactly the batches the dead run would have;
  * ``supervise`` retries the loop with exponential backoff up to
    ``max_restarts``, re-entering through the resume path each time.

Straggler mitigation (documented design, exercised by the watchdog):
the per-step watchdog bounds a straggling host's damage to one step; the
deterministic data pipeline means a restarted straggler replays the same
step rather than forking the batch order.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.launch.train import RunConfig, train_loop


class InjectedFailure(RuntimeError):
    pass


def failing_hook(fail_at_step: int):
    """Raise once at ``fail_at_step`` (simulated host loss mid-run)."""
    state = {"armed": True}

    def hook(step, metrics):
        if state["armed"] and step == fail_at_step:
            state["armed"] = False
            raise InjectedFailure(f"injected node failure at step {step}")

    return hook


def supervise(rc: RunConfig, *, max_restarts: int = 3, backoff_s: float = 0.1,
              hook: Optional[Callable] = None) -> Dict:
    """Run train_loop under bounded-retry supervision; resume from the last
    checkpoint after every failure."""
    assert rc.ckpt_dir, "supervision requires a checkpoint directory"
    attempt = 0
    while True:
        try:
            return train_loop(rc, hook=hook)
        except Exception as e:  # noqa: BLE001 — any failure triggers restart
            attempt += 1
            if attempt > max_restarts:
                raise RuntimeError(
                    f"giving up after {max_restarts} restarts") from e
            print(f"[elastic] attempt {attempt} failed: {e!r}; "
                  f"restarting from latest checkpoint", flush=True)
            time.sleep(backoff_s * (2 ** (attempt - 1)))
