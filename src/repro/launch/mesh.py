"""Production meshes.

Single pod : (data=16, model=16)            — 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     — 512 chips across DCI

``model`` maps to the fast intra-pod ICI ring (TP + LP live here), ``data``
to the remaining intra-pod dimension (pure DP + FSDP weight shards), and
``pod`` crosses the data-center interconnect (gradient psum only — the
trainer optionally int8-compresses exactly this hop).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    assert len(devices) == n, (
        f"need {n} devices for the production mesh, have {len(jax.devices())} "
        "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU multi-device tests (8 host devices)."""
    n = 1
    for s in shape:
        n *= s
    import numpy as np
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)


def parse_mesh_spec(spec: str):
    """'DxM' -> (data, model) sizes; the CLI mesh grammar shared by
    launch/serve.py, benchmarks/serve_throughput.py and lp_speed.py."""
    try:
        d, m = (int(x) for x in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh spec must be DxM (e.g. 1x2), got {spec!r}")
    if d < 1 or m < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    return d, m


def make_serving_mesh(spec: str):
    """(mesh | None, model_size) from a 'DxM' CLI spec.

    '1x1' means plain single-device execution (mesh None). Any D > 1 is
    REJECTED instead of silently accepted: serving shards only over the
    model axis today, so a data axis would either be dropped (engine
    inputs are replicated — every data rank duplicates identical work) or
    crash shard_map on batches not divisible by D (the one-shot prefill
    dp-shards its batch). Insufficient devices exit with the XLA_FLAGS
    incantation rather than an opaque reshape error.
    """
    d, m = parse_mesh_spec(spec)
    if d > 1:
        raise ValueError(
            f"mesh {spec!r}: serving shards only the model axis; use 1xM "
            "(data-parallel serving means running engine replicas)")
    if m == 1:
        return None, 1
    n = len(jax.devices())
    if n < m:
        raise SystemExit(
            f"mesh {spec} needs {m} devices, found {n}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={max(8, m)}")
    return jax.make_mesh((1, m), ("data", "model")), m
