"""Production meshes.

Single pod : (data=16, model=16)            — 256 chips (TPU v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     — 512 chips across DCI

``model`` maps to the fast intra-pod ICI ring (TP + LP live here), ``data``
to the remaining intra-pod dimension (pure DP + FSDP weight shards), and
``pod`` crosses the data-center interconnect (gradient psum only — the
trainer optionally int8-compresses exactly this hop).

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before the first jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    assert len(devices) == n, (
        f"need {n} devices for the production mesh, have {len(jax.devices())} "
        "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count=512)")
    import numpy as np
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for CPU multi-device tests (8 host devices)."""
    n = 1
    for s in shape:
        n *= s
    import numpy as np
    return jax.sharding.Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
