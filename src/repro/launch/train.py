"""End-to-end training driver with the fault-tolerance loop.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --steps 200 --eff-depth 20 --ckpt-dir /tmp/run1

Runs on whatever devices exist (CPU smoke -> 1 device; a real slice -> the
production mesh). The loop is restart-safe: batches are a pure function of
the step index, checkpoints commit atomically, and --resume picks up the
latest manifest. ``repro.launch.elastic`` wraps this loop with the failure
simulation used by tests/test_elastic.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.lp import EMPTY_PLAN, plan_for_depth
from repro.data import DataConfig, SynthConfig, make_source
from repro.model import transformer as T
from repro.parallel.context import ParallelContext, make_context
from repro.train import OptConfig, TrainConfig, checkpoint as CK
from repro.train.trainer import (init_state, make_sharded_train_step,
                                 make_train_step, state_pspecs)


@dataclasses.dataclass
class RunConfig:
    arch: str = "tinyllama-1.1b"
    reduced: bool = True          # CPU-sized config for in-container runs
    n_layers: int = 0             # 0 -> family default (reduced only)
    eff_depth: int = 0            # 0 -> no LP
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    lr: float = 3e-3
    warmup: int = 20
    accum: int = 1
    remat: bool = False
    finetune_lp_only: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    eval_every: int = 25
    seed: int = 0
    log_every: int = 10


class Watchdog:
    """Detects a hung step (straggler / dead host) so the launcher can kill
    and restart from the last checkpoint. On this CPU container it guards
    against pathological compile/step times."""

    def __init__(self, timeout_s: float = 600.0):
        self.timeout_s = timeout_s
        self._last = time.monotonic()

    def tick(self):
        now = time.monotonic()
        dt = now - self._last
        self._last = now
        if dt > self.timeout_s:
            raise TimeoutError(f"step exceeded watchdog budget ({dt:.0f}s)")


def build(rc: RunConfig):
    cfg = get_config(rc.arch)
    if rc.reduced:
        cfg = reduced_config(cfg, n_layers=rc.n_layers or None)
    plan = (plan_for_depth(cfg, rc.eff_depth) if rc.eff_depth
            else EMPTY_PLAN)
    ms = T.build_structure(cfg, plan=plan, tp=1)
    tc = TrainConfig(
        opt=OptConfig(lr=rc.lr, warmup_steps=rc.warmup, total_steps=rc.steps,
                      schedule="wsd"),
        accum=rc.accum, remat=rc.remat,
        finetune_lp_only=rc.finetune_lp_only)
    sc = SynthConfig(vocab_size=cfg.vocab_size)
    src = make_source(DataConfig(seq_len=rc.seq_len,
                                 global_batch=rc.global_batch,
                                 seed=rc.seed), sc)
    return cfg, ms, tc, src


def train_loop(rc: RunConfig, *, state=None, hook=None) -> Dict[str, Any]:
    """Run (or resume) the training loop. Returns the final state + metrics
    history. ``hook(step, metrics)`` is the failure-injection point for the
    elastic tests."""
    cfg, ms, tc, src = build(rc)
    pc = ParallelContext()
    step_fn = jax.jit(make_train_step(ms, pc, tc), donate_argnums=(0,))

    ckpt = CK.AsyncCheckpointer(rc.ckpt_dir) if rc.ckpt_dir else None
    start_step = 0
    if state is None:
        if rc.ckpt_dir and CK.latest_step(rc.ckpt_dir) is not None:
            like = CK.state_to_logical(
                init_state(ms, jax.random.PRNGKey(rc.seed), pc, tc), ms, pc)
            logical = CK.restore(rc.ckpt_dir, like)
            state = CK.logical_to_state(logical, ms, pc, tc)
            start_step = int(state["step"])
            print(f"[resume] from step {start_step}")
        else:
            state = init_state(ms, jax.random.PRNGKey(rc.seed), pc, tc)

    wd = Watchdog()
    history = []
    for step in range(start_step, rc.steps):
        batch = src.batch_at(step)
        state, metrics = step_fn(state, batch)
        wd.tick()
        if hook is not None:
            hook(step, metrics)
        if step % rc.log_every == 0 or step == rc.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            print(f"[{step:5d}] loss={m['loss']:.4f} xent={m['xent']:.4f} "
                  f"gnorm={m['grad_norm']:.3f} lr={m['lr']:.2e}", flush=True)
        if ckpt and (step + 1) % rc.ckpt_every == 0:
            ckpt.save(CK.state_to_logical(state, ms, pc), step + 1)
    if ckpt:
        ckpt.save(CK.state_to_logical(state, ms, pc), rc.steps)
        ckpt.wait()
    return {"state": state, "history": history, "ms": ms, "cfg": cfg}


def main() -> None:
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(RunConfig):
        name = "--" + f.name.replace("_", "-")
        if f.type == "bool" or isinstance(f.default, bool):
            ap.add_argument(name, action="store_true", default=f.default)
        else:
            ap.add_argument(name, type=type(f.default) if f.default is not None
                            else str, default=f.default)
    args = ap.parse_args()
    rc = RunConfig(**{f.name: getattr(args, f.name)
                      for f in dataclasses.fields(RunConfig)})
    train_loop(rc)


if __name__ == "__main__":
    main()
