"""Per-(architecture x shape) cell policy + abstract input specs.

``cell_policy`` encodes the static decisions the launcher makes per cell:
LP plan, FSDP on/off, KV-cache mode, gradient-accumulation factor, batch
sharding. ``input_specs`` produces the ShapeDtypeStruct stand-ins that the
dry-run lowers against (weak-type-correct, shardable, no allocation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.configs.shapes import ShapeConfig
from repro.core.lp import LPPlan, default_plan
from repro.model import transformer as T

# Architectures whose bf16 weights per chip exceed the v5e HBM budget at
# TP=16 and therefore train AND serve with FSDP (ZeRO-3) over the data axis.
FSDP_ARCHS = frozenset({"dbrx-132b", "llama4-scout-17b-a16e"})
# Architectures that fit for serving but whose train step (fp32 grads +
# optimizer + activations) needs the weights sharded too.
TRAIN_FSDP_ARCHS = FSDP_ARCHS | frozenset({"granite-34b"})


@dataclass(frozen=True)
class CellPolicy:
    plan: LPPlan
    fsdp: bool
    kv_mode: str          # heads | seq
    accum: int            # train-shape gradient accumulation
    shard_batch: bool     # False -> replicate batch over dp (e.g. batch 1)
    sp: bool              # sequence parallelism for full-seq programs
    remat: bool = True
    quant: bool = False   # int8 FSDP weight shards (serving only)


def cell_policy(cfg: ArchConfig, shape: ShapeConfig, *, tp: int = 16,
                dp: int = 16, lp: bool = True) -> CellPolicy:
    plan = default_plan(cfg) if lp else LPPlan(())
    fsdp = cfg.name in (FSDP_ARCHS if shape.step == "decode"
                        else TRAIN_FSDP_ARCHS)
    # Decode caches: sequence-shard over `model` when kv heads < tp
    # (avoids tp-fold cache replication).
    kv_mode = "seq" if (0 < cfg.n_kv_heads < tp) else "heads"
    shard_batch = shape.global_batch % dp == 0
    # Keep per-microbatch activations ~1 sequence per chip for train.
    local_batch = shape.global_batch // dp if shard_batch else shape.global_batch
    accum = max(1, local_batch) if shape.step == "train" else 1
    # Cap accum so the scan stays shallow on small-activation archs.
    if cfg.d_model * shape.seq_len <= 2048 * 4096:
        accum = max(1, local_batch // 4)
    return CellPolicy(plan=plan, fsdp=fsdp, kv_mode=kv_mode, accum=accum,
                      shard_batch=shard_batch, sp=True)


def build_cell_structure(cfg: ArchConfig, shape: ShapeConfig, pol: CellPolicy,
                         *, tp: int = 16, data: int = 16) -> T.ModelStructure:
    return T.build_structure(cfg, plan=pol.plan, tp=tp,
                             fsdp=pol.fsdp, fsdp_data=data, quant=pol.quant)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh=None, spec=None):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=jax.sharding.NamedSharding(mesh, spec or P()))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, pol: CellPolicy,
                mesh=None, dp_ax=None) -> Dict[str, Any]:
    """Abstract train/prefill batch for one cell (GLOBAL shapes)."""
    B, S = shape.global_batch, shape.seq_len
    row = dp_ax if pol.shard_batch else None
    out = {"tokens": _sds((B, S), jnp.int32, mesh, P(row, None))}
    if shape.step == "train":
        out["labels"] = _sds((B, S), jnp.int32, mesh, P(row, None))
    if cfg.prefix_len:
        out["prefix"] = _sds((B, cfg.prefix_len, cfg.d_model), jnp.bfloat16,
                             mesh, P(row, None, None))
    if cfg.enc_layers:
        out["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
                             mesh, P(row, None, None))
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeConfig, pol: CellPolicy,
                 ms: T.ModelStructure, mesh=None, dp_ax=None):
    """(tok, caches, t, key) abstract inputs for serve_step."""
    B = shape.global_batch
    row = dp_ax if pol.shard_batch else None
    cache_abs, cache_ps = T.cache_meta(ms, batch=B, max_len=shape.seq_len,
                                       kv_mode=pol.kv_mode)
    if mesh is not None:
        def attach(path, a, ps):
            parts = list(ps)
            parts[T.cache_batch_axis(path[-1].key)] = row
            return jax.ShapeDtypeStruct(
                a.shape, a.dtype,
                sharding=jax.sharding.NamedSharding(mesh, P(*parts)))
        cache_abs = jax.tree_util.tree_map_with_path(
            attach, cache_abs, cache_ps,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tok = _sds((B,), jnp.int32, mesh, P(row))
    t = _sds((), jnp.int32, mesh, P())
    key = _sds((2,), jnp.uint32, mesh, P())
    return tok, cache_abs, t, key
