"""Serving driver: batched prefill + decode with an LP model.

One-shot fixed batch (the paper's measurement setup):

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --eff-depth 20 --batch 4 --prompt-len 64 --new-tokens 32

Continuous batching over the paged pair-KV cache pool (deployment shape —
requests arrive staggered, share pages, finish independently):

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --eff-depth 20 --continuous --requests 16 --new-tokens 32

Sharded continuous batching (tp > 1: the page pool shards its kv-head axis
over the model axis, scheduling stays host-side):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch tinyllama-1.1b --eff-depth 20 \
        --continuous --mesh 1x2 --requests 16 --new-tokens 32

In-container this runs the reduced config on CPU host devices; on a real
slice the same shard_map programs run unchanged.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core.lp import EMPTY_PLAN, plan_for_depth
from repro.launch.mesh import make_serving_mesh
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (AdmissionConfig, DegradeConfig, PagedEngine,
                         PagedServeConfig, QueueFullError, ServeConfig,
                         SpecConfig, TelemetryConfig, generate,
                         make_sharded_generate)


def _parse_buckets(text: str):
    """--bucket-sizes value -> PagedServeConfig.prefill_buckets: "auto"
    (None, the power-of-two ladder), "off" ((), exact-length prefill), or
    comma-separated widths ("8,16,32")."""
    text = text.strip().lower()
    if text == "auto":
        return None
    if text == "off":
        return ()
    try:
        return tuple(int(t) for t in text.split(","))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"--bucket-sizes {text!r}: expected 'auto', 'off', or "
            "comma-separated ints like '8,16,32'")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--eff-depth", type=int, default=0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over the paged KV cache pool")
    ap.add_argument("--requests", type=int, default=16,
                    help="(--continuous) number of synthetic requests")
    ap.add_argument("--page-size", type=int, default=16,
                    help="(--continuous) tokens per cache page")
    ap.add_argument("--mesh", default="1x1",
                    help="1xM device mesh; M > 1 runs the shard_map "
                         "programs with tp=M — needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count>=M on CPU")
    ap.add_argument("--preempt-after", type=int, default=0,
                    help="(--continuous) blocked-head steps before the "
                         "youngest running request is preempted (0 = off)")
    ap.add_argument("--prefix-cache", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="(--continuous) radix prefix sharing over whole "
                         "cache pages, tp=1 and sharded --mesh engines "
                         "alike (--no-prefix-cache disables)")
    ap.add_argument("--deadline-steps", type=int, default=0,
                    help="(--continuous) per-request deadline, engine "
                         "steps after submission; overrun requests EXPIRE "
                         "and release their pages (0 = none)")
    # Argument groups mirror the PagedServeConfig sub-configs one-to-one:
    # each group below builds exactly one grouped kwarg.
    adm = ap.add_argument_group(
        "admission (AdmissionConfig)",
        "what enters the engine per step, and at what padded cost")
    adm.add_argument("--prefill-token-budget", type=int, default=4096,
                     help="(--continuous) max prefill tokens admitted per "
                          "step after the first (prefill/decode "
                          "interleave); bucketed admissions cost their "
                          "PADDED width")
    adm.add_argument("--max-queue", type=int, default=0,
                     help="(--continuous) bound the submit queue; a full "
                          "queue sheds the slackest-deadline request for a "
                          "more urgent newcomer, else rejects (0 = "
                          "unbounded)")
    adm.add_argument("--bucket-sizes", type=_parse_buckets, default="auto",
                     help="(--continuous) prefill bucket ladder: 'auto' "
                          "(power-of-two page multiples up to max_len), "
                          "'off' (exact-length prefill, one compile per "
                          "distinct prompt length), or comma-separated "
                          "widths like '16,32,64'")
    deg = ap.add_argument_group(
        "overload degradation (DegradeConfig)",
        "surge admissions at an aggressive-Δ re-pairing of the weights")
    deg.add_argument("--degrade-delta", action="store_true",
                     help="(--continuous) overload degradation: overflow "
                          "admissions run an aggressive-Δ re-pairing of "
                          "the same weights in a reserved slot cohort")
    deg.add_argument("--degrade-slots", type=int, default=0,
                     help="(--degrade-delta) slots reserved for the "
                          "degraded cohort (default: half the batch)")
    deg.add_argument("--degrade-eff-depth", type=int, default=0,
                     help="(--degrade-delta) effective depth of the "
                          "degraded cohort (0 = maximal pairing)")
    spec = ap.add_argument_group(
        "speculative decoding (SpecConfig)",
        "shallow-Δ drafts verified by the full-depth decode program")
    spec.add_argument("--spec-k", type=int, default=0,
                      help="(--continuous) self-speculative decoding: "
                           "draft this many greedy tokens per step with "
                           "the same weights re-paired at an aggressive "
                           "Δ, verify them in one full-depth launch "
                           "(greedy-only, tp=1; 0 = off)")
    spec.add_argument("--spec-delta", type=int, default=0,
                      help="(--spec-k) drafter effective depth (0 = "
                           "maximal pairing)")
    tel = ap.add_argument_group(
        "telemetry (TelemetryConfig)",
        "observation must never change the served bits")
    tel.add_argument("--trace-out", default="",
                     help="(--continuous) write the run's Chrome/Perfetto "
                          "trace_event JSON here (open in chrome://tracing "
                          "or ui.perfetto.dev)")
    tel.add_argument("--metrics-out", default="",
                     help="(--continuous) write the run's metrics snapshot "
                          "here; a .prom suffix writes Prometheus text "
                          "instead of JSON")
    tel.add_argument("--telemetry", action=argparse.BooleanOptionalAction,
                     default=True,
                     help="(--continuous) retain spans/gauge series for "
                          "traces (--no-telemetry caps memory on long "
                          "soaks; counters and faults stay live)")
    tel.add_argument("--profile-decode", action="store_true",
                     help="(--continuous) bracket each decode launch in a "
                          "jax.profiler StepTraceAnnotation (only useful "
                          "under an active jax profiler session)")
    args = ap.parse_args()
    if isinstance(args.bucket_sizes, str):      # default never went through
        args.bucket_sizes = _parse_buckets(args.bucket_sizes)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = reduced_config(cfg)
    plan = (plan_for_depth(cfg, args.eff_depth) if args.eff_depth
            else EMPTY_PLAN)
    mesh, mesh_m = make_serving_mesh(args.mesh)
    ms = T.build_structure(cfg, plan=plan, tp=mesh_m)
    params = T.init_params(ms, jax.random.PRNGKey(0))
    pc = ParallelContext()

    if args.continuous:
        ps = args.page_size
        max_len = -(-(args.prompt_len + args.new_tokens + 8) // ps) * ps
        deg_slots = (args.degrade_slots or args.batch // 2
                     if args.degrade_delta else 0)
        psv = PagedServeConfig(
            n_slots=args.batch, page_size=ps,
            n_pages=1 + args.batch * (max_len // ps), max_len=max_len,
            temperature=args.temperature,
            prefix_cache=args.prefix_cache,
            preempt_after=args.preempt_after,
            admission=AdmissionConfig(
                prefill_token_budget=args.prefill_token_budget,
                max_queue=args.max_queue,
                prefill_buckets=args.bucket_sizes),
            degrade=DegradeConfig(
                enabled=args.degrade_delta, slots=deg_slots,
                eff_depth=args.degrade_eff_depth),
            spec=SpecConfig(k=args.spec_k, delta=args.spec_delta),
            telemetry_cfg=TelemetryConfig(
                enabled=args.telemetry,
                profile_decode=args.profile_decode))
        if args.trace_out and not args.telemetry:
            ap.error("--trace-out needs telemetry (drop --no-telemetry)")
        eng = PagedEngine(params, ms, psv, mesh=mesh)
        key = jax.random.PRNGKey(1)
        # A shared head (page-aligned) + per-request tails: realistic
        # system-prompt traffic that exercises the radix cache when on.
        shared_len = min(args.prompt_len // 2 // ps * ps, args.prompt_len)
        shared = np.asarray(jax.random.randint(
            jax.random.fold_in(key, 999), (shared_len,), 0, cfg.vocab_size))
        lens = [max(4, args.prompt_len - shared_len - 8 * (i % 3))
                for i in range(args.requests)]
        t0 = time.time()
        rejected = 0
        for i, L in enumerate(lens):
            tail = np.asarray(jax.random.randint(
                jax.random.fold_in(key, i), (L,), 0, cfg.vocab_size))
            prompt = np.concatenate([shared, tail])
            dl = (eng.step_count + args.deadline_steps
                  if args.deadline_steps else None)
            try:
                eng.add_request(prompt, args.new_tokens, deadline=dl)
            except QueueFullError:
                # Bounded queue, nothing slacker to shed: serve a step to
                # make room, then drop this arrival (typed, counted).
                rejected += 1
                eng.step()
        res = eng.drain()
        run = time.time() - t0
        toks = sum(len(v) for v in res.values())
        c = eng.counters
        print(f"arch={cfg.name} eff_depth={ms.effective_depth}/{cfg.n_layers} "
              f"tp={ms.tp} "
              f"continuous: {args.requests} reqs x {args.new_tokens} new, "
              f"slots={psv.n_slots} pages={psv.n_pages - 1}x{ps} "
              f"prefix_cache={'on' if eng.prefix is not None else 'off'} "
              f"preempt_after={args.preempt_after}")
        print(f"run={run:.3f}s throughput={toks / run:.1f} tok/s "
              f"steps={eng.step_count} "
              f"pages alloc/freed={eng.pool.allocated_total}"
              f"/{eng.pool.freed_total} "
              f"prefill_toks={c['prefill_tokens']} "
              f"hit_toks={c['hit_tokens']} "
              f"preemptions={eng.sched.preemptions_total}")
        if eng.spec_k:
            v = c["verify_steps"]
            probed = c["spec_accepted"] + c["spec_rejected"]
            print(f"speculative: k={eng.spec_k} "
                  f"draft_depth={eng.ms_draft.effective_depth} "
                  f"verifies={v} drafts={c['draft_steps']} "
                  f"accept_rate="
                  f"{c['spec_accepted'] / max(probed, 1):.2f} "
                  f"rewound={c['spec_rewound']}")
        if (c["failed"] or c["expired"] or c["shed"] or rejected
                or c["degraded_admissions"]):
            print(f"lifecycle: failed={c['failed']} expired={c['expired']} "
                  f"shed={c['shed']} rejected={rejected} "
                  f"degraded={c['degraded_admissions']}")
        if args.trace_out:
            print("trace:", eng.dump_trace(args.trace_out))
        if args.metrics_out:
            if args.metrics_out.endswith(".prom"):
                with open(args.metrics_out, "w") as f:
                    f.write(eng.metrics_text())
            else:
                import json
                with open(args.metrics_out, "w") as f:
                    json.dump(eng.metrics_snapshot(), f, indent=1,
                              sort_keys=True)
            print("metrics:", args.metrics_out)
        print("sample:", res[0][:16].tolist())
        return
    sv = ServeConfig(max_len=args.prompt_len + args.new_tokens + 8,
                     temperature=args.temperature)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    if mesh is not None:
        assert args.temperature == 0.0, "--mesh one-shot is greedy-only"
        # Build the loop ONCE so the warm call compiles the programs the
        # timed call reuses.
        gen = make_sharded_generate(ms, mesh, sv, batch=args.batch,
                                    prompt_len=args.prompt_len)
        out = gen(params, prompts, args.new_tokens)    # warm + compile
        t0 = time.time()
        out = gen(params, prompts, args.new_tokens)
        run = time.time() - t0
        tput = args.batch * args.new_tokens / run
        print(f"arch={cfg.name} eff_depth={ms.effective_depth}/"
              f"{cfg.n_layers} tp={ms.tp} batch={args.batch} "
              f"new={args.new_tokens}")
        print(f"run={run:.3f}s throughput={tput:.1f} tok/s")
        print("sample:", out[0, :16].tolist())
        return
    extras = {}
    if cfg.prefix_len:
        extras["prefix"] = jnp.zeros((args.batch, cfg.prefix_len, cfg.d_model))
    if cfg.enc_layers:
        extras["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model))

    gen = jax.jit(lambda p, x: generate(
        p, x, args.new_tokens, ms=ms, pc=pc, sv=sv,
        prefix=extras.get("prefix"), frames=extras.get("frames")))
    t0 = time.time()
    out = jax.block_until_ready(gen(params, prompts))
    compile_time = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(gen(params, prompts))
    run = time.time() - t0
    tput = args.batch * args.new_tokens / run
    print(f"arch={cfg.name} eff_depth={ms.effective_depth}/{cfg.n_layers} "
          f"batch={args.batch} new={args.new_tokens}")
    print(f"compile={compile_time:.2f}s run={run:.3f}s throughput={tput:.1f} tok/s")
    print("sample:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
