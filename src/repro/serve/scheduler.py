"""Host-side scheduling for the continuous-batching engine.

Pure-Python request/page bookkeeping — nothing here touches a device. The
engine (repro.serve.engine.PagedEngine) asks the scheduler three questions
per step:

  admit()   — which queued requests start NOW (FCFS, gated by free decode
              slots, free cache pages, and a prefill token budget so a
              burst of long prompts cannot starve running decodes)
  finish()  — recycle a finished request's slot + pages
  n_running — is there anything to decode

Pages come from ``PagePool``, a REFCOUNTED free-list allocator over the
paged pair-KV cache (repro.serve.paged_cache). Page 0 is the reserved
garbage page and is never handed out. Refcounts are what make prefix
sharing possible: many slots' block tables (plus the radix tree) can hold
the same page, and it only returns to the free list when the last holder
releases it. The pool keeps monotone allocated/freed counters so the
serving benchmark can assert the generalized accounting invariant
``allocated - freed == live_unique`` at every step (the invariant the
``serve-structural`` CI job gates on) — shares and partial releases move
refcounts, not the counters.

Prefix sharing (repro.serve.prefix_cache) hooks admission: the queue head's
prompt is radix-matched against donated whole pages; matched pages are
linked read-only (share + lock) and only the unmatched suffix needs fresh
pages and prefill compute. Finished requests donate their full prompt pages
back to the tree; under pool pressure admission evicts LRU unlocked leaves
before giving up.

Preemption removes head-of-line blocking: when the head has been blocked
``preempt_after`` consecutive steps, the YOUNGEST running request is
preempted — its generated tokens are parked on the request, its whole
written pages are donated to the tree (so they are reclaimable by the head
but radix-hittable at resume), everything else is released, and it is
re-queued directly BEHIND the blocked head (re-queueing it at position 0
would let it re-steal the pages the preemption just freed).

Request lifecycle: ``QUEUED -> RUNNING -> FINISHED`` is the happy path;
``FAILED`` (fault containment: poisoned prompt, non-finite logits,
corrupted block table), ``CANCELLED`` (client abort), and ``EXPIRED``
(deadline passed / load shed) are the abnormal terminals. All three
abnormal transitions go through one ``_terminalize`` path that releases the
slot and every page WITHOUT donating to the radix tree (a faulted stream's
pages are suspect; a cancelled/expired stream's donation windows are
usually partial anyway), records the typed ``ServeError`` on the request,
and keeps ``allocated - freed == live_unique`` — crash containment must
never corrupt accounting. Misuse of ``PagePool`` itself (double-free,
foreign/garbage page) raises ``PageAccountingError`` BEFORE any state
mutates, so a caught abuse still leaves ``check_balance()`` green.

Overload degradation (``degrade_slots > 0``): the slot range splits into a
MAIN cohort ``[0, n_slots - degrade_slots)`` and a DEGRADED cohort that the
engine runs with a more aggressively paired (higher-Δ, shallower) variant
of the same weights — the paper's retraining-free depth family as a
load-shedding alternative. The scheduler only tracks cohort membership:
a request is pinned to its cohort at FIRST admission (its kv bits are
plan-specific, so preemption resume must land back in the same cohort) and
degraded requests never touch the radix tree (pages written under a
different pairing are not interchangeable with main-cohort pages).

Tensor parallelism never reaches this module: page ids, block tables, slot
indices and refcounts are logical names for DEVICE-side pages whose kv-head
axis may be sharded over a mesh (repro.serve.paged_cache), so one scheduler
instance drives tp=1 and tp>1 engines identically — radix matching,
preemption and the accounting invariant ``allocated - freed ==
live_unique`` all included (the suffix-prefill ctx fold branches per rank
inside the engine's compiled programs; nothing here knows or cares).
"""
from __future__ import annotations

from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.bucketing import bucket_for
from repro.serve.faults import (DeadlineExceededError, InvalidRequestError,
                                LoadShedError, PageAccountingError,
                                ServeError, error_kind)
from repro.serve.paged_cache import GARBAGE_PAGE, pages_needed
from repro.serve.prefix_cache import PrefixCache, RadixNode
from repro.serve.telemetry import (ADMITTED, PREEMPTED, SUBMITTED,
                                   Telemetry)

QUEUED, RUNNING, FINISHED = "queued", "running", "finished"
FAILED, CANCELLED, EXPIRED = "failed", "cancelled", "expired"
#: States a request never leaves; any transition into one releases its
#: slot and every page within the same engine step.
TERMINAL_STATES = frozenset({FINISHED, FAILED, CANCELLED, EXPIRED})

COHORT_MAIN, COHORT_DEGRADED = "main", "degraded"


class PagePool:
    """Refcounted free-list page allocator with monotone accounting.

    ``alloc`` hands out pages at refcount 1; ``share`` adds a reference to
    an already-live page (prefix sharing / tree residency transfer);
    ``free`` drops one reference per page and only a 1 -> 0 transition
    returns the page to the free list and counts as freed. Releasing a
    shared page twice therefore only recycles it once the LAST holder lets
    go — the double-free safety the property tests pin down.

    Misuse raises ``PageAccountingError`` with the WHOLE batch validated
    before any refcount moves: catching the error leaves the pool exactly
    as it was (``check_balance()`` stays green), which is what lets the
    engine contain a buggy release path to the offending request.
    ``fail_next_allocs`` is the deterministic-chaos hook: the next n calls
    to ``alloc`` return None as if the pool were exhausted, exercising the
    caller's rollback path without actually draining the free list.
    """

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "need at least one allocatable page + garbage"
        self.n_pages = n_pages
        # LIFO free list; page 0 (GARBAGE_PAGE) is reserved, never listed.
        self._free: List[int] = list(range(n_pages - 1, GARBAGE_PAGE, -1))
        self._ref = np.zeros(n_pages, np.int32)
        self.allocated_total = 0     # fresh allocations (0 -> 1)
        self.freed_total = 0         # true frees (1 -> 0)
        self.shared_total = 0        # extra references taken over lifetime
        self._fail_next = 0          # chaos: pending injected alloc failures
        self.alloc_faults = 0        # chaos: refusals actually served

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def live(self) -> int:
        """UNIQUE pages currently held (by requests and/or the tree) —
        shared pages count once, whatever their refcount."""
        return (self.n_pages - 1) - len(self._free)

    # Alias making call sites that care about the invariant read naturally.
    live_unique = live

    @property
    def shared(self) -> int:
        """Live pages with more than one holder (refcount > 1) — the
        telemetry gauge for how much of the pool is radix/CoW-shared."""
        return int((self._ref > 1).sum())

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def fail_next_allocs(self, n: int) -> None:
        """Chaos hook: make the next ``n`` ``alloc`` calls return None
        (indistinguishable from exhaustion to the caller)."""
        self._fail_next += n

    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh pages at refcount 1, or None if the pool cannot satisfy
        the request (the caller keeps the request QUEUED — exhaustion
        queues, never OOMs)."""
        if self._fail_next > 0:
            self._fail_next -= 1
            self.alloc_faults += 1
            return None
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.allocated_total += n
        return pages

    def _validate(self, pages: List[int], op: str) -> Counter:
        """Range/liveness check for a whole batch BEFORE mutating anything.
        Multiplicity-aware: freeing ``[p, p]`` against refcount 1 is a
        double-free even though each single free would pass."""
        counts = Counter(pages)
        for p, c in counts.items():
            if not 0 <= p < self.n_pages:
                raise PageAccountingError(
                    f"{op} of out-of-range page id {p} (pool has pages "
                    f"1..{self.n_pages - 1})")
            if p == GARBAGE_PAGE:
                raise PageAccountingError(
                    f"{op} of the reserved garbage page {GARBAGE_PAGE}: it "
                    "is never allocated or refcounted")
            if self._ref[p] < c:
                raise PageAccountingError(
                    f"{op} of page {p} x{c} exceeds its refcount "
                    f"{int(self._ref[p])}"
                    + (" (double-free past zero)" if op == "free" else
                       " (share of a dead page)"))
        return counts

    def share(self, pages: List[int]) -> None:
        """Add one reference per page; every page must already be live."""
        self._validate(pages, "share")
        for p in pages:
            self._ref[p] += 1
        self.shared_total += len(pages)

    def free(self, pages: List[int]) -> None:
        """Drop one reference per page; a last-holder release returns the
        page to the free list and advances ``freed_total``."""
        self._validate(pages, "free")
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                self.freed_total += 1

    def free_rewound(self, pages: List[int]) -> None:
        """Return fully-rewound pages (``paged_cache.rewind_plan``'s free
        list) to the pool. A rewind un-writes this holder's OWN token
        writes; it can never release a reference someone else holds — so
        any page here still refcounted above 1 (radix-shared or CoW-linked)
        is a caller bug, refused before anything mutates. Accepted pages
        go through the ordinary 1 -> 0 free, keeping
        ``allocated - freed == live_unique`` exact through arbitrary
        draft/accept/rewind interleavings (the rewind property test)."""
        self._validate(pages, "free")
        for p in set(pages):
            if self._ref[p] != 1:
                raise PageAccountingError(
                    f"rewind-free of page {p} at refcount "
                    f"{int(self._ref[p])}: rewound pages return to the "
                    "pool only when privately held — a shared page's other "
                    "holders still read it")
        self.free(pages)

    def check_balance(self) -> None:
        assert self.allocated_total - self.freed_total == self.live, (
            self.allocated_total, self.freed_total, self.live)
        assert self._ref[GARBAGE_PAGE] == 0
        live_by_ref = int((self._ref > 0).sum())
        assert live_by_ref == self.live, (live_by_ref, self.live)
        assert all(self._ref[p] == 0 for p in self._free)


@dataclass
class Request:
    """One serving request and its life-cycle state.

    Prefix/preemption extensions: ``pages`` always lists the request's
    pages in POSITION ORDER, the first ``n_shared`` of which are read-only
    links into the radix tree (``shared_path`` holds the matched nodes).
    After a preemption, ``out`` keeps the parked generated tokens and
    admission resumes the request by re-linking/re-computing their kv.

    Lifecycle extensions: ``deadline`` is an ABSOLUTE engine step (-1 =
    none); the engine expires the request at the first step boundary where
    ``step_count >= deadline``. ``cohort`` pins the request to the slot
    cohort of its first admission (main vs degraded-Δ — kv bits are
    plan-specific, see the module docstring). ``error`` carries the typed
    ``ServeError`` for FAILED/CANCELLED/EXPIRED terminals.
    ``donated_pages`` tracks pages whose ownership this request transferred
    to the radix tree, so fault containment can purge exactly its own
    donations without touching foreign donors' pages.
    """

    rid: int
    prompt: np.ndarray            # [prompt_len] int32
    max_new: int
    eos_token: int = -1           # -1: never stop early
    status: str = QUEUED
    out: List[int] = field(default_factory=list)
    slot: int = -1
    pages: List[int] = field(default_factory=list)
    n_shared: int = 0
    shared_path: List[RadixNode] = field(default_factory=list)
    admitted_step: int = -1
    finished_step: int = -1
    preemptions: int = 0
    deadline: int = -1            # absolute engine step; -1 = no deadline
    cohort: Optional[str] = None  # pinned at first admission
    error: Optional[ServeError] = None
    donated_pages: List[int] = field(default_factory=list)

    @property
    def state(self) -> str:
        """Public name for the lifecycle state (== ``status``); terminal iff
        ``state in TERMINAL_STATES``."""
        return self.status

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def pos(self) -> int:
        """Absolute stream position of the NEXT token fed to decode (== the
        position its kv will be written at)."""
        return self.prompt_len + len(self.out) - 1

    @property
    def seq_tokens(self) -> np.ndarray:
        """Tokens whose kv must exist before decode resumes: the prompt
        plus every parked generated token except the last (the last parked
        token is the next decode INPUT; its kv is written by that step)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out[:-1], np.int32)])

    def done(self) -> bool:
        return (len(self.out) >= self.max_new
                or (self.eos_token >= 0 and len(self.out) > 0
                    and self.out[-1] == self.eos_token))


class Scheduler:
    """FCFS admission with token-budget batching, slot recycling, radix
    prefix matching, blocked-head preemption, and typed terminal
    transitions.

    Strict FCFS: the queue head blocks admission when it does not fit
    (head-of-line blocking makes page exhaustion starvation-free: the head
    is guaranteed the next freed pages). With ``preempt_after > 0`` the
    head additionally reclaims pages from the youngest running request
    once it has been blocked that many consecutive admission rounds.

    ``degrade_slots`` reserves the TOP of the slot range as the degraded-Δ
    cohort: ``admit(..., degrade=True)`` may place an unpinned head there
    when the main cohort is full (surge capacity at reduced depth); with
    ``degrade=False`` those slots stay idle rather than silently serving
    degraded quality.
    """

    def __init__(self, *, n_slots: int, pool: PagePool, page_size: int,
                 max_len: int, prefill_token_budget: int = 4096,
                 prefix_cache: Optional[PrefixCache] = None,
                 preempt_after: int = 0, degrade_slots: int = 0,
                 telemetry: Optional[Telemetry] = None,
                 prefill_buckets: Tuple[int, ...] = ()):
        assert 0 <= degrade_slots < n_slots
        self.telemetry = telemetry
        self.pool = pool
        self.page_size = page_size
        self.max_len = max_len
        self.prefill_token_budget = prefill_token_budget
        # When the engine buckets its cold prefills, the admission budget
        # must count what the device will actually COMPUTE — the padded
        # bucket width — or a step could pack more forward rows than the
        # budget promises to bound.
        self.prefill_buckets = tuple(prefill_buckets)
        self.prefix_cache = prefix_cache
        self.preempt_after = preempt_after
        self.n_slots = n_slots
        self.n_main = n_slots - degrade_slots
        self.queue: Deque[Request] = deque()
        # Two free lists, one per cohort; ``free_slots`` keeps its historic
        # name (and meaning: the MAIN cohort) for external callers.
        self.free_slots: List[int] = list(range(self.n_main - 1, -1, -1))
        self.free_slots_deg: List[int] = list(
            range(n_slots - 1, self.n_main - 1, -1))
        self.running: Dict[int, Request] = {}   # slot -> request
        self.head_blocked = 0                   # consecutive blocked rounds
        self.preemptions_total = 0
        self._next_rid = 0

    # ------------------------------------------------------------------
    @property
    def n_running(self) -> int:
        return len(self.running)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    def _free_list_for(self, slot: int) -> List[int]:
        return self.free_slots if slot < self.n_main else self.free_slots_deg

    # -- telemetry plumbing (no-ops without a registry) -----------------
    def _emit(self, r: Request, state: str, step: int, **attrs) -> None:
        if self.telemetry is not None:
            self.telemetry.span_event(r.rid, state, step, **attrs)

    def _count(self, name: str) -> None:
        if self.telemetry is not None:
            self.telemetry.inc(name)

    def submit(self, prompt: np.ndarray, max_new: int, eos_token: int = -1,
               *, deadline: int = -1, step: int = 0) -> Request:
        """Validate + enqueue. Every rejection is an ``InvalidRequestError``
        (a ``ValueError``) raised BEFORE the request enters the queue:
        malformed work must fail at the submit boundary, not deep inside a
        compiled prefill where the whole engine (and every cohabiting
        stream) would go down with it."""
        prompt = np.asarray(prompt)
        if prompt.size and not np.issubdtype(prompt.dtype, np.integer):
            raise InvalidRequestError(
                f"prompt dtype {prompt.dtype} is not an integer type; token "
                "ids must be integral (floats would be truncated silently)")
        prompt = prompt.astype(np.int32).reshape(-1)
        if prompt.shape[0] == 0:
            raise InvalidRequestError(
                "empty prompt: prefill needs at least one position to "
                "sample the first token from")
        if max_new < 1:
            raise InvalidRequestError(
                f"max_new={max_new} must be >= 1 (a request that generates "
                "nothing has no decode step to produce it)")
        total = prompt.shape[0] + max_new
        if total > self.max_len:
            # An over-length request would sit in the queue forever —
            # admit() could never satisfy it.
            raise InvalidRequestError(
                f"request needs {total} positions > max_len={self.max_len}")
        if pages_needed(prompt.shape[0], max_new,
                        self.page_size) > self.pool.n_pages - 1:
            raise InvalidRequestError(
                f"request needs "
                f"{pages_needed(prompt.shape[0], max_new, self.page_size)} "
                f"pages > pool capacity {self.pool.n_pages - 1}: it can "
                "never be admitted")
        r = Request(self._next_rid, prompt, max_new, eos_token,
                    deadline=deadline)
        self._next_rid += 1
        self.queue.append(r)
        self._count("submitted")
        self._emit(r, SUBMITTED, step, prompt_len=r.prompt_len,
                   max_new=max_new, deadline=deadline)
        self._emit(r, QUEUED, step)
        return r

    # -- prefix matching ----------------------------------------------
    def _match_cap(self, r: Request) -> int:
        """Max whole pages the radix match may link for this admission.

        Fresh request: the unmatched prompt suffix must keep >= 2 tokens —
        1 because the engine needs the last prompt position's logits to
        sample the first token, 2 because a 1-row suffix forward lowers to
        matvecs whose reduction grouping differs from the full forward's
        gemm rows, breaking the bit-identity contract (see
        model.attention.output_proj).
        Resumed request: same cap while the match lands inside the prompt;
        a match covering the whole prompt ([prompt_len, written]) skips the
        suffix forward entirely (decode replay only), so any whole written
        page may link.
        """
        ps = self.page_size
        Lp = r.prompt_len
        written = Lp + len(r.out) - 1 if r.out else Lp
        cap_written = written // ps
        cap_prompt = max(Lp - 2, 0) // ps
        if not r.out:
            return cap_prompt
        if cap_written * ps >= Lp:
            return cap_written
        return cap_prompt

    def _match_head(self, r: Request, step: int) -> List[RadixNode]:
        # Only a RESUME may link decode-written pages (a preemption
        # donation holds decode-horizon bits that only reproduce the
        # donor's own interrupted run; a fresh prompt extending into
        # another request's generated range must prefill cold).
        path = self.prefix_cache.match(
            r.seq_tokens, max_pages=self._match_cap(r), step=step,
            include_decode_written=bool(r.out))
        # A match may not land in [prompt_len - 1, prompt_len): a 1-token
        # suffix forward is not bit-safe (see _match_cap). One pop always
        # clears the window (it is narrower than a page).
        while path and (r.prompt_len - 2
                        < len(path) * self.page_size < r.prompt_len):
            path.pop()
        return path

    def _try_admit_head(self, r: Request, path: List[RadixNode],
                        step: int, cohort: str) -> bool:
        """Allocate + link the matched queue head; False when blocked."""
        free = (self.free_slots if cohort == COHORT_MAIN
                else self.free_slots_deg)
        if not free:
            return False
        need = pages_needed(r.prompt_len, r.max_new, self.page_size) \
            - len(path)
        pages = self.pool.alloc(need)
        if pages is None and self.prefix_cache is not None:
            protect = {id(n) for n in path}
            self.prefix_cache.evict(need - self.pool.n_free, self.pool,
                                    protect=protect)
            pages = self.pool.alloc(need)
        if pages is None:
            return False
        if path:
            self.prefix_cache.lock_path(path, self.pool, step=step)
        self.queue.popleft()
        r.shared_path = path
        r.n_shared = len(path)
        r.pages = [n.page for n in path] + pages
        r.slot = free.pop()
        r.status = RUNNING
        r.cohort = cohort
        r.admitted_step = step
        self.running[r.slot] = r
        self._emit(r, ADMITTED, step, slot=r.slot, cohort=cohort,
                   n_shared=r.n_shared, resumed=bool(r.out))
        return True

    def admit(self, step: int = -1, *, count_blocked: bool = True,
              degrade: bool = False) -> List[Request]:
        """Admit queue-head requests while a slot, pages, and prefill-token
        budget remain. The FIRST admission of a round ignores the token
        budget so a prompt longer than the budget cannot livelock. A
        blocked head bumps ``head_blocked`` (the preemption trigger);
        any admission resets it.

        ``degrade``: the engine's SLO-pressure signal. An UNPINNED head may
        then take a degraded-cohort slot when the main cohort is full;
        pinned requests (preemption resumes) always re-enter their own
        cohort. Degraded admissions skip the radix tree entirely: pages
        written under the aggressive pairing hold different bits than
        main-cohort pages for the same tokens.
        """
        admitted: List[Request] = []
        budget = self.prefill_token_budget
        while self.queue and (self.free_slots or self.free_slots_deg):
            r = self.queue[0]
            cohort = r.cohort
            if cohort is None:
                if self.free_slots:
                    cohort = COHORT_MAIN
                elif degrade and self.free_slots_deg:
                    cohort = COHORT_DEGRADED
                else:
                    cohort = COHORT_MAIN   # blocked: wait for a main slot
            use_tree = (self.prefix_cache is not None
                        and cohort == COHORT_MAIN)
            path = self._match_head(r, step) if use_tree else []
            # Cost this step = tokens actually recomputed (suffix forward
            # rows + decode replay steps), not the full prompt. An
            # admission headed for the bucketed path — cold OR radix-hit:
            # hit suffixes ride the same ladder — costs its PADDED bucket
            # width plus any replay tail, mirroring the engine's bucket
            # eligibility (ladder on, suffix tokens remain, rung holds
            # the suffix).
            Ls = r.prompt_len - len(path) * self.page_size
            cost = len(r.seq_tokens) - len(path) * self.page_size
            if self.prefill_buckets and Ls > 0:
                b = bucket_for(Ls, self.prefill_buckets)
                if b is not None:
                    cost = b + (len(r.seq_tokens) - r.prompt_len)
            if admitted and cost > budget:
                break  # prefill/decode interleaving: cap this step's cost
            if not self._try_admit_head(r, path, step, cohort):
                break  # slot/page exhaustion: r stays queued, retried later
            budget -= cost
            admitted.append(r)
        if admitted:
            self.head_blocked = 0
        elif self.queue and count_blocked:
            self.head_blocked += 1
        return admitted

    def donate_prefilled(self, r: Request, step: int = -1) -> None:
        """Donate a request's whole PROMPT pages the moment its prefill
        lands (not at finish): concurrent same-prefix requests admitted a
        step later can already share them. The request keeps using the
        pages through the tree protocol — ownership of each newly created
        node transfers to the tree and the request re-pins it (lock +
        share), exactly the state a radix HIT would have produced, so
        finish/preempt release uniformly. Pages whose chunk already has an
        incumbent node under a different page id stay private (first donor
        wins; the duplicate is freed at finish)."""
        if self.prefix_cache is None or r.cohort == COHORT_DEGRADED:
            return
        n_whole = r.prompt_len // self.page_size
        if n_whole <= r.n_shared:
            return
        transferred = self.prefix_cache.insert(
            r.prompt[:n_whole * self.page_size], r.pages[:n_whole],
            step=step, prompt_len=r.prompt_len)
        r.donated_pages.extend(transferred)
        # include_decode_written: the re-match only confirms OUR pages (the
        # ext loop drops anything foreign), so reach past flagged nodes.
        path = self.prefix_cache.match(
            r.prompt, max_pages=n_whole, step=step,
            include_decode_written=True)
        ext = []
        for i in range(r.n_shared, len(path)):
            if path[i].page != r.pages[i]:
                break   # incumbent from another donor: our copy stays private
            ext.append(path[i])
        if ext:
            self.prefix_cache.lock_path(ext, self.pool, step=step)
            r.shared_path = r.shared_path + ext
            r.n_shared += len(ext)

    # -- release paths -------------------------------------------------
    def _release_pages(self, r: Request, *, donate_upto_tokens: int,
                       step: int) -> None:
        """Return a leaving request's pages: donate the whole-page chunks
        of its first ``donate_upto_tokens`` tokens to the radix tree
        (reference transfer for new nodes), release everything else.
        Shared-path pins are always released (the tree keeps its own
        reference on those pages)."""
        ps = self.page_size
        private = r.pages[r.n_shared:]
        transferred: List[int] = []
        if self.prefix_cache is not None and donate_upto_tokens >= ps:
            donate_pages = r.pages[:donate_upto_tokens // ps]
            transferred = self.prefix_cache.insert(
                r.seq_tokens[:donate_upto_tokens], donate_pages, step=step,
                prompt_len=r.prompt_len)
            r.donated_pages.extend(transferred)
        if r.shared_path:
            self.prefix_cache.release_path(r.shared_path, self.pool)
        keep = set(transferred)
        leftover = [p for p in private if p not in keep]
        if leftover:
            self.pool.free(leftover)
        r.pages = []
        r.n_shared = 0
        r.shared_path = []

    def finish(self, r: Request, step: int = -1) -> None:
        """Recycle the request's slot and pages (EOS / max-len reached);
        its full prompt pages are donated to the prefix tree."""
        assert r.status == RUNNING
        r.status = FINISHED
        r.finished_step = step
        self._count("finished")
        self._emit(r, FINISHED, step, n_out=len(r.out))
        del self.running[r.slot]
        self._free_list_for(r.slot).append(r.slot)
        # Donate only pages fully covered by the PROMPT (pages containing
        # generated-token kv are per-request: decode wrote them with the
        # full-horizon reduction, so their bits are not what a cold prefill
        # of a matching prompt would produce). Degraded-cohort pages never
        # enter the tree (plan-specific bits).
        donate = ((r.prompt_len // self.page_size) * self.page_size
                  if r.cohort != COHORT_DEGRADED else 0)
        self._release_pages(r, donate_upto_tokens=donate, step=step)
        r.slot = -1

    # -- abnormal terminals --------------------------------------------
    def _terminalize(self, r: Request, status: str, step: int,
                     error: Optional[ServeError]) -> None:
        """One path for FAILED/CANCELLED/EXPIRED: leave queue or running
        set, release the slot and EVERY page (no radix donation — partial
        or suspect streams do not seed the tree), record the typed error.
        Runs entirely host-side within the current engine step, which is
        what makes 'terminal transition releases everything within one
        step' an invariant rather than an eventual property."""
        if r.status in TERMINAL_STATES:
            raise ServeError(
                f"rid={r.rid} is already terminal ({r.status}); terminal "
                "states are final")
        if r.status == QUEUED:
            self.queue.remove(r)
        else:   # RUNNING
            del self.running[r.slot]
            self._free_list_for(r.slot).append(r.slot)
            self._release_pages(r, donate_upto_tokens=0, step=step)
            r.slot = -1
        r.status = status
        r.error = error
        r.finished_step = step
        # One increment site per terminal event. A load-shed victim is
        # EXPIRED with a LoadShedError and counts under "shed", never
        # "expired" — shedding is queue policy, not a deadline overrun.
        shed = status == EXPIRED and isinstance(error, LoadShedError)
        self._count("shed" if shed else status)
        self._emit(r, status, step, error=error_kind(error), shed=shed)

    def fail(self, r: Request, step: int,
             error: Optional[ServeError] = None) -> None:
        """Fault containment: the request is FAILED with ``error``."""
        self._terminalize(r, FAILED, step, error)

    def cancel(self, r: Request, step: int,
               error: Optional[ServeError] = None) -> None:
        self._terminalize(r, CANCELLED, step, error)

    def expire(self, r: Request, step: int,
               error: Optional[ServeError] = None) -> None:
        self._terminalize(r, EXPIRED, step, error or DeadlineExceededError(
            f"rid={r.rid}: deadline {r.deadline} passed at step {step}"))

    # -- preemption ----------------------------------------------------
    def should_preempt(self) -> bool:
        return (self.preempt_after > 0 and self.running
                and self.head_blocked >= self.preempt_after)

    def preempt_youngest(self, step: int = -1):
        """Preempt the youngest running request: park its generated tokens
        on the request, donate every WHOLE written page (prompt and
        generated — at resume the radix hit makes those positions free to
        recover, and decode replay is bit-exact against its own pages),
        release the rest, and re-queue it directly behind the blocked head.
        Returns ``(victim, freed_slot)`` so the engine can clear the
        slot's device-side rows. Degraded-cohort victims donate nothing
        (their pages hold aggressive-plan bits) and stay pinned to the
        degraded cohort for resume."""
        assert self.running
        victim = max(self.running.values(),
                     key=lambda r: (r.admitted_step, r.rid))
        slot = victim.slot
        del self.running[victim.slot]
        self._free_list_for(victim.slot).append(victim.slot)
        self._count("preempted")
        self._emit(victim, PREEMPTED, step, slot=victim.slot,
                   n_out=len(victim.out))
        self._emit(victim, QUEUED, step)
        victim.slot = -1
        victim.status = QUEUED
        victim.preemptions += 1
        self.preemptions_total += 1
        written = victim.prompt_len + len(victim.out) - 1
        donate = ((written // self.page_size) * self.page_size
                  if victim.cohort != COHORT_DEGRADED else 0)
        self._release_pages(victim, donate_upto_tokens=donate, step=step)
        if self.queue:
            self.queue.insert(1, victim)
        else:
            self.queue.appendleft(victim)
        self.head_blocked = 0
        return victim, slot
