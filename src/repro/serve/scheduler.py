"""Host-side scheduling for the continuous-batching engine.

Pure-Python request/page bookkeeping — nothing here touches a device. The
engine (repro.serve.engine.PagedEngine) asks the scheduler three questions
per step:

  admit()   — which queued requests start NOW (FCFS, gated by free decode
              slots, free cache pages, and a prefill token budget so a
              burst of long prompts cannot starve running decodes)
  finish()  — recycle a finished request's slot + pages
  n_running — is there anything to decode

Pages come from ``PagePool``, a free-list allocator over the paged pair-KV
cache (repro.serve.paged_cache). Page 0 is the reserved garbage page and is
never handed out. The pool keeps monotone allocated/freed counters so the
serving benchmark can assert the accounting balance
``allocated - freed == live`` at every step (the invariant the
``serve-structural`` CI job gates on).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.serve.paged_cache import GARBAGE_PAGE, pages_needed

QUEUED, RUNNING, FINISHED = "queued", "running", "finished"


class PagePool:
    """Free-list page allocator with monotone accounting counters."""

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "need at least one allocatable page + garbage"
        self.n_pages = n_pages
        # LIFO free list; page 0 (GARBAGE_PAGE) is reserved, never listed.
        self._free: List[int] = list(range(n_pages - 1, GARBAGE_PAGE, -1))
        self.allocated_total = 0
        self.freed_total = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def live(self) -> int:
        """Pages currently held by running requests."""
        return (self.n_pages - 1) - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages, or None if the pool cannot satisfy the request (the
        caller keeps the request QUEUED — exhaustion queues, never OOMs)."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.allocated_total += n
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            assert p != GARBAGE_PAGE, "garbage page is never allocated"
            self._free.append(p)
        self.freed_total += len(pages)

    def check_balance(self) -> None:
        assert self.allocated_total - self.freed_total == self.live, (
            self.allocated_total, self.freed_total, self.live)


@dataclass
class Request:
    """One serving request and its life-cycle state."""

    rid: int
    prompt: np.ndarray            # [prompt_len] int32
    max_new: int
    eos_token: int = -1           # -1: never stop early
    status: str = QUEUED
    out: List[int] = field(default_factory=list)
    slot: int = -1
    pages: List[int] = field(default_factory=list)
    admitted_step: int = -1
    finished_step: int = -1

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def pos(self) -> int:
        """Absolute stream position of the NEXT token fed to decode (== the
        position its kv will be written at)."""
        return self.prompt_len + len(self.out) - 1

    def done(self) -> bool:
        return (len(self.out) >= self.max_new
                or (self.eos_token >= 0 and len(self.out) > 0
                    and self.out[-1] == self.eos_token))


class Scheduler:
    """FCFS admission with token-budget batching and slot recycling.

    Strict FCFS: the queue head blocks admission when it does not fit
    (head-of-line blocking is intentional — it makes page exhaustion
    starvation-free: the head is guaranteed the next freed pages).
    """

    def __init__(self, *, n_slots: int, pool: PagePool, page_size: int,
                 max_len: int, prefill_token_budget: int = 4096):
        self.pool = pool
        self.page_size = page_size
        self.max_len = max_len
        self.prefill_token_budget = prefill_token_budget
        self.queue: Deque[Request] = deque()
        self.free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self.running: Dict[int, Request] = {}   # slot -> request
        self._next_rid = 0

    # ------------------------------------------------------------------
    @property
    def n_running(self) -> int:
        return len(self.running)

    @property
    def n_queued(self) -> int:
        return len(self.queue)

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_token: int = -1) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert max_new >= 1
        total = prompt.shape[0] + max_new
        if total > self.max_len:
            # ValueError (not assert): an over-length request would sit in
            # the queue forever — admit() could never satisfy it.
            raise ValueError(
                f"request needs {total} positions > max_len={self.max_len}")
        if pages_needed(prompt.shape[0], max_new,
                        self.page_size) > self.pool.n_pages - 1:
            raise ValueError("request can never fit the page pool")
        r = Request(self._next_rid, prompt, max_new, eos_token)
        self._next_rid += 1
        self.queue.append(r)
        return r

    def admit(self, step: int = -1) -> List[Request]:
        """Admit queue-head requests while a slot, pages, and prefill-token
        budget remain. The FIRST admission of a step ignores the token
        budget so a prompt longer than the budget cannot livelock."""
        admitted: List[Request] = []
        budget = self.prefill_token_budget
        while self.queue and self.free_slots:
            r = self.queue[0]
            if admitted and r.prompt_len > budget:
                break  # prefill/decode interleaving: cap this step's prefill
            pages = self.pool.alloc(
                pages_needed(r.prompt_len, r.max_new, self.page_size))
            if pages is None:
                break  # page exhaustion: r stays queued, retried next step
            self.queue.popleft()
            r.pages = pages
            r.slot = self.free_slots.pop()
            r.status = RUNNING
            r.admitted_step = step
            budget -= r.prompt_len
            self.running[r.slot] = r
            admitted.append(r)
        return admitted

    def finish(self, r: Request, step: int = -1) -> None:
        """Recycle the request's slot and pages (EOS / max-len reached)."""
        assert r.status == RUNNING
        r.status = FINISHED
        r.finished_step = step
        del self.running[r.slot]
        self.free_slots.append(r.slot)
        self.pool.free(r.pages)
        r.pages = []
