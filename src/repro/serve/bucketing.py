"""Bucketed, batched prefill: the ladder, the packing math, and the
attention impl that makes right-padding bit-safe.

Why buckets: the exact-length prefill compiles one program per DISTINCT
prompt length and runs batch-1, so at production arrival rates TTFT is
dominated by compile stalls plus a serial launch per admission. A bucket
ladder right-pads each admitted prompt to the smallest bucket that holds
it and prefills SEVERAL requests in ONE ``[n_req, bucket_len]`` launch —
compile count is bounded by the ladder length and the launch count by the
number of bucket groups, not by arrivals.

Why right-padding is bit-safe here (and only here)
--------------------------------------------------
The bit-identity contract says an engine stream must equal one-shot
``generate()`` bit-for-bit under greedy sampling. A padded forward changes
the KEY-axis extent of every attention reduction, and XLA's dense softmax
re-tiles with it — the low bits of row p's output depend on the TOTAL
padded length, not just on positions [0, p]. The fix is pinned-tile
chunked attention (``model.attention._chunked_core`` with a FIXED kv tile
width, impl string ``"chunked:<kb>"``): the kv axis is reduced tile by
tile in a fori_loop, a fully-masked tile is an exact bitwise no-op
(``corr = exp(m - m) = 1``, ``p = 0``), and a partially-masked tile
reduces over the same ``kb`` lanes whatever the padded total is. Row p's
output then depends ONLY on tiles covering [0, p] — padding on the right
cannot move a single bit, and the batch dimension is bit-transparent by
row independence. ``PREFILL_ATTN_IMPL`` names that impl; every prefill
consumer (bucketed, exact, suffix, one-shot generate) must run it so the
engine's streams and its ``generate()`` reference stay bitwise equal.

Pad positions DO compute junk kv (from pad token 0) which lands in the
tail of the request's last real page — that is safe for the same reason:
masked lanes get score ``-inf`` and exactly-zero weight in f32, decode
overwrites each tail position before it is ever unmasked, and page
donation/preemption only ever moves WHOLE fully-written pages, never a
junk tail (``paged_cache.scatter_prefill_rows`` masks pad ROWS; the
in-page tail is handled by the attention mask).
"""
from __future__ import annotations

from typing import Optional, Tuple

#: The prefill attention impl: flash-style chunked softmax with a PINNED
#: 16-wide kv tile (see module docstring). 16 divides every page size the
#: repo serves and keeps the fori_loop short at smoke scales.
PREFILL_ATTN_IMPL = "chunked:16"


def default_buckets(max_len: int, page_size: int) -> Tuple[int, ...]:
    """The auto ladder: powers-of-two multiples of ``page_size`` with the
    last rung capped at ``max_len`` (every bucket is a whole number of
    pages; the cap keeps the widest program at the engine's horizon).

    >>> default_buckets(48, 8)
    (8, 16, 32, 48)
    >>> default_buckets(32, 8)
    (8, 16, 32)
    """
    out = []
    b = page_size
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


def bucket_for(length: int, buckets: Tuple[int, ...]) -> Optional[int]:
    """Smallest bucket holding ``length``, or None when the ladder tops
    out below it (the caller falls back to the exact-length program)."""
    for b in buckets:
        if length <= b:
            return b
    return None


def rows_for_bucket(bucket: int, cohort_slots: int, budget: int) -> int:
    """Row count of the bucket's compiled program: as many requests as
    the prefill token budget allows at this width, capped by the cohort's
    slot count (more rows than slots can never launch together), floored
    at 1 (a bucket wider than the budget still runs — the scheduler's
    first-admission-ignores-budget rule guarantees such prompts admit).
    A pure function of (bucket, static config), so compile count stays
    <= len(buckets) per cohort."""
    return max(1, min(cohort_slots, budget // bucket))


def validate_buckets(buckets: Tuple[int, ...], *, page_size: int,
                     max_len: int) -> None:
    """Actionable ValueErrors for an explicit ladder (the auto ladder is
    correct by construction)."""
    if tuple(sorted(set(buckets))) != tuple(buckets):
        raise ValueError(
            f"prefill_buckets={buckets} must be strictly increasing: the "
            "packer picks the FIRST bucket that holds the prompt")
    for b in buckets:
        if b <= 0 or b % page_size != 0:
            raise ValueError(
                f"prefill bucket {b} is not a positive multiple of "
                f"page_size={page_size}: the page scatter writes whole "
                "pages, so a partial-page bucket could never land its kv")
        if b > max_len:
            raise ValueError(
                f"prefill bucket {b} exceeds max_len={max_len}: no "
                "admissible prompt can need it (requests longer than "
                "max_len are rejected at submit)")
