"""Radix prefix cache over whole-page token chunks.

Deployment traffic is dominated by shared prompt prefixes — system prompts,
few-shot headers, multi-turn histories. The paged pair-KV layout from PR 2
already lets many decode slots point at one page through their block tables;
this module adds the host-side index that makes that sharing happen: a radix
tree whose node is ONE FULL PAGE of tokens (``page_size`` ids), carrying the
page id that holds the kv for those positions. Because a page's kv depend on
the ENTIRE token path from position 0, the tree key is the root-to-node
chunk path, never the chunk alone — two prompts share a node only when they
agree on every token before it. The stacked ``[2, n_pages, ...]`` pair
layout means one node (one page id) covers BOTH halves of every fused LP
pair at once.

Ownership protocol (with ``scheduler.PagePool`` refcounts):

  * a RESIDENT node holds exactly one pool reference on its page — the
    reference the donating request transferred on ``insert`` (no pool call
    is made at donation; ownership moves, counters stay balanced);
  * every RUNNING request that matched through a node adds its own pool
    reference (``PagePool.share``) and a node ``lock``; both are dropped
    when the request finishes or is preempted;
  * eviction (LRU over ``last_used``) only ever removes UNLOCKED LEAVES —
    their pool refcount is exactly the tree's 1, so freeing returns the
    page to the free list. Interior nodes become leaves as their children
    evict, so pressure peels the tree from the deepest, coldest chunks
    backwards.

Copy-on-write needs no device-side machinery: a request only ever links
WHOLE matched pages read-only and writes from its first unmatched position
onward, which by construction lives in a freshly allocated private page
(``Scheduler.admit`` caps the match so the written tail is never shared).

Speculative decoding (``PagedServeConfig.spec_k``) composes with sharing:
the tree indexes MAIN-tree kv only (draft bits are plan-specific and never
donated), and the drafter re-prefills its own tree over the shared page
ids — an idempotent write, since the same tokens at the same positions
produce the same draft bits whoever computes them. Rewinds never touch
shared pages either: rejected drafts live strictly past the prompt, and
``paged_cache.rewind_plan`` refuses any horizon inside the shared prefix.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["RadixNode", "PrefixCache"]


@dataclass
class RadixNode:
    """One full page of tokens along a prompt path. ``page`` holds the kv
    for this chunk's positions; ``lock`` counts running requests matched
    through this node (evictable only at 0); ``last_used`` is the engine
    step of the last match/insert touching the node (LRU key).

    ``decode_written``: the page contains kv the DECODE program wrote
    (generated-range positions of a preempted request). Decode reduces
    over the full max_len horizon while prefill reduces over the prompt
    length, so these bits are not what a cold prefill of the same token
    path would produce — fresh matches must stop before such a node
    (only the donor's own resume, which originally produced those exact
    bits, may link it)."""
    chunk: Tuple[int, ...]
    page: int
    parent: Optional["RadixNode"] = None
    children: Dict[Tuple[int, ...], "RadixNode"] = field(default_factory=dict)
    lock: int = 0
    last_used: int = -1
    decode_written: bool = False

    @property
    def is_leaf(self) -> bool:
        return not self.children


class PrefixCache:
    """Radix tree of whole-page prompt chunks -> resident cache pages."""

    def __init__(self, page_size: int, *, telemetry=None):
        assert page_size >= 1
        self.page_size = page_size
        self.root = RadixNode(chunk=(), page=-1)   # sentinel, never evicted
        self.n_nodes = 0
        # Monotone lifetime counters (admission-confirmed hit stats live on
        # the ENGINE's counters dict — match() also runs speculatively, so
        # counting hits here would inflate them). ``telemetry`` (a
        # repro.serve.telemetry.Telemetry, kept duck-typed to avoid an
        # import cycle) mirrors them as radix_inserted_pages /
        # radix_evicted_pages so one snapshot carries the tree's churn.
        self.inserted_pages_total = 0
        self.evicted_pages_total = 0
        self._tel = telemetry

    def _inc(self, name: str) -> None:
        if self._tel is not None:
            self._tel.inc(name)

    # -- matching ------------------------------------------------------
    def _chunks(self, tokens: np.ndarray):
        ps = self.page_size
        for i in range(len(tokens) // ps):
            yield tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])

    def match(self, tokens: np.ndarray, *, max_pages: int, step: int,
              include_decode_written: bool = False) -> List[RadixNode]:
        """Longest whole-page prefix of ``tokens`` present in the tree,
        capped at ``max_pages`` nodes. Touches LRU stamps; does NOT lock —
        the caller locks via ``lock_path`` once admission is certain.
        Fresh matches (the default) stop before a ``decode_written`` node:
        its bits are only exact for the preempted donor's own resume
        (``include_decode_written=True``)."""
        path: List[RadixNode] = []
        node = self.root
        for chunk in self._chunks(tokens):
            if len(path) >= max_pages:
                break
            child = node.children.get(chunk)
            if child is None:
                break
            if child.decode_written and not include_decode_written:
                break
            child.last_used = step
            path.append(child)
            node = child
        return path

    def lock_path(self, path: List[RadixNode], pool, *, step: int) -> None:
        """Pin a matched path for a running request: one node lock + one
        pool reference per page (released by ``release_path``)."""
        for node in path:
            node.lock += 1
            node.last_used = step
        if path:
            pool.share([n.page for n in path])

    def release_path(self, path: List[RadixNode], pool) -> None:
        """Drop a running request's pins (finish/preempt). The pool
        references are returned via ``pool.free`` — the tree's own
        reference keeps each page resident until eviction."""
        for node in path:
            assert node.lock > 0
            node.lock -= 1
        if path:
            pool.free([n.page for n in path])

    # -- donation ------------------------------------------------------
    def insert(self, tokens: np.ndarray, pages: List[int], *,
               step: int, prompt_len: Optional[int] = None) -> List[int]:
        """Donate a finished/preempted request's whole-page chunks.

        ``pages[i]`` holds the kv of chunk i of ``tokens`` (only
        ``len(tokens) // page_size`` leading pages are considered).
        ``prompt_len``: chunks extending past it contain decode-written kv
        and are flagged ``decode_written`` (resume-only matches); None
        means every donated chunk is prefill-written. Returns
        the page ids whose POOL REFERENCE TRANSFERRED to the tree (newly
        created nodes) — the caller must NOT free those; every other page
        stays the caller's to release. A chunk already present keeps its
        incumbent page (first donor wins); if the incumbent differs from
        the offered page the walk STOPS — donating deeper nodes under a
        foreign prefix would strand ownership of pages the donor's own
        release path still accounts for (the donor keeps its duplicate
        pages private and frees them normally)."""
        node = self.root
        transferred: List[int] = []
        for i, chunk in enumerate(self._chunks(tokens)):
            if i >= len(pages):
                break
            child = node.children.get(chunk)
            if child is None:
                dw = (prompt_len is not None
                      and (i + 1) * self.page_size > prompt_len)
                child = RadixNode(chunk=chunk, page=pages[i], parent=node,
                                  last_used=step, decode_written=dw)
                node.children[chunk] = child
                self.n_nodes += 1
                self.inserted_pages_total += 1
                self._inc("radix_inserted_pages")
                transferred.append(pages[i])
            elif child.page != pages[i]:
                break
            else:
                child.last_used = step
            node = child
        return transferred

    # -- eviction ------------------------------------------------------
    def evictable_leaves(self) -> List[RadixNode]:
        out: List[RadixNode] = []
        stack = [self.root]
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            if n is not self.root and n.is_leaf and n.lock == 0:
                out.append(n)
        return out

    def evict(self, n_pages: int, pool, *,
              protect: Optional[set] = None) -> int:
        """Free up to ``n_pages`` pool pages by evicting LRU unlocked
        leaves (never a node in ``protect`` — the path a request is about
        to lock). Evicting a leaf can expose its parent as the next
        candidate, so eviction proceeds in rounds until satisfied or no
        candidate remains. Returns the number of pages freed."""
        protect = protect or set()
        freed = 0
        while freed < n_pages:
            cands = [n for n in self.evictable_leaves()
                     if id(n) not in protect]
            if not cands:
                break
            cands.sort(key=lambda n: n.last_used)
            for n in cands:
                pool.free([n.page])
                del n.parent.children[n.chunk]
                self.n_nodes -= 1
                self.evicted_pages_total += 1
                self._inc("radix_evicted_pages")
                freed += 1
                if freed >= n_pages:
                    break
        return freed

    def purge_pages(self, pages: List[int], pool) -> int:
        """Fault containment: evict every resident node holding one of
        ``pages``, together with its WHOLE subtree — a child's kv extend
        the purged path, so once a page is suspect everything donated
        beyond it is too. Today's engine only reaches this defensively (a
        request that FAILS after donating passed the prefill finite guard
        first, so its donated bits are provably finite); it exists so any
        future write path that can dirty a donated page has a containment
        primitive that keeps pool accounting balanced. Subtrees containing
        a LOCKED node are skipped entirely (running requests hold real
        references into them; they finish or fail on their own terms).
        Returns the number of pages freed back to the pool."""
        suspects = set(pages)
        if not suspects:
            return 0

        def subtree_locked(n: RadixNode) -> bool:
            stack = [n]
            while stack:
                m = stack.pop()
                if m.lock > 0:
                    return True
                stack.extend(m.children.values())
            return False

        # Top-most suspect nodes only: purging one drops its whole subtree.
        roots: List[RadixNode] = []
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.page in suspects:
                roots.append(n)
            else:
                stack.extend(n.children.values())
        freed = 0
        for n in roots:
            if subtree_locked(n):
                continue
            del n.parent.children[n.chunk]
            drop = [n]
            while drop:
                m = drop.pop()
                drop.extend(m.children.values())
                pool.free([m.page])
                self.n_nodes -= 1
                self.evicted_pages_total += 1
                self._inc("radix_evicted_pages")
                freed += 1
        return freed

    @property
    def resident_pages(self) -> int:
        return self.n_nodes

    def check_locks(self) -> None:
        """Chain-pin invariant: requests lock whole root-to-node paths, so
        a child can never be locked more often than its parent (a request
        ending mid-path leaves the parent's lock HIGHER, never lower)."""
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            for c in n.children.values():
                assert c.lock <= n.lock, (c.chunk, c.lock, n.lock)
                stack.append(c)
