"""Serve telemetry: step-denominated counters/gauges/histograms + request
lifecycle spans for the paged LP engine.

Why a registry instead of ad-hoc dicts: before this module the engine kept
``counters`` (monotone totals), per-step ``stats`` dicts threaded by hand,
``fault_log``/``fault_counts``, and the serving benchmark recomputed
TTFT/latency percentiles host-side from its own timestamp dicts — four
bookkeeping paths for one event stream. ``Telemetry`` is the single path:
every engine event increments exactly once here, per-step ``stats`` are
counter DELTAS over the step, and every exporter (Prometheus text, JSON
snapshot, Chrome/Perfetto trace via repro.serve.trace) reads the same
records.

Metric semantics — step clock vs wall clock
-------------------------------------------
The primary clock is the ENGINE STEP COUNTER (``PagedEngine.step_count``):
every counter increment, gauge sample, histogram observation, span
transition, and fault record is stamped with the step it happened in. The
step clock is deterministic — two runs of the same ``(seed, workload,
FaultPlan)`` produce byte-identical step-denominated streams — which is
what makes traces replayable EVIDENCE under the chaos schedule rather than
noise. Wall-clock time is an optional ANNOTATION riding alongside
(``SpanEvent.wall``, ``Telemetry.step_wall``): it never keys anything, it
is only used to derive human-facing latency milliseconds, and every field
carrying it has a name starting with ``wall`` so ``repro.serve.trace.
strip_wall`` can drop all of it when comparing streams for determinism.

What counts as a HIT TOKEN: a prompt token of a FRESH admission whose kv
was served from a radix-shared page instead of the prefill forward
(``hit_tokens``). A preemption resume re-linking its own donated pages is
real work avoided too, but a different phenomenon — it is tracked as
``resume_hit_tokens`` so ``hit_rate = hit_tokens / (hit_tokens +
prefill_tokens)`` stays "prompt prefill work avoided by sharing".

Request lifecycle span model
----------------------------
One ``RequestSpan`` per rid, an append-only list of state transitions
validated against the machine::

    SUBMITTED -> QUEUED -> ADMITTED -> [PREFILL] -> [REPLAY] -> DECODE
         DECODE -> PREEMPTED -> QUEUED -> ...      (any number of cycles)
         {QUEUED, DECODE, ...} -> FINISHED | FAILED | CANCELLED | EXPIRED

Terminal states are absorbing (any further transition raises), DECODE is
unreachable before ADMITTED, and a PREEMPTED span must re-QUEUE before
re-admission. Annotations ride on the transitions: ``PREFILL`` carries
``kind="full"|"suffix"`` and ``hit_tokens``; ``ADMITTED`` carries ``slot``
and ``cohort`` (the degrade annotation); terminal transitions carry the
``ServeError`` class name for the PR-5 fault taxonomy (``LoadShedError``
== shed). Illegal transitions raise ``SpanStateError`` — an
``AssertionError`` on purpose: the ENGINE drives the span, so an illegal
transition is engine corruption, not a per-request fault.

Zero-device-launch contract: nothing in this module (or in what the engine
records into it) touches jax — it is pure host bookkeeping, appended
outside the compiled programs. The serve-structural CI gate pins this:
telemetry-on launch counts equal telemetry-off, telemetry-on greedy
streams are bit-identical to telemetry-off, and same-seed chaos runs
produce byte-identical wall-stripped traces. ``Telemetry(enabled=False)``
additionally drops span/gauge-series/wall retention (counters, compile
events, histograms and the fault log stay live — the engine's own
``stats``/replay machinery reads them), so long soaks can run without
unbounded history growth.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "SUBMITTED", "QUEUED", "ADMITTED", "PREFILL", "REPLAY", "DECODE",
    "PREEMPTED", "FINISHED", "FAILED", "CANCELLED", "EXPIRED",
    "SPAN_TERMINAL", "SPAN_TRANSITIONS", "DEFAULT_BUCKETS",
    "SpanStateError", "SpanEvent", "RequestSpan", "Histogram", "Telemetry",
    "ProgramCache",
]

# Span states. The terminal four reuse the scheduler's status strings so a
# span's last state string == Request.state for terminal requests.
SUBMITTED = "submitted"
QUEUED = "queued"
ADMITTED = "admitted"
PREFILL = "prefill"
REPLAY = "replay"
DECODE = "decode"
PREEMPTED = "preempted"
FINISHED = "finished"
FAILED = "failed"
CANCELLED = "cancelled"
EXPIRED = "expired"

SPAN_TERMINAL = frozenset({FINISHED, FAILED, CANCELLED, EXPIRED})

#: Legal transitions. PREFILL may terminate directly (max_new == 1 requests
#: finish on the prefill-sampled token without a decode step); REPLAY and
#: PREFILL may FAIL (finite-guard trips); a full-radix-hit resume may go
#: ADMITTED -> REPLAY or even ADMITTED -> DECODE with no recompute at all.
SPAN_TRANSITIONS: Dict[str, frozenset] = {
    SUBMITTED: frozenset({QUEUED}),
    QUEUED: frozenset({ADMITTED, CANCELLED, EXPIRED}),
    ADMITTED: frozenset({PREFILL, REPLAY, DECODE, FAILED}),
    PREFILL: frozenset({REPLAY, DECODE, FINISHED, FAILED}),
    REPLAY: frozenset({DECODE, FAILED}),
    DECODE: frozenset({PREEMPTED, FINISHED, FAILED, CANCELLED, EXPIRED}),
    PREEMPTED: frozenset({QUEUED}),
    FINISHED: frozenset(),
    FAILED: frozenset(),
    CANCELLED: frozenset(),
    EXPIRED: frozenset(),
}


class SpanStateError(AssertionError):
    """An illegal span transition — engine-integrity corruption, not a
    per-request fault (the engine, not the client, drives every span)."""


@dataclass
class SpanEvent:
    """One lifecycle transition. ``attrs`` hold only deterministic
    step-denominated annotations; ``wall`` is the optional wall-clock
    annotation (``time.perf_counter()`` at emit) and is the ONLY
    nondeterministic field."""
    step: int
    state: str
    attrs: Dict[str, Any] = field(default_factory=dict)
    wall: Optional[float] = None


@dataclass
class RequestSpan:
    """Lifecycle of one request, validated against ``SPAN_TRANSITIONS``."""
    rid: int
    events: List[SpanEvent] = field(default_factory=list)
    first_token_step: int = -1     # step the request's FIRST token landed
    cohort: Optional[str] = None   # from the last ADMITTED annotation

    @property
    def state(self) -> str:
        return self.events[-1].state if self.events else SUBMITTED

    @property
    def submit_step(self) -> int:
        return self.events[0].step if self.events else -1

    @property
    def terminal_step(self) -> int:
        return self.events[-1].step if self.state in SPAN_TERMINAL else -1

    def transition(self, state: str, step: int, *,
                   wall: Optional[float] = None, **attrs) -> SpanEvent:
        if self.events:
            cur = self.state
            if state not in SPAN_TRANSITIONS[cur]:
                raise SpanStateError(
                    f"rid={self.rid}: illegal span transition "
                    f"{cur} -> {state} at step {step} (legal: "
                    f"{sorted(SPAN_TRANSITIONS[cur])})")
        elif state != SUBMITTED:
            raise SpanStateError(
                f"rid={self.rid}: span must open with {SUBMITTED}, "
                f"got {state}")
        ev = SpanEvent(step=step, state=state, attrs=dict(attrs), wall=wall)
        self.events.append(ev)
        if state == ADMITTED:
            self.cohort = attrs.get("cohort")
        return ev

    def events_of(self, state: str) -> List[SpanEvent]:
        return [e for e in self.events if e.state == state]


#: Default histogram edges (steps / tokens): upper-inclusive powers of two.
DEFAULT_BUCKETS: Tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512,
                                    1024)


class Histogram:
    """Fixed-bucket histogram with Prometheus ``le`` (upper-inclusive)
    semantics: ``counts[i]`` counts observations ``v <= edges[i]`` that
    exceeded every earlier edge; ``counts[-1]`` is the +Inf overflow
    bucket, so ``len(counts) == len(edges) + 1`` and ``sum(counts) ==
    count`` always."""

    def __init__(self, edges: Tuple[float, ...] = DEFAULT_BUCKETS):
        assert tuple(edges) == tuple(sorted(edges)) and len(edges) > 0
        self.edges: Tuple[float, ...] = tuple(edges)
        self.counts: List[int] = [0] * (len(edges) + 1)
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        for i, edge in enumerate(self.edges):
            if value <= edge:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.count += 1
        self.sum += float(value)

    def percentile(self, q: float) -> float:
        """Upper-edge estimate of the q-th percentile (q in [0, 100]);
        overflow observations report the last finite edge."""
        if self.count == 0:
            return 0.0
        rank = max(1, int(-(-q / 100.0 * self.count // 1)))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank:
                return float(self.edges[min(i, len(self.edges) - 1)])
        return float(self.edges[-1])

    def as_dict(self) -> Dict[str, Any]:
        return {"edges": list(self.edges), "counts": list(self.counts),
                "count": self.count, "sum": self.sum}


class ProgramCache:
    """ONE cache for every compiled serve program, keyed exactly like
    telemetry compile events: ``(cohort, program, shape)``.

    Before this class the engine kept per-family dicts and lazy attrs
    (``self._prefills``, ``self._scrubs``, ``self._decode_draft``, ...),
    each pairing its own membership test with its own
    ``compile_event`` call — the accounting could drift from the cache.
    Here a miss ALWAYS emits the compile event and then builds, so the
    telemetry compile map is by construction the cache's key census, and
    the bucket ladder's shapes register through the same single site as
    everything else.
    """

    def __init__(self, telemetry: "Telemetry"):
        self._telemetry = telemetry
        self._programs: Dict[Tuple[str, str, Any], Any] = {}

    def get(self, cohort: str, program: str, shape, build):
        """The compiled fn for the key, building (and recording the
        compile event) on first use. ``build`` is a zero-arg callable
        returning the jitted fn."""
        key = (cohort, program, shape)
        fn = self._programs.get(key)
        if fn is None:
            self._telemetry.compile_event(cohort, program, shape)
            fn = self._programs[key] = build()
        return fn

    def note(self, cohort: str, program: str, shape) -> None:
        """Record a compile event for a program that rides inside another
        key's build (the fused speculative step holds both the draft
        episode and the wide verify — one build, two program bodies)."""
        self._telemetry.compile_event(cohort, program, shape)

    def __len__(self) -> int:
        return len(self._programs)

    def __contains__(self, key) -> bool:
        return key in self._programs


def _percentiles(vals: List[float], qs=(50, 99)) -> List[float]:
    if not vals:
        return [0.0 for _ in qs]
    xs = sorted(vals)
    out = []
    for q in qs:
        # numpy 'linear' interpolation, dependency-free.
        pos = (len(xs) - 1) * q / 100.0
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        out.append(xs[lo] + (xs[hi] - xs[lo]) * (pos - lo))
    return out


class Telemetry:
    """Central registry: counters, gauges, histograms, compile events,
    fault records, and request spans — all step-stamped.

    ``enabled=False`` keeps the cheap fixed-size channels live (counters,
    compile events, histograms, fault log — the engine's ``stats`` deltas
    and the chaos-replay gates read them) but drops everything whose
    memory grows with run length: spans, gauge SERIES (last values are
    kept), and per-step wall marks. The flag must never change behavior —
    the bit-identity CI gate runs the same workload both ways.
    """

    SNAPSHOT_SCHEMA = 1

    def __init__(self, *, enabled: bool = True,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.enabled = enabled
        self.buckets = tuple(buckets)
        self.counters: Dict[str, int] = {}
        self.compiles: Dict[Tuple[str, str, Any], int] = {}
        self.fault_log: List[Dict[str, Any]] = []
        self.fault_counts: Dict[str, int] = {}
        self.spans: Dict[int, RequestSpan] = {}
        self.gauge_series: Dict[str, List[Tuple[int, float]]] = {}
        self.gauge_last: Dict[str, float] = {}
        self.hists: Dict[str, Histogram] = {}
        self.spec_log: List[Dict[str, Any]] = []
        self.step_wall: Dict[int, float] = {}   # step -> perf_counter at end

    # -- scalar channels (always on) -----------------------------------
    def seed_counters(self, names) -> None:
        """Pre-register counters at 0 so exporters (and callers iterating
        ``counters``) see the full key set before the first event."""
        for n in names:
            self.counters.setdefault(n, 0)

    def inc(self, name: str, n: int = 1) -> int:
        v = self.counters.get(name, 0) + n
        self.counters[name] = v
        return v

    def compile_event(self, cohort: str, program: str, shape) -> None:
        """Record one compiled-program-cache MISS, keyed ``(cohort,
        program, shape)``. The key is the host-side jit-wrapper cache key
        — a deterministic proxy for an XLA compile (each wrapper compiles
        on its first call). The engine's ``ProgramCache`` is the single
        increment site; bucketed prefill pins ``prefill compiles <=
        len(bucket ladder)`` per cohort in CI (the pre-bucket baseline
        was one compile per distinct prompt length)."""
        key = (cohort, program, shape)
        self.compiles[key] = self.compiles.get(key, 0) + 1

    def fault(self, step: int, kind: str, *, rid: Optional[int] = None,
              slot: Optional[int] = None, applied: bool = True,
              deferred: bool = False) -> None:
        """One fault-injection/occurrence record (the engine's single
        ``_log_fault`` site). Applied events advance ``fault_counts``;
        skipped ones are logged so gates count applied events, not
        intentions."""
        self.fault_log.append({
            "step": step, "kind": kind, "rid": rid, "slot": slot,
            "applied": applied, "deferred": deferred})
        if applied:
            self.fault_counts[kind] = self.fault_counts.get(kind, 0) + 1

    def observe(self, name: str, value: float) -> None:
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram(self.buckets)
        h.observe(value)

    # -- growing channels (gated by ``enabled``) -----------------------
    def spec_episode(self, step: int, slot: int, rid: int, *, probed: int,
                     accepted: int, committed: int) -> None:
        """One speculative draft/verify episode for a slot: ``probed``
        drafts were checked, ``accepted`` matched the full model,
        ``committed`` tokens landed (accepted + bonus, EOS may cut). The
        log renders as per-slot trace slices (repro.serve.trace); the
        always-on counters/histogram carry the aggregate view, so this is
        a growing channel gated like spans. Step-denominated — same-seed
        runs log byte-identically."""
        if self.enabled:
            self.spec_log.append({
                "step": step, "slot": slot, "rid": rid, "probed": probed,
                "accepted": accepted, "committed": committed})

    def gauge(self, name: str, step: int, value: float) -> None:
        self.gauge_last[name] = value
        if self.enabled:
            self.gauge_series.setdefault(name, []).append((step, value))

    def mark_step(self, step: int) -> None:
        """Wall-clock annotation for the END of ``step`` — the instant the
        step's decode results are on the host (what a client would see)."""
        if self.enabled:
            self.step_wall[step] = time.perf_counter()

    # -- spans ----------------------------------------------------------
    def span(self, rid: int) -> Optional[RequestSpan]:
        return self.spans.get(rid)

    def span_event(self, rid: int, state: str, step: int,
                   **attrs) -> None:
        """Append one lifecycle transition (creates the span on
        ``SUBMITTED``). No-op when disabled. Terminal transitions feed the
        step-latency histograms (global + per-cohort — the per-Δ-cohort
        breakdown operating-point decisions need)."""
        if not self.enabled:
            return
        span = self.spans.get(rid)
        if span is None:
            span = self.spans[rid] = RequestSpan(rid)
        span.transition(state, step, wall=time.perf_counter(), **attrs)
        if state in SPAN_TERMINAL:
            self._observe_terminal(span, step)

    def first_token(self, rid: int, step: int) -> None:
        span = self.spans.get(rid)
        if span is not None and span.first_token_step < 0:
            span.first_token_step = step

    def _observe_terminal(self, span: RequestSpan, step: int) -> None:
        e2e = step - span.submit_step
        self.observe("e2e_steps", e2e)
        if span.cohort is not None:
            self.observe(f"e2e_steps/{span.cohort}", e2e)
        if span.first_token_step >= 0:
            ttft = span.first_token_step - span.submit_step
            self.observe("ttft_steps", ttft)
            if span.cohort is not None:
                self.observe(f"ttft_steps/{span.cohort}", ttft)
        admits = span.events_of(ADMITTED)
        if admits:
            self.observe("queue_steps", admits[0].step - span.submit_step)

    # -- lifecycle ------------------------------------------------------
    def reset(self) -> None:
        """Zero every channel (benchmark warmup barrier): counters and
        fault counts keep their keys at 0, histories are dropped."""
        for k in self.counters:
            self.counters[k] = 0
        self.compiles.clear()
        self.fault_log.clear()
        for k in self.fault_counts:
            self.fault_counts[k] = 0
        self.spans.clear()
        self.gauge_series.clear()
        self.gauge_last.clear()
        self.hists.clear()
        self.spec_log.clear()
        self.step_wall.clear()

    # -- derived metrics ------------------------------------------------
    def _span_latency(self) -> Dict[str, Any]:
        """Step percentiles over terminal spans + wall-ms annotations.
        Wall TTFT/latency use the END-of-step wall mark of the step the
        first/last token landed (what the old benchmark loop measured) and
        the submit event's own wall stamp."""
        ttft_steps: List[float] = []
        e2e_steps: List[float] = []
        ttft_ms: List[float] = []
        lat_ms: List[float] = []
        for span in self.spans.values():
            if span.state not in SPAN_TERMINAL:
                continue
            sub = span.events[0]
            e2e_steps.append(span.terminal_step - span.submit_step)
            if span.first_token_step >= 0:
                ttft_steps.append(span.first_token_step - span.submit_step)
            if sub.wall is None:
                continue
            ft_wall = self.step_wall.get(span.first_token_step)
            end_wall = self.step_wall.get(span.terminal_step)
            if ft_wall is not None:
                ttft_ms.append((ft_wall - sub.wall) * 1e3)
            if end_wall is not None:
                lat_ms.append((end_wall - sub.wall) * 1e3)
        t50, t99 = _percentiles(ttft_steps)
        e50, e99 = _percentiles(e2e_steps)
        wt50, wt99 = _percentiles(ttft_ms)
        wl50, wl99 = _percentiles(lat_ms)
        return {
            "ttft_steps_p50": t50, "ttft_steps_p99": t99,
            "e2e_steps_p50": e50, "e2e_steps_p99": e99,
            "wall": {"ttft_p50_ms": round(wt50, 1),
                     "ttft_p99_ms": round(wt99, 1),
                     "lat_p50_ms": round(wl50, 1),
                     "lat_p99_ms": round(wl99, 1)},
        }

    def snapshot(self, *, step: int = -1) -> Dict[str, Any]:
        """JSON-able metrics snapshot. Everything outside keys named
        ``wall*`` is a pure function of the step-denominated event stream
        (the determinism gate compares wall-stripped snapshots)."""
        c = self.counters
        served = c.get("hit_tokens", 0) + c.get("prefill_tokens", 0)
        req_states: Dict[str, int] = {}
        for span in self.spans.values():
            req_states[span.state] = req_states.get(span.state, 0) + 1
        return {
            "schema": self.SNAPSHOT_SCHEMA,
            "step": step,
            "counters": dict(sorted(c.items())),
            "gauges": dict(sorted(self.gauge_last.items())),
            "histograms": {k: self.hists[k].as_dict()
                           for k in sorted(self.hists)},
            "compiles": {f"{co}/{prog}/{shape}": n
                         for (co, prog, shape), n
                         in sorted(self.compiles.items(), key=repr)},
            "compiles_total": sum(self.compiles.values()),
            "faults": dict(sorted(self.fault_counts.items())),
            "requests": dict(sorted(req_states.items())),
            "latency": self._span_latency(),
            "prefix": {
                "hit_tokens": c.get("hit_tokens", 0),
                "prefill_tokens": c.get("prefill_tokens", 0),
                "hit_rate": (round(c.get("hit_tokens", 0) / served, 3)
                             if served else 0.0),
            },
        }

    def prom_text(self) -> str:
        """Prometheus text exposition of the scalar channels (counters,
        last-value gauges, histograms with cumulative ``le`` buckets,
        compile events and faults as labeled counters)."""
        lines: List[str] = []
        for name in sorted(self.counters):
            lines.append(f"# TYPE serve_{name}_total counter")
            lines.append(f"serve_{name}_total {self.counters[name]}")
        for name in sorted(self.gauge_last):
            m = name.replace("/", "_")
            lines.append(f"# TYPE serve_{m} gauge")
            lines.append(f"serve_{m} {self.gauge_last[name]}")
        for name in sorted(self.hists):
            h = self.hists[name]
            m = f"serve_{name.replace('/', '_')}"
            lines.append(f"# TYPE {m} histogram")
            cum = 0
            for edge, cnt in zip(h.edges, h.counts):
                cum += cnt
                lines.append(f'{m}_bucket{{le="{edge}"}} {cum}')
            lines.append(f'{m}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{m}_sum {h.sum}")
            lines.append(f"{m}_count {h.count}")
        if self.compiles:
            lines.append("# TYPE serve_compile_events_total counter")
            for (co, prog, shape), n in sorted(self.compiles.items(),
                                               key=repr):
                lines.append(
                    f'serve_compile_events_total{{cohort="{co}",'
                    f'program="{prog}",shape="{shape}"}} {n}')
        if self.fault_counts:
            lines.append("# TYPE serve_faults_total counter")
            for kind in sorted(self.fault_counts):
                lines.append(f'serve_faults_total{{kind="{kind}"}} '
                             f"{self.fault_counts[kind]}")
        return "\n".join(lines) + "\n"
