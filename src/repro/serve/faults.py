"""Structured serving errors + deterministic chaos injection.

Two halves, both host-side:

**Error taxonomy.** Everything that can go wrong on a PER-REQUEST path in
the continuous-batching engine raises (or is recorded as) a ``ServeError``
subclass instead of an ``assert`` — a poisoned request must end in a typed
terminal state (``FAILED`` / ``EXPIRED``) with its pages and slot released,
never crash the ``PagedEngine`` and every cohabiting stream with it.
Engine-integrity invariants (page accounting balance, radix lock chains,
replay bit-identity) stay asserts on purpose: if THOSE fire the engine
state is wrong and limping on would corrupt surviving streams.

**Deterministic chaos.** A ``FaultPlan`` is a seeded, precomputed schedule
of fault events — the full schedule is a pure function of ``(seed,
n_steps)``, and each event names its engine step, so any outcome is
reproducible by ``(seed, step)`` and CI can gate on EXACT results (the
``serve_throughput.py --chaos --structural`` soak runs the same plan twice
and asserts identical fault logs and identical output streams). The five
kinds cover every per-request failure surface the engine defends:

  ``page_alloc_fail``     — ``PagePool.alloc`` transiently refuses; the
                            admission must roll back cleanly (request stays
                            QUEUED, accounting balanced).
  ``nan_logits``          — a running slot's decode logits turn NaN; the
                            engine's finite guard must FAIL exactly that
                            request and leave every survivor bit-identical.
  ``block_table_corrupt`` — a running slot's host block-table row is
                            scribbled; the pre-launch validator must catch
                            it before the gather ever runs.
  ``poison_prompt``       — a queued prompt grows an out-of-vocab token
                            after submit-time validation (a tokenizer-bug
                            stand-in); the device-boundary check at
                            admission must FAIL it and roll back its pages.
  ``deadline_storm``      — queued requests' deadlines collapse to "now";
                            the step-boundary expiry must shed them all in
                            one step with balanced accounting.

The plan only SCHEDULES events; the engine applies them via its hooks
(``PagePool.fail_next_allocs``, the poison-mask decode input, host
block-table/prompt mutation, deadline tightening) and logs what actually
fired in ``engine.fault_log`` — an event landing on an empty running set
is recorded as skipped, so gates count applied events, not intentions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ServeError", "InvalidRequestError", "QueueFullError", "LoadShedError",
    "PageAccountingError", "NonFiniteLogitsError",
    "BlockTableCorruptionError", "PoisonedPromptError",
    "DeadlineExceededError", "error_kind",
    "PAGE_ALLOC_FAIL", "NAN_LOGITS", "BLOCK_TABLE_CORRUPT", "POISON_PROMPT",
    "DEADLINE_STORM", "ALL_FAULT_KINDS", "FaultEvent", "FaultPlan",
]


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class ServeError(Exception):
    """Base of the serving error hierarchy. Request-scoped: raising (or
    recording) one fails A request, never the engine."""


class InvalidRequestError(ServeError, ValueError):
    """Submit-time validation failure (empty/over-length/mistyped prompt,
    request that could never fit the pool). Subclasses ``ValueError`` so
    pre-taxonomy callers catching ValueError keep working."""


class QueueFullError(ServeError):
    """Bounded submit queue is at capacity and the newcomer is no more
    urgent than anything queued — the submission is rejected."""


class LoadShedError(ServeError):
    """A queued request was shed to make room for a more urgent arrival
    (deadline-aware load-shedding under a bounded queue)."""


class PageAccountingError(ServeError, AssertionError):
    """Page-pool misuse: double-free, freeing/sharing a foreign or garbage
    page. Raised BEFORE any state mutates, so a caught abuse leaves
    ``check_balance()`` green. Subclasses ``AssertionError`` because the
    pool historically guarded these paths with bare asserts and callers
    test for that."""


class NonFiniteLogitsError(ServeError):
    """The decode/prefill finite guard saw NaN/inf logits (or non-finite
    emitted cache values) for this request's row."""


class BlockTableCorruptionError(ServeError):
    """A running slot's host block-table row disagrees with the pages the
    request actually owns (caught before the decode launch)."""


class PoisonedPromptError(ServeError):
    """A prompt reaching the device boundary holds out-of-vocab token ids
    (post-submit corruption; submit-time validation would have caught it)."""


class DeadlineExceededError(ServeError):
    """The request's deadline passed a step boundary before it finished."""


def error_kind(error: Optional[BaseException]) -> Optional[str]:
    """Stable telemetry label for an error: the taxonomy class name (e.g.
    ``"LoadShedError"``), or None. Class names — not ``str(error)`` — so
    span/trace annotations stay deterministic across runs whose messages
    embed run-dependent ids."""
    return None if error is None else type(error).__name__


# ---------------------------------------------------------------------------
# Deterministic fault plan
# ---------------------------------------------------------------------------

PAGE_ALLOC_FAIL = "page_alloc_fail"
NAN_LOGITS = "nan_logits"
BLOCK_TABLE_CORRUPT = "block_table_corrupt"
POISON_PROMPT = "poison_prompt"
DEADLINE_STORM = "deadline_storm"

ALL_FAULT_KINDS: Tuple[str, ...] = (
    PAGE_ALLOC_FAIL, NAN_LOGITS, BLOCK_TABLE_CORRUPT, POISON_PROMPT,
    DEADLINE_STORM)


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``index`` deterministically selects the victim
    at fire time (modulo the live population — running slots for decode
    faults, queue position for admission faults); ``payload`` parameterises
    the corruption (failed-alloc count, corrupted page offset, storm
    width). Victim selection is still fully reproducible: the engine is
    deterministic, so the same (seed, workload) always has the same
    population at ``step``."""
    step: int
    kind: str
    index: int
    payload: int


class FaultPlan:
    """Seeded, precomputed fault schedule over an engine-step horizon.

    The whole schedule is drawn at construction from one
    ``np.random.default_rng(seed)`` stream — ``at(step)`` is a pure lookup,
    so two plans with the same ``(seed, n_steps, per_kind, kinds)`` are
    identical event for event (the reproducibility contract the chaos CI
    gate runs twice to verify)."""

    def __init__(self, seed: int, *, n_steps: int = 200, per_kind: int = 3,
                 kinds: Sequence[str] = ALL_FAULT_KINDS, start: int = 5):
        for k in kinds:
            if k not in ALL_FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}; "
                                 f"choose from {ALL_FAULT_KINDS}")
        if n_steps - start < per_kind:
            raise ValueError(
                f"horizon [{start}, {n_steps}) too short for {per_kind} "
                "events per kind")
        self.seed = seed
        self.n_steps = n_steps
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        for kind in kinds:   # fixed kind order => fixed rng consumption
            steps = rng.choice(np.arange(start, n_steps), size=per_kind,
                               replace=False)
            for s in sorted(int(x) for x in steps):
                events.append(FaultEvent(step=s, kind=kind,
                                         index=int(rng.integers(0, 64)),
                                         payload=int(rng.integers(1, 8))))
        events.sort(key=lambda e: (e.step, e.kind, e.index))
        self.events: Tuple[FaultEvent, ...] = tuple(events)
        self._by_step: Dict[int, List[FaultEvent]] = {}
        for e in self.events:
            self._by_step.setdefault(e.step, []).append(e)

    def at(self, step: int) -> Tuple[FaultEvent, ...]:
        """Events scheduled for ``step`` (possibly empty) — pure lookup."""
        return tuple(self._by_step.get(step, ()))

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        kinds: Dict[str, int] = {}
        for e in self.events:
            kinds[e.kind] = kinds.get(e.kind, 0) + 1
        return (f"FaultPlan(seed={self.seed}, n_steps={self.n_steps}, "
                f"events={kinds})")
