from repro.serve.engine import (  # noqa: F401
    ServeConfig,
    cache_pspecs,
    generate,
    make_prefill,
    make_serve_step,
    make_sharded_prefill,
    make_sharded_serve_step,
)
