from repro.serve.bucketing import (  # noqa: F401
    PREFILL_ATTN_IMPL,
    bucket_for,
    default_buckets,
    rows_for_bucket,
    validate_buckets,
)
from repro.serve.engine import (  # noqa: F401
    AdmissionConfig,
    DegradeConfig,
    PagedEngine,
    PagedServeConfig,
    ServeConfig,
    SpecConfig,
    TelemetryConfig,
    cache_pspecs,
    generate,
    make_paged_bucket_prefill_fn,
    make_prefill,
    make_serve_step,
    make_sharded_generate,
    make_sharded_prefill,
    make_sharded_serve_step,
    sharded_generate,
)
from repro.serve.faults import (  # noqa: F401
    ALL_FAULT_KINDS,
    BlockTableCorruptionError,
    DeadlineExceededError,
    FaultEvent,
    FaultPlan,
    InvalidRequestError,
    LoadShedError,
    NonFiniteLogitsError,
    PageAccountingError,
    PoisonedPromptError,
    QueueFullError,
    ServeError,
    error_kind,
)
from repro.serve.telemetry import (  # noqa: F401
    DEFAULT_BUCKETS,
    Histogram,
    ProgramCache,
    RequestSpan,
    SpanEvent,
    SpanStateError,
    Telemetry,
)
from repro.serve.trace import (  # noqa: F401
    build_trace,
    dumps_trace,
    strip_wall,
    validate_trace,
    write_trace,
)
from repro.serve.prefix_cache import (  # noqa: F401
    PrefixCache,
    RadixNode,
)
from repro.serve.scheduler import (  # noqa: F401
    CANCELLED,
    COHORT_DEGRADED,
    COHORT_MAIN,
    EXPIRED,
    FAILED,
    FINISHED,
    QUEUED,
    RUNNING,
    TERMINAL_STATES,
    PagePool,
    Request,
    Scheduler,
)
from repro.serve.speculative import (  # noqa: F401
    COHORT_SPEC_DRAFT,
    COHORT_SPEC_VERIFY,
    SpecEpisode,
    accept_length,
    build_draft_step,
    build_verify_batch,
    commit_tokens,
    draft_plan_for,
    spec_eligible,
    stale_span,
)
from repro.serve.paged_cache import (  # noqa: F401
    rewind_plan,
    rewind_tokens,
    scatter_prefill_rows,
)
