from repro.serve.engine import (  # noqa: F401
    PagedEngine,
    PagedServeConfig,
    ServeConfig,
    cache_pspecs,
    generate,
    make_prefill,
    make_serve_step,
    make_sharded_generate,
    make_sharded_prefill,
    make_sharded_serve_step,
    sharded_generate,
)
from repro.serve.prefix_cache import (  # noqa: F401
    PrefixCache,
    RadixNode,
)
from repro.serve.scheduler import (  # noqa: F401
    PagePool,
    Request,
    Scheduler,
)
