"""Chrome/Perfetto ``trace_event`` export for the serve telemetry stream.

The trace is a pure function of the step-denominated telemetry records, so
two runs with the same ``(seed, workload, FaultPlan)`` schedule serialize
to byte-identical JSON once wall-clock annotations are stripped — the
chaos-structural gate asserts exactly that. Open the file in
https://ui.perfetto.dev or chrome://tracing.

Timebase: 1 engine step = ``US_PER_STEP`` (1000) trace microseconds, so
one "millisecond" on the timeline is one step. Wall-clock never positions
events — it only rides along in ``args`` fields prefixed ``wall``.

Track layout (pid/tid are synthetic ids; ``M`` metadata events name them):

- pid "slots", one tid per decode slot: a complete (``ph: X``) event per
  ADMITTED->offslot episode of the request occupying the slot, named
  ``r<rid>`` with cohort/hit-token args, plus nested ``prefill:*`` /
  ``replay`` child slices (trace_event nests X events on the same tid by
  containment) and, under speculative decoding, one ``spec:accepted/k``
  slice per draft/verify episode.
- pid "requests", one tid per rid: async-style lifetime from SUBMITTED to
  terminal plus instant (``ph: i``) markers for each state transition —
  queueing delay and preemption cycles read directly off this track.
- pid "engine": counter (``ph: C``) tracks — queue depth, pool
  live/free/refcount-shared pages, per-cohort slot occupancy, per-step
  radix hit tokens — and instant fault markers from the chaos schedule.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from repro.serve.telemetry import (ADMITTED, DECODE, PREEMPTED, PREFILL,
                                   REPLAY, SPAN_TERMINAL, SUBMITTED,
                                   Telemetry)

__all__ = ["US_PER_STEP", "build_trace", "dumps_trace", "write_trace",
           "strip_wall", "validate_trace"]

US_PER_STEP = 1000

_PID_SLOTS = 1
_PID_REQUESTS = 2
_PID_ENGINE = 3

#: Gauge series rendered as counter tracks, in track order.
_COUNTER_GAUGES = (
    "queue_depth", "pages_live", "pages_free", "pages_shared",
    "hit_tokens_step",
)


def _ts(step: int, frac: float = 0.0) -> int:
    """Deterministic integer microsecond for ``step`` (+ an intra-step
    fraction used to order sub-events within one step)."""
    return int(step * US_PER_STEP + frac * US_PER_STEP)


def _meta(pid: int, name: str, *, tid: int = 0, kind: str) -> Dict[str, Any]:
    return {"ph": "M", "pid": pid, "tid": tid, "ts": 0, "name": kind,
            "args": {"name": name}}


def _slot_episodes(tel: Telemetry) -> List[Dict[str, Any]]:
    """X slices on the per-slot tracks: one per admission episode."""
    events: List[Dict[str, Any]] = []
    for rid in sorted(tel.spans):
        span = tel.spans[rid]
        start = None      # the episode-opening ADMITTED event
        start_i = -1
        for i, ev in enumerate(span.events):
            if ev.state == ADMITTED:
                start, start_i = ev, i
            elif start is not None and (ev.state == PREEMPTED
                                        or ev.state in SPAN_TERMINAL):
                slot = start.attrs.get("slot", 0)
                args = {"rid": rid, "state": ev.state,
                        "cohort": start.attrs.get("cohort")}
                if "error" in ev.attrs:
                    args["error"] = ev.attrs["error"]
                events.append({
                    "ph": "X", "pid": _PID_SLOTS, "tid": slot,
                    "ts": _ts(start.step),
                    "dur": max(_ts(ev.step) - _ts(start.step),
                               US_PER_STEP // 2),
                    "name": f"r{rid}", "cat": "slot", "args": args})
                # Nested compute slices: prefill/replay happen in the
                # admission step; order them inside it.
                frac = 0.1
                for sub in span.events[start_i:i]:
                    if sub.state == PREFILL:
                        events.append({
                            "ph": "X", "pid": _PID_SLOTS, "tid": slot,
                            "ts": _ts(sub.step, frac),
                            "dur": US_PER_STEP // 4,
                            "name": f"prefill:{sub.attrs.get('kind')}",
                            "cat": "prefill",
                            "args": dict(sub.attrs, rid=rid)})
                        frac += 0.3
                    elif sub.state == REPLAY:
                        events.append({
                            "ph": "X", "pid": _PID_SLOTS, "tid": slot,
                            "ts": _ts(sub.step, frac),
                            "dur": US_PER_STEP // 4,
                            "name": "replay", "cat": "replay",
                            "args": dict(sub.attrs, rid=rid)})
                        frac += 0.3
                start, start_i = None, -1
    return events


def _spec_episodes(tel: Telemetry) -> List[Dict[str, Any]]:
    """X slices on the per-slot tracks: one draft/verify episode per
    running slot per speculative step, named ``spec:accepted/probed`` so
    acceptance collapse is visible on the timeline at a glance. Placed in
    the middle of the step (the episode IS the step's decode work),
    nesting inside the slot's admission slice by containment."""
    events: List[Dict[str, Any]] = []
    for e in tel.spec_log:
        events.append({
            "ph": "X", "pid": _PID_SLOTS, "tid": e["slot"],
            "ts": _ts(e["step"], 0.5), "dur": US_PER_STEP // 4,
            "name": f"spec:{e['accepted']}/{e['probed']}", "cat": "spec",
            "args": {k: e[k] for k in ("rid", "probed", "accepted",
                                       "committed")}})
    return events


def _request_track(tel: Telemetry) -> List[Dict[str, Any]]:
    """Per-request lifetime slices + transition instants."""
    events: List[Dict[str, Any]] = []
    for rid in sorted(tel.spans):
        span = tel.spans[rid]
        if not span.events:
            continue
        first, last = span.events[0], span.events[-1]
        end = (last.step if span.state in SPAN_TERMINAL
               else last.step + 1)
        args: Dict[str, Any] = {"rid": rid, "final": span.state,
                                "cohort": span.cohort,
                                "preemptions":
                                    len(span.events_of(PREEMPTED))}
        if span.first_token_step >= 0:
            args["ttft_steps"] = span.first_token_step - span.submit_step
        if first.wall is not None:
            args["wall_submit_s"] = first.wall
        events.append({
            "ph": "X", "pid": _PID_REQUESTS, "tid": rid,
            "ts": _ts(first.step),
            "dur": max(_ts(end) - _ts(first.step), US_PER_STEP // 2),
            "name": f"r{rid}", "cat": "request", "args": args})
        for j, ev in enumerate(span.events):
            if ev.state in (SUBMITTED, DECODE):
                continue   # SUBMITTED == slice start; DECODE spans steps
            iargs = dict(ev.attrs, rid=rid)
            if ev.wall is not None:
                iargs["wall_s"] = ev.wall
            events.append({
                "ph": "i", "pid": _PID_REQUESTS, "tid": rid,
                "ts": _ts(ev.step, min(0.9, 0.05 * j)), "s": "t",
                "name": ev.state, "cat": "lifecycle", "args": iargs})
    return events


def _engine_track(tel: Telemetry) -> List[Dict[str, Any]]:
    """Counter tracks from the gauge series + fault instants."""
    events: List[Dict[str, Any]] = []
    for name in _COUNTER_GAUGES:
        for step, value in tel.gauge_series.get(name, []):
            events.append({
                "ph": "C", "pid": _PID_ENGINE, "tid": 0,
                "ts": _ts(step, 0.99), "name": name,
                "args": {name: value}})
    # Per-cohort occupancy on one multi-series counter track.
    occ: Dict[int, Dict[str, float]] = {}
    for name, series in sorted(tel.gauge_series.items()):
        if not name.startswith("slots_live/"):
            continue
        cohort = name.split("/", 1)[1]
        for step, value in series:
            occ.setdefault(step, {})[cohort] = value
    for step in sorted(occ):
        events.append({
            "ph": "C", "pid": _PID_ENGINE, "tid": 0,
            "ts": _ts(step, 0.99), "name": "slots_live", "args": occ[step]})
    for k, f in enumerate(tel.fault_log):
        events.append({
            "ph": "i", "pid": _PID_ENGINE, "tid": 1,
            "ts": _ts(f["step"], min(0.9, 0.05 * k)), "s": "p",
            "name": f"fault:{f['kind']}", "cat": "fault",
            "args": {kk: f[kk] for kk in ("kind", "rid", "slot",
                                          "applied", "deferred")}})
    return events


def build_trace(tel: Telemetry, *, n_slots: int = 0) -> Dict[str, Any]:
    """Assemble the ``trace_event`` document from a Telemetry registry."""
    events: List[Dict[str, Any]] = [
        _meta(_PID_SLOTS, "slots", kind="process_name"),
        _meta(_PID_REQUESTS, "requests", kind="process_name"),
        _meta(_PID_ENGINE, "engine", kind="process_name"),
        _meta(_PID_ENGINE, "faults", tid=1, kind="thread_name"),
    ]
    slots = n_slots or 1 + max(
        (ev.attrs.get("slot", 0) for s in tel.spans.values()
         for ev in s.events if ev.state == ADMITTED), default=0)
    for s in range(slots):
        events.append(_meta(_PID_SLOTS, f"slot{s}", tid=s,
                            kind="thread_name"))
    events += _slot_episodes(tel)
    events += _spec_episodes(tel)
    events += _request_track(tel)
    events += _engine_track(tel)
    # Deterministic global order (ts, then pid/tid/ph/name) — json dump of
    # the sorted list is the byte stream the determinism gate compares.
    events.sort(key=lambda e: (e["ts"], e["pid"], e["tid"], e["ph"],
                               e["name"]))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {"clock": "engine_steps", "us_per_step": US_PER_STEP},
    }


def strip_wall(obj: Any) -> Any:
    """Recursively drop every dict key starting with ``wall`` — the only
    nondeterministic fields in traces and snapshots. What remains must be
    byte-identical across same-seed runs."""
    if isinstance(obj, dict):
        return {k: strip_wall(v) for k, v in sorted(obj.items())
                if not str(k).startswith("wall")}
    if isinstance(obj, (list, tuple)):
        return [strip_wall(v) for v in obj]
    return obj


def dumps_trace(tel: Telemetry, *, n_slots: int = 0,
                wall: bool = True) -> str:
    """Serialize deterministically (sorted keys, canonical separators).
    ``wall=False`` strips wall annotations first — the determinism gate
    compares these strings byte-for-byte."""
    doc = build_trace(tel, n_slots=n_slots)
    if not wall:
        doc = strip_wall(doc)
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


def write_trace(tel: Telemetry, path: str, *, n_slots: int = 0) -> str:
    s = dumps_trace(tel, n_slots=n_slots)
    with open(path, "w") as f:
        f.write(s)
    return path


def validate_trace(doc: Dict[str, Any]) -> None:
    """Structural validity check for a trace document (used by tests and
    the CI gates): required top-level keys, every event carries the
    required fields for its phase, timestamps non-negative ints."""
    assert isinstance(doc, dict) and "traceEvents" in doc
    for ev in doc["traceEvents"]:
        assert {"ph", "pid", "tid", "ts", "name"} <= set(ev), ev
        assert isinstance(ev["ts"], int) and ev["ts"] >= 0, ev
        if ev["ph"] == "X":
            assert "dur" in ev and ev["dur"] > 0, ev
        elif ev["ph"] == "C":
            assert "args" in ev and ev["args"], ev
        elif ev["ph"] == "i":
            assert ev.get("s") in ("t", "p", "g"), ev
        else:
            assert ev["ph"] == "M", ev
