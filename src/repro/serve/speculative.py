"""Self-speculative decoding controller: shallow-Δ drafts, full-depth verify.

The paper's Δ sweep shows the aggressively-paired shallow configuration is
a usable approximation of the full model — which makes the `LP.replan`
re-pairing a FREE draft model: same weights, same stacked pair-cache
layout, no extra parameter memory. The speculative mode drafts ``k`` greedy
tokens with the aggressive plan, then verifies all of them in ONE
full-depth launch, accepting the longest draft prefix the full model
agrees with plus the full model's own "bonus" token. Under greedy decoding
this is lossless by construction: every committed token is an argmax of
FULL-depth logits over an exactly-committed history, so the output stream
is bit-identical to the non-speculative engine (the spec-structural CI
gate) — the paper's accuracy-vs-speed tradeoff turned into pure speed.

Why the verifier is the regular batched paged-decode program
------------------------------------------------------------
The obvious verifier — a suffix forward over the k draft tokens
(``forward_full(ctx_kv=, start=)``) — would run each slot as a 1-row
sequence forward; at tiny row counts XLA lowers those projections to
matvecs whose reduction grouping differs from the batched decode gemm, and
the engine's bit-identity contract pins decode bits to the DECODE program
(see ``Scheduler._match_cap`` for the same constraint on prefix matching).
Instead the verifier packs slot ``s``'s k+1 probe tokens into rows
``s*(k+1)+j`` of one regular paged-decode launch at batch
``n_main*(k+1)``:

  row j feeds token u_j at position p0+j, where u_0 is the slot's last
  committed token at its committed position p0 and u_j (j>=1) is draft j.

Row independence makes this sound AND exact: the decode step scatters
every row's kv BEFORE any row gathers (model.attention.decode_attn_paged),
and each row masks positions beyond its own ``pos`` — so within the one
launch row j attends over exactly the committed history plus drafts
1..j, the same keys the sequential engine would have given it, through
the same kernel at the same batched shapes.

Rewind
------
Rejected drafts leave kv at positions past the new committed horizon in
both cache trees. Those bits are never read (future writes land before
any gather; per-row masks hide unwritten tails) but the contract that
pages hold ONLY committed-token kv is what the radix prefix cache and the
page accounting audit (``PagePool.check_balance``) lean on — so the
engine un-writes them (``paged_cache.rewind_tokens``) and the host-side
plan (``paged_cache.rewind_plan`` + ``PagePool.free_rewound``) returns
fully-rewound private pages to the pool for allocators that extend page
holdings on demand. Radix-SHARED pages are read-only by refcount: a
rewind may never touch them, which both the plan and the pool enforce.

Scope: attention-only models (mamba/RG-LRU state advances every slot on
every launch — a rewind would need conv/h snapshots per draft step; the
engine auto-disables speculation with a warning, prefix-cache precedent),
greedy sampling (acceptance compares argmax ids), tp=1 for now.

Everything here is pure host-side bookkeeping (numpy in, numpy out) so
the acceptance/masking/rewind math is unit-testable without an engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import lp as LP
from repro.serve.paged_cache import GARBAGE_PAGE

#: Compile-event cohorts for the speculative programs (the draft prefill /
#: draft decode and the wide verify launch), keyed like the engine's
#: main/degraded cohorts so ``metrics_snapshot()`` shows them.
COHORT_SPEC_DRAFT = "spec_draft"
COHORT_SPEC_VERIFY = "spec_verify"


def draft_plan_for(cfg, base_plan, spec_delta: int):
    """The drafter's LP plan: ``spec_delta`` effective layers (0 = maximal
    pairing), validated to be strictly MORE aggressive than the base plan —
    a draft at the serving depth would just double every step."""
    if spec_delta > 0:
        plan = LP.plan_for_depth(cfg, spec_delta, end=cfg.n_layers)
    else:
        plan = LP.plan_range(cfg, 0, cfg.n_layers)
    if len(plan.pairs) <= len(base_plan.pairs):
        raise ValueError(
            f"draft plan pairs {len(plan.pairs)} vs base "
            f"{len(base_plan.pairs)}: the drafter must be strictly more "
            "aggressive than the serving plan (lower spec_delta, or serve "
            "a shallower base)")
    return plan


def spec_eligible(ms) -> bool:
    """Speculation needs every mixer to be plain causal attention: paged
    k/v entries are positional, so rewinding = un-writing positions.
    Recurrent state (mamba conv/h, RG-LRU h) advances EVERY slot on every
    launch and has no per-position representation — rewind would need a
    state snapshot per draft step."""
    return all(spec.mixer.startswith("attn") and not spec.cross_attn
               for seg in ms.segments for spec in seg.group.specs)


# ---------------------------------------------------------------------------
# Batch packing: draft steps and the one wide verify launch
# ---------------------------------------------------------------------------
#
# ``remaining[s]`` is the slot's commit headroom: max_new - len(out) for a
# running slot, -1 for an idle one. Draft step j and verify row j both feed
# a token at device position p0+j; any j past ``remaining`` would write kv
# beyond the request's page allocation, so those rows are masked to the
# idle-slot convention (garbage block table, pos 0, tok 0 — exactly the
# rows the engine already ignores).

def draft_active(j: int, remaining: np.ndarray) -> np.ndarray:
    """Bool [n]: slots whose draft step j writes inside their allocation."""
    return (remaining >= 0) & (j <= remaining)


def build_draft_step(j: int, tok: np.ndarray, drafts: np.ndarray,
                     pos: np.ndarray, bt: np.ndarray,
                     remaining: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Inputs for draft launch ``j`` (0-based): feed the last committed
    token for j == 0, else draft j-1's output, at position p0+j."""
    act = draft_active(j, remaining)
    tok_j = np.where(act, tok if j == 0 else drafts[j - 1], 0)
    pos_j = np.where(act, pos + j, 0)
    bt_j = np.where(act[:, None], bt, GARBAGE_PAGE)
    return tok_j.astype(np.int32), pos_j.astype(np.int32), \
        bt_j.astype(np.int32)


def build_verify_batch(k: int, tok: np.ndarray, pos: np.ndarray,
                       bt: np.ndarray, poison: np.ndarray,
                       drafts: np.ndarray, remaining: np.ndarray
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                  np.ndarray]:
    """Pack every slot's k+1 probe rows for the ONE verify launch.

    Slot s occupies rows [s*(k+1), (s+1)*(k+1)): row j re-feeds u_j at
    p0+j against the slot's own block table, so its logits are the full
    model's distribution for position p0+j+1 given drafts 1..j. Poisoned
    slots replicate their poison flag to every row (chaos containment
    composes: any poisoned row fails the whole slot, never a neighbour).
    """
    n = tok.shape[0]
    rows = n * (k + 1)
    tok_v = np.zeros((rows,), np.int32)
    pos_v = np.zeros((rows,), np.int32)
    bt_v = np.full((rows, bt.shape[1]), GARBAGE_PAGE, np.int32)
    poison_v = np.zeros((rows,), bool)
    for j in range(k + 1):
        act = draft_active(j, remaining)
        idx = np.arange(n) * (k + 1) + j
        tok_v[idx] = np.where(act, tok if j == 0 else drafts[j - 1], 0)
        pos_v[idx] = np.where(act, pos + j, 0)
        bt_v[idx[act]] = bt[act]
        poison_v[idx] = poison & act
    return tok_v, pos_v, bt_v, poison_v


# ---------------------------------------------------------------------------
# Acceptance
# ---------------------------------------------------------------------------

def accept_length(draft_col: Sequence[int], verify_col: Sequence[int],
                  a_max: int) -> int:
    """Longest prefix of the drafts the full model reproduces, capped at
    ``a_max``: draft i+1 is accepted iff it equals verify row i's argmax
    (the full model's choice after committing drafts 1..i)."""
    a = 0
    while a < a_max and int(draft_col[a]) == int(verify_col[a]):
        a += 1
    return a


def commit_tokens(draft_col: Sequence[int], verify_col: Sequence[int],
                  a: int) -> List[int]:
    """The episode's committed tokens: accepted drafts 1..a, then the
    verifier's bonus — verify row a's argmax, the full model's pick for
    the first position the drafts got wrong (or the position after the
    last accepted draft). Every element is a FULL-depth argmax over a
    committed history: zero accuracy loss."""
    return [int(draft_col[i]) for i in range(a)] + [int(verify_col[a])]


def stale_span(pos0: int, accepted: int, j_hi: int) -> Tuple[int, int]:
    """Device positions [start, stop) holding rejected-draft kv after an
    episode: the verify/draft launches wrote positions p0..p0+j_hi, of
    which p0..p0+accepted hold committed-token kv. Empty when every
    written draft was accepted."""
    return pos0 + accepted + 1, pos0 + j_hi + 1


@dataclass(frozen=True)
class SpecEpisode:
    """One slot's draft/verify episode (telemetry record)."""
    step: int
    slot: int
    rid: int
    probed: int      # drafts actually probed (a_max; < k near max_new)
    accepted: int    # drafts the full model reproduced
    committed: int   # tokens appended (accepted + bonus, EOS may cut)
