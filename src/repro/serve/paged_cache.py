"""Paged pair-KV cache pool for the continuous-batching engine.

One-shot ``generate()`` gives every request a contiguous ring cache of
``max_len`` slots for its whole life — fine for a fixed batch, hopeless for
serving: a short request strands the memory of a long one and nothing can be
admitted until the whole batch drains. The paged pool instead carves the
cache into fixed-size PAGES handed out from a free list; a request holds
exactly the pages its length needs and returns them the moment it finishes,
so requests of very different lengths share one cache allocation.

Layout: the pool keeps PR 1's stacked pair layout end to end. A fused LP
pair's k/v pool is ``[2, n_pages, page_size, Hkv, hd]`` (leading pair axis,
bare entry names), a per-layer entry is ``[n_pages, page_size, Hkv, hd]``
(indexed names ``k0``/``v0``) — i.e. the ring layout with the ``[B, L]``
prefix replaced by ``[n_pages, page_size]``. Both halves of a pair live at
the SAME page indices of their own half of the leading axis, so one block
table serves the pair and homogeneous pairs still stream through one kernel
launch (``repro.kernels.decode_attention.decode_attention_pair_paged``).

Indirection: a block table ``[n_slots, pages_per_slot]`` maps each decode
slot's logical position ``t`` to ``(page, offset) = (bt[slot, t // ps],
t % ps)``. Page 0 is RESERVED as the garbage page: idle slots and the
unused tail of every block-table row point at it, so padded slots in the
fixed-shape decode batch write/read harmlessly without masking logic on
device. The free list never hands out page 0.

Mamba/RG-LRU state entries (``conv``/``h``) are O(1) per request and are
not paged — they stay slot-indexed with ``n_slots`` as the batch axis.
Cross-attention caches and non-causal ring kinds (sliding-window/chunked)
are not supported by the paged layout; ``validate_paged_support`` rejects
them up front.

Sharding (tp > 1): the pool shards over the model axis exactly like the
ring cache — the stored kv-head axis is cut when kv heads are sharded
(n_kv >= tp, so each rank's shard is ``[2, n_pages, page_size, Hkv/tp,
hd]``), replicated when n_kv < tp (ranks select their head in-kernel).
``paged_cache_meta`` inherits the pspecs from the ring meta verbatim:
replacing the ``[B, L]`` prefix with ``[n_pages, page_size]`` keeps every
sharded axis at the same position, so no new partition rules exist for
paged serving. Page ids, block tables and slot indices are host-side and
tp-agnostic — ``scatter_prefill``/decode writes run unchanged inside
shard_map on each rank's local shard.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.model import blocks as B
from repro.model import transformer as T

PyTree = Any

#: Reserved garbage page: idle slots and unused block-table entries point here.
GARBAGE_PAGE = 0


def is_paged_entry(name: str) -> bool:
    """Self-attention k/v entries are paged (per-token length dim); state
    entries (conv/h) are slot-indexed; cross-attention (xk/xv) unsupported."""
    return name.rstrip("0123456789") in ("k", "v")


def pages_needed(prompt_len: int, max_new: int, page_size: int) -> int:
    """Pages a request holds for its whole life (prompt + all new tokens)."""
    return -(-(prompt_len + max_new) // page_size)


def validate_paged_support(ms: T.ModelStructure, max_len: int) -> None:
    """The paged layout covers plain causal attention caches + slot state.

    Rejects: encoder/cross-attention (whisper), prefix-LM (paligemma), and
    ring kinds whose cache is a reused window/chunk ring rather than one
    slot per absolute position (recurrentgemma's attn_local, llama4's
    attn_chunked) — paging a reused ring would need per-page eviction.

    TP: a kv-SHARDED pool (n_kv >= tp) cuts the stored head axis into
    equal per-rank shards, so ``n_kv`` must divide by ``tp`` — the padded
    hkv_global the ring cache tolerates would put phantom heads in the
    pool and the paged kernel's scalar-prefetch index maps would walk off
    the real heads. Reject it HERE with an actionable message instead of
    failing inside the kernel index map. Replicated kv (n_kv < tp) has no
    divisibility requirement: every rank holds all stored heads and
    selects in-kernel (kernels.decode_attention head_map).
    """
    cfg = ms.cfg
    if ms.enc_segments or cfg.enc_layers:
        raise ValueError(f"{cfg.name}: encoder/cross-attention caches are "
                         "not pageable")
    if cfg.prefix_len:
        raise ValueError(f"{cfg.name}: prefix-LM serving is not paged yet")
    dims = ms.dims
    if ms.tp > 1 and dims.kv_sharded and cfg.n_kv_heads % ms.tp:
        raise ValueError(
            f"{cfg.name}: n_kv_heads={cfg.n_kv_heads} does not divide by "
            f"tp={ms.tp}; the paged pool shards stored kv heads evenly over "
            "the model axis (the ring cache pads to "
            f"{dims.hkv_global} heads, but padded pool heads would desync "
            "the paged kernel's block-table index maps) — pick tp dividing "
            "n_kv_heads, or tp > n_kv_heads for replicated-kv selection")
    for seg in ms.segments:
        for spec in seg.group.specs:
            if spec.cross_attn:
                raise ValueError(f"{cfg.name}: cross-attention not pageable")
            m = spec.mixer
            if m.startswith("attn") and B.ring_len(cfg, m, max_len) != max_len:
                raise ValueError(
                    f"{cfg.name}: {m} reuses a ring of "
                    f"{B.ring_len(cfg, m, max_len)} < {max_len} slots; paged "
                    "layout requires one slot per absolute position")


def paged_cache_meta(ms: T.ModelStructure, *, n_slots: int, n_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    """(abstract, pspec) trees for the paged pool, mirroring the ring cache
    tree structure (same segment list, same entry names) with the ``[B, L]``
    prefix of every paged entry replaced by ``[n_pages, page_size]``.

    ``dtype`` plays the role of ``prefill``'s cache cast: every float entry
    of the ring meta (including the fp32 recurrent state) is stored at
    ``dtype`` so pool contents match what a ring cache holds after the
    prefill cast.
    """
    abs_, ps_ = T.cache_meta(ms, batch=n_slots, max_len=n_pages * page_size,
                             kv_mode="heads", dtype=dtype)

    def remap(seg_abs, seg_ps):
        na, np_ = {}, {}
        for name, a in seg_abs.items():
            ba = T.cache_batch_axis(name)  # [count, (2,) B, ...]
            dt = dtype if a.dtype in (jnp.float32, jnp.bfloat16) else a.dtype
            if is_paged_entry(name):
                # [count, (2,) B, L, H, hd] -> [count, (2,) n_pages, ps, H, hd]
                shape = (*a.shape[:ba], n_pages, page_size, *a.shape[ba + 2:])
                spec = list(seg_ps[name])
                na[name] = jax.ShapeDtypeStruct(shape, dt)
                np_[name] = P(*spec)
            else:
                na[name] = jax.ShapeDtypeStruct(a.shape, dt)
                np_[name] = seg_ps[name]
        return na, np_

    outs = [remap(a, p) for a, p in zip(abs_, ps_)]
    return [o[0] for o in outs], [o[1] for o in outs]


def init_paged_caches(ms: T.ModelStructure, *, n_slots: int, n_pages: int,
                      page_size: int, dtype=jnp.bfloat16) -> List[Dict]:
    abs_, _ = paged_cache_meta(ms, n_slots=n_slots, n_pages=n_pages,
                               page_size=page_size, dtype=dtype)
    return jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), abs_)


def gather_ctx(pool: List[Dict], page_ids) -> List[Dict]:
    """Gather a prefix's pages into per-segment CONTEXT kv trees for the
    suffix prefill (``forward_full(ctx_kv=..., start=n_pg * page_size)``).

    pool: the paged cache tree; page_ids: [n_pg] int32 pages covering the
    matched prefix in position order. Returns one tree per segment with the
    emitted-cache layer layout and a batch-1 length axis: stacked pair
    entries [count, 2, 1, n_pg * ps, Hkv, hd], per-layer entries
    [count, 1, n_pg * ps, Hkv, hd]. Slot-state entries (conv/h) have no kv
    to resume from and are rejected upstream (prefix sharing is
    attention-only).
    """
    out = []
    for seg in pool:
        nseg = {}
        for name, pv in seg.items():
            assert is_paged_entry(name), (
                f"{name}: prefix sharing requires attention-only caches")
            ba = T.cache_batch_axis(name)   # page axis of the pool entry
            g = jnp.take(pv, page_ids, axis=ba)   # [.., n_pg, ps, H, hd]
            g = g.reshape(*g.shape[:ba], -1, *g.shape[ba + 2:])
            nseg[name] = jnp.expand_dims(g, ba)   # batch-1 at the B axis
        out.append(nseg)
    return out


def gather_ctx_rows(pool: List[Dict], page_ids) -> List[Dict]:
    """Per-row twin of ``gather_ctx`` for the bucketed radix-suffix path:
    gather EVERY row's ctx pages in one shot so prefix-hit and cold rows
    share a single ``[rows, bucket]`` prefill launch.

    pool: the paged cache tree; page_ids: [rows, n_ctx_pages] int32 — row
    i's first ``ctx_len_i / page_size`` entries are its matched prefix
    pages in position order, the rest (and every entry of a cold row) is
    ``GARBAGE_PAGE``. Returns one tree per segment with the emitted-cache
    layout and ``rows`` as the batch axis: stacked pair entries
    [count, 2, rows, n_ctx_pages * ps, Hkv, hd], per-layer entries
    [count, rows, n_ctx_pages * ps, Hkv, hd]. Garbage-directed positions
    gather the all-zero garbage page — finite junk the forward's per-row
    key rearrangement parks behind each row's causal horizon, where the
    pinned-tile chunked core treats it as exact-zero contribution (the
    same masked-no-op argument as bucket padding). Attention-only, like
    everything on the prefix path.
    """
    out = []
    for seg in pool:
        nseg = {}
        for name, pv in seg.items():
            assert is_paged_entry(name), (
                f"{name}: prefix sharing requires attention-only caches")
            ba = T.cache_batch_axis(name)   # page axis of the pool entry
            # [.., rows, n_pg, ps, H, hd]: rows becomes the batch axis in
            # place (no expand_dims — the row axis replaces batch-1).
            g = jnp.take(pv, page_ids, axis=ba)
            nseg[name] = g.reshape(*g.shape[:ba + 1], -1, *g.shape[ba + 3:])
        out.append(nseg)
    return out


def scrub_pages(pool: List[Dict], page_ids, slot):
    """Zero a departing request's pages and its slot-state rows.

    Fault-containment path: when a request FAILS with possibly non-finite
    cache contents (NaN params/activations during its prefill or decode),
    its private pages go back to the free list — and a later holder would
    gather whatever bits were left there. Masking makes stale values
    *ignored* in the softmax, but NaN is absorbing through masked lanes in
    some kernel layouts, so the engine scrubs before freeing rather than
    trusting masks. ``page_ids`` is fixed-shape (padded with
    ``GARBAGE_PAGE`` — zeroing the garbage page is harmless by definition),
    so one compiled program serves every failure. ``slot`` additionally
    clears the non-paged recurrent-state entries (conv/h) at the slot.
    """
    out = []
    for seg in pool:
        nseg = {}
        for name, pv in seg.items():
            ba = T.cache_batch_axis(name)
            if is_paged_entry(name):
                n_pg = page_ids.shape[0]
                ps = pv.shape[ba + 1]
                z = jnp.zeros((*pv.shape[:ba], n_pg, ps, *pv.shape[ba + 2:]),
                              pv.dtype)
                if ba == 2:   # stacked pair entry [count, 2, n_pages, ...]
                    nseg[name] = pv.at[:, :, page_ids].set(z)
                else:         # per-layer entry [count, n_pages, ...]
                    nseg[name] = pv.at[:, page_ids].set(z)
            else:
                zs = (*pv.shape[:ba], 1, *pv.shape[ba + 1:])
                nseg[name] = lax.dynamic_update_slice_in_dim(
                    pv, jnp.zeros(zs, pv.dtype), slot, axis=ba)
        out.append(nseg)
    return out


def scatter_prefill(pool: List[Dict], seq: List[Dict], page_ids, slot):
    """Place one request's prefill caches into its pages / state slot.

    pool: the paged cache tree (list of per-segment dicts).
    seq:  a batch-1 ring cache tree from ``forward_full(emit_cache=True,
          max_len=n_scatter_pages * page_size)`` — i.e. the cache length is
          already a whole number of pages.
    page_ids: [n_scatter_pages] int32 — the FIRST ceil(prompt_len /
          page_size) pages the request owns (always <= its allocation,
          since it holds pages for prompt + max_new). Positions in the
          last page past the true prompt length receive garbage; that is
          safe because they stay masked (pos > horizon) until the decode
          loop overwrites each of them in turn.
    slot: scalar int32 decode slot (receives the non-paged state entries).
    """
    n_pg = page_ids.shape[0]
    out = []
    for pool_seg, seq_seg in zip(pool, seq):
        nseg = {}
        for name, pv in pool_seg.items():
            sv = seq_seg[name]
            ba = T.cache_batch_axis(name)
            if is_paged_entry(name):
                ps = pv.shape[ba + 1]
                s = jnp.squeeze(sv, axis=ba)   # drop B=1 -> length at ba
                s = s.reshape(*s.shape[:ba], n_pg, ps, *s.shape[ba + 1:])
                s = s.astype(pv.dtype)
                if ba == 2:   # stacked pair entry [count, 2, n_pages, ...]
                    nseg[name] = pv.at[:, :, page_ids].set(s)
                else:         # per-layer entry [count, n_pages, ...]
                    nseg[name] = pv.at[:, page_ids].set(s)
            else:
                # Slot state: write the request's B=1 slice at its slot.
                nseg[name] = lax.dynamic_update_slice_in_dim(
                    pv, sv.astype(pv.dtype), slot, axis=ba)
        out.append(nseg)
    return out


def scatter_prefill_rows(pool: List[Dict], seq: List[Dict], page_ids):
    """Place a BUCKETED prefill batch's caches into each row's pages in
    one shot — the batched twin of ``scatter_prefill``.

    pool: the paged cache tree.
    seq:  a batch-``n_rows`` ring cache tree from the bucket forward
          (``forward_full(emit_cache=True, max_len=bucket)`` — the bucket
          is a whole number of pages).
    page_ids: [n_rows, n_pg] int32. Row i's first ``ceil(true_len_i /
          page_size)`` entries are its real pages; every PAD entry — the
          whole-page tail a short prompt does not reach, and every entry
          of an empty pad row — is ``GARBAGE_PAGE``. Garbage-directed
          chunks are ZEROED before the scatter, so (a) pad rows write
          nothing anywhere real, (b) the garbage page stays all-zero (its
          contract), and (c) the duplicate garbage indices are
          deterministic — every colliding write stores the same zeros.
          Positions in a row's LAST real page past its true length
          receive that row's junk-tail kv, exactly like the exact-length
          path's emit rounding: safe because they stay masked (pos >
          horizon) until decode overwrites each in turn.

    Bucketing is attention-only (the engine gates it on the same
    eligibility as prefix sharing), so there are no slot-state entries to
    place — a recurrent mixer's state would advance on pad positions with
    no way to mask the corruption.
    """
    n_rows, n_pg = page_ids.shape
    flat = page_ids.reshape(-1)                      # [n_rows * n_pg]
    valid = flat != GARBAGE_PAGE
    out = []
    for pool_seg, seq_seg in zip(pool, seq):
        nseg = {}
        for name, pv in pool_seg.items():
            assert is_paged_entry(name), (
                f"{name}: bucketed prefill requires attention-only caches")
            sv = seq_seg[name]
            ba = T.cache_batch_axis(name)            # rows at ba, len at ba+1
            ps = pv.shape[ba + 1]
            # Merge (rows, len) -> (rows * n_pg, ps): adjacent axes.
            s = sv.reshape(*sv.shape[:ba], n_rows * n_pg, ps,
                           *sv.shape[ba + 2:])
            mask = valid.reshape((1,) * ba + (n_rows * n_pg,)
                                 + (1,) * (s.ndim - ba - 1))
            s = jnp.where(mask, s, jnp.zeros((), s.dtype)).astype(pv.dtype)
            if ba == 2:   # stacked pair entry [count, 2, n_pages, ...]
                nseg[name] = pv.at[:, :, flat].set(s)
            else:         # per-layer entry [count, n_pages, ...]
                nseg[name] = pv.at[:, flat].set(s)
        out.append(nseg)
    return out


def rewind_tokens(pool: List[Dict], page_ids, offsets):
    """Un-write single token positions: zero ``(page_ids[i], offsets[i])``
    across every paged entry (both halves of a stacked pair at once).

    Speculative-decoding rewind path: rejected draft tokens left kv at
    positions past the slot's committed horizon. Those bits can never be
    *read* wrong — every decode launch scatters a row's kv before any row
    gathers, and per-row masks hide positions beyond each row's own
    ``pos`` — but the pool contract that pages hold only committed-token
    kv is what prefix sharing and the accounting audits lean on, so the
    engine restores it eagerly. Fixed-shape like ``scrub_pages``: pad the
    pair lists with ``(GARBAGE_PAGE, 0)`` (zeroing the garbage page is
    harmless by definition; duplicate pairs all write the same zero), so
    one compiled program serves every episode. Slot-state entries are left
    alone — speculation is attention-only (see serve.speculative).
    """
    out = []
    for seg in pool:
        nseg = {}
        for name, pv in seg.items():
            ba = T.cache_batch_axis(name)
            if is_paged_entry(name):
                n = page_ids.shape[0]
                z = jnp.zeros((*pv.shape[:ba], n, *pv.shape[ba + 2:]),
                              pv.dtype)
                if ba == 2:   # stacked pair entry [count, 2, n_pages, ...]
                    nseg[name] = pv.at[:, :, page_ids, offsets].set(z)
                else:         # per-layer entry [count, n_pages, ...]
                    nseg[name] = pv.at[:, page_ids, offsets].set(z)
            else:
                nseg[name] = pv
        out.append(nseg)
    return out


def rewind_plan(pages: List[int], n_shared: int, new_len: int, old_len: int,
                page_size: int) -> Tuple[List[Tuple[int, int]], List[int]]:
    """Host-side rewind bookkeeping: shrink a request's written horizon
    from ``old_len`` to ``new_len`` tokens.

    Returns ``(zero_pairs, free_pages)``:

    - ``zero_pairs``: the ``(page, offset)`` of every position in
      ``[new_len, old_len)`` — feed to ``rewind_tokens`` to un-write them.
    - ``free_pages``: the trailing pages left with NO live position — an
      allocator that extends page holdings on demand returns these via
      ``PagePool.free_rewound`` (which re-checks they are privately held).
      The engine's own allocator claims prompt + max_new pages up front
      and re-uses rewound positions for later commits, so it ignores this
      list; the distinction is exercised by the rewind property test.

    Radix-shared pages are read-only by refcount — a rewind may only
    un-write THIS request's own writes, so ``new_len`` may never cut into
    the shared prefix.
    """
    if not 0 <= new_len <= old_len:
        raise ValueError(f"rewind to {new_len} from {old_len}: the new "
                         "horizon must be within the written one")
    if new_len < n_shared * page_size:
        raise ValueError(
            f"rewind to {new_len} tokens would cut into the "
            f"{n_shared}-page radix-shared prefix "
            f"({n_shared * page_size} tokens): shared pages are read-only "
            "— only positions this request wrote itself can rewind")
    if old_len > len(pages) * page_size:
        raise ValueError(f"old_len={old_len} exceeds the "
                         f"{len(pages)}-page holding")
    zero_pairs = [(int(pages[t // page_size]), t % page_size)
                  for t in range(new_len, old_len)]
    first_keep = -(-new_len // page_size)
    n_old = -(-old_len // page_size)
    return zero_pairs, [int(p) for p in pages[first_keep:n_old]]
