"""Serving engine: batched prefill + autoregressive decode with LP models.

The engine exposes the three programs the assigned shapes lower:
  prefill_step  — logits + cache from a full prompt batch   (prefill_32k)
  serve_step    — ONE new token against the cache            (decode_32k /
                  long_500k; this is where LP's sync halving shows up —
                  seq=1 matmuls are tiny, so decode latency on a TP mesh is
                  dominated by the per-layer all-reduces the paper removes)
  generate      — host loop / scanned loop over serve_step

Sampling is vocab-parallel (Gumbel-max over the sharded vocabulary), so full
logits are never gathered.

Continuous batching
-------------------
``PagedEngine`` is the deployment-shaped entry point: requests of different
lengths arrive at different times, share ONE paged pair-KV cache pool
(repro.serve.paged_cache), and finish independently — ``add_request`` /
``step`` / ``drain``. The decode step stays ONE compiled program: the batch
is a fixed set of ``n_slots`` decode slots (idle slots point at the garbage
page and their outputs are ignored on the host), with per-slot positions
and a block table as the only per-step inputs. Prefill compiles per
distinct prompt length and runs the EXACT prompt (no right-padding), which
is what makes engine outputs bit-identical to one-shot ``generate()`` —
padding would change reduction shapes and perturb low bits. Admission is
FCFS with a prefill token budget (repro.serve.scheduler) so prefill bursts
interleave with, rather than starve, running decodes.

Prefix sharing & preemption (PagedServeConfig.prefix_cache/preempt_after):
admission radix-matches the prompt against donated whole pages
(repro.serve.prefix_cache) — matched pages link read-only into the block
table (copy-on-write: the first written page is always private) and only
the unmatched suffix runs through ``_suffix_fn``, a forward over the
suffix with the matched pages gathered as context kv whose rows reduce at
the cold program's exact shapes. A blocked queue head preempts the
youngest running request: its tokens park on the Request, its whole
written pages are donated (reclaimable, radix-hittable at resume), and
resume replays the parked positions through the regular decode program —
the engine asserts every replayed token reproduces the parked one.

Robustness (request lifecycle, fault isolation, chaos, degradation)
-------------------------------------------------------------------
Per-request failures are CONTAINED, never engine-fatal. The decode and
prefill programs return a per-row finite flag alongside tokens (NaN/inf
logits or non-finite emitted cache values), the block table of every
running slot is validated against its request's owned pages before each
launch, and prompts are re-checked against the vocabulary at the device
boundary. A tripped guard FAILs exactly the offending request — its
private pages are scrubbed (zeroed) before returning to the free list so
stale NaN cannot leak to a later holder — while every surviving stream
stays bit-identical to a fault-free run (the chaos CI gate). Requests
carry deadlines (expired at step boundaries) and can be cancelled;
``PagedServeConfig.max_queue`` bounds the submit queue with deadline-aware
shedding. ``fault_plan`` (repro.serve.faults.FaultPlan) injects seeded,
reproducible faults through the same hooks the real failures would take.

``degrade_delta`` turns overload into the paper's retraining-free
depth/quality trade instead of queueing: the engine re-pairs the SAME
weights under a more aggressive Δ plan (repro.core.lp.replan — no reload,
no retraining) and reserves ``degrade_slots`` decode slots as a DEGRADED
cohort running a second precompiled decode program over a separate cache
pool tree. Under SLO pressure (queue depth >= degrade_queue_depth) new
admissions overflow into that cohort; its greedy streams are bit-identical
to an engine built wholly at the aggressive Δ (the overload CI gate), and
cohorts never share radix pages (kv bits are plan-specific).

Sharded paged serving (``PagedEngine(mesh=...)``): the same engine loop
drives shard_map-compiled programs on a tp > 1 mesh. The page pool shards
its kv-head axis over the "model" axis exactly like the ring cache, every
host-side structure (scheduler, block tables, positions, page ids) is
tp-agnostic, and greedy decode streams stay bit-identical to the tp=1
engine and to one-shot ``sharded_generate`` (the sharded-structural CI
gate). Prefix sharing runs under tp > 1 too: the suffix-prefill ctx fold
branches per rank (kv-sharded pool: the gathered ctx arrives rank-local;
replicated pool: the rank in-gathers its head(s) like the paged decode
kernel), so radix hits keep their ~10x TTFT win exactly where production
runs — gated by the sharded-structural shared-prefix job.
"""
from __future__ import annotations

import contextlib
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import lp as LP
from repro.model import embedding as E
from repro.model import transformer as T
from repro.parallel.context import ParallelContext, make_context
from repro.serve import bucketing as BK
from repro.serve import faults as F
from repro.serve import paged_cache as PG
from repro.serve import speculative as SP
from repro.serve.faults import (BlockTableCorruptionError,
                                DeadlineExceededError, InvalidRequestError,
                                LoadShedError, NonFiniteLogitsError,
                                PoisonedPromptError, QueueFullError)
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import (COHORT_DEGRADED, COHORT_MAIN,
                                   TERMINAL_STATES, PagePool, Request,
                                   Scheduler)
from repro.serve.telemetry import (DECODE, PREFILL, REPLAY, ProgramCache,
                                   Telemetry)
from repro.serve.trace import write_trace

PyTree = Any


@dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024           # KV-cache length
    temperature: float = 0.0      # 0 -> greedy
    kv_mode: str = "heads"        # heads | seq  (seq-sharded KV cache)
    cache_dtype: Any = jnp.bfloat16
    # Pinned-tile chunked attention: the impl whose prefill output is
    # bit-invariant to right-padding the key axis (serve.bucketing). The
    # one-shot reference and the engine's prefills must run the SAME impl
    # or the engine==generate() bit-identity gates would compare different
    # reduction tilings.
    attn_impl: str = BK.PREFILL_ATTN_IMPL


# ---------------------------------------------------------------------------
# Local step functions (run under shard_map or plain)
# ---------------------------------------------------------------------------

def make_prefill(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    def prefill_fn(params, tokens, prefix=None, frames=None):
        logits, caches = T.prefill(
            params, tokens, ms=ms, pc=pc, max_len=sv.max_len,
            prefix_embed=prefix, enc_frames=frames, kv_mode=sv.kv_mode,
            attn_impl=sv.attn_impl, cache_dtype=sv.cache_dtype)
        return logits, caches
    return prefill_fn


def make_serve_step(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    """serve_step(params, tok [B], caches, t, key) -> (next_tok [B], caches).

    One full decode iteration: embed -> stack (1 psum per LP group phase) ->
    head -> vocab-parallel sample.
    """
    def serve_fn(params, tok, caches, t, key):
        logits, caches = T.decode_step(params, tok, caches, t, ms=ms, pc=pc,
                                       kv_mode=sv.kv_mode)
        if sv.temperature > 0:
            nxt = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
        else:
            nxt = E.vocab_parallel_argmax(logits, pc)
        return nxt.astype(jnp.int32), caches
    return serve_fn


def generate(params, prompts, n_new: int, *, ms: T.ModelStructure,
             pc: ParallelContext, sv: ServeConfig, key=None,
             prefix=None, frames=None):
    """Greedy/temperature generation: returns [B, n_new] new tokens.

    The decode loop is a lax.scan (one compiled program regardless of
    n_new), carrying (tok, caches, t, key).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    prefill_fn = make_prefill(ms, pc, sv)
    step_fn = make_serve_step(ms, pc, sv)
    logits, caches = prefill_fn(params, prompts, prefix, frames)
    if sv.temperature > 0:
        tok0 = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
    else:
        tok0 = E.vocab_parallel_argmax(logits, pc)
    tok0 = tok0.astype(jnp.int32)
    t0 = prompts.shape[1] + (ms.cfg.prefix_len if prefix is not None else 0)

    def body(carry, i):
        tok, caches, key = carry
        key, sub = jax.random.split(key)
        # ``tok`` sits at absolute position t0 + i; its logits predict i+1.
        nxt, caches = step_fn(params, tok, caches, t0 + i, sub)
        return (nxt, caches, key), tok

    (last, _, _), toks = lax.scan(body, (tok0, caches, key),
                                  jnp.arange(n_new - 1))
    return jnp.concatenate([toks.T, last[:, None]], axis=1)


# ---------------------------------------------------------------------------
# Continuous batching over the paged pair-KV cache pool
# ---------------------------------------------------------------------------

def _finite_flag(pc: ParallelContext, *leaves) -> jnp.ndarray:
    """Scalar bool: every inexact leaf is fully finite (reduced over tp so
    all ranks agree — the host decision must be replicated)."""
    bad = jnp.zeros((), jnp.int32)
    for leaf in leaves:
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            bad = bad | jnp.any(~jnp.isfinite(leaf)).astype(jnp.int32)
    return pc.pmax_tp(bad) == 0


def make_paged_decode_fn(ms: T.ModelStructure, pc: ParallelContext, psv):
    """Local paged decode step: (params, caches, tok [n_slots], pos
    [n_slots], block_tables, poison [n_slots] bool, key) ->
    (next_tok [n_slots], ok [n_slots] bool, caches).

    ``ok[slot]`` is the per-row finite guard: False when the slot's logits
    hold NaN/inf (tp-reduced so every rank reports identically). ``poison``
    is the deterministic-chaos hook — True rows get their logits overwritten
    with NaN BEFORE the guard, exercising the containment path; an
    all-False mask is a bitwise no-op (``where`` with a false predicate
    returns the original lanes), so the hook costs the bit-identity
    contract nothing.

    The SAME body runs under plain jit (tp=1 engine) and inside shard_map
    over a tp mesh (``make_sharded_serve_step(paged=...)``): tok/pos/block
    tables are replicated host-side inputs, the pool's kv-head axis is the
    only sharded dim, and sampling is vocab-parallel so full logits never
    materialise.
    """
    def f(params, caches, tok, pos, bt, poison, key):
        logits, caches = T.decode_step(
            params, tok, caches, pos, ms=ms, pc=pc,
            cache_layout="paged", block_tables=bt)
        logits = jnp.where(poison[:, None], jnp.nan, logits)
        bad = jnp.any(~jnp.isfinite(logits), axis=-1).astype(jnp.int32)
        ok = pc.pmax_tp(bad) == 0
        if psv.temperature > 0:
            nxt = E.vocab_parallel_sample(logits, key, psv.temperature, pc)
        else:
            nxt = E.vocab_parallel_argmax(logits, pc)
        return nxt.astype(jnp.int32), ok, caches

    return f


def make_spec_step_fn(ms_draft: T.ModelStructure, ms: T.ModelStructure,
                      pc: ParallelContext, psv, k: int):
    """Fused speculative step: (params_draft, params, caches_draft,
    caches, tok, pos, bt, poison, remaining, key) -> (drafts [k, n],
    yhat [n*(k+1)], ok [n*(k+1)], caches_draft, caches).

    One compiled program runs the whole episode: ``k`` shallow greedy
    draft steps (the device-side twin of ``speculative.
    build_draft_step`` — same activity mask, same garbage-page masking
    for rows whose commit budget ends mid-episode), the probe-row
    packing (twin of ``speculative.build_verify_batch``), and the ONE
    full-depth verify at batch ``n*(k+1)``. Host-side acceptance is the
    only thing left outside.

    Fusing matters for throughput: a (k+1)-launch python loop pays the
    per-launch dispatch + device sync k+1 times per speculative step —
    most of a smoke-scale step's wall time, and k avoidable device
    round-trips per step on real accelerators. Bit-identity is
    unaffected: the draft and verify BODIES are the unchanged paged
    decode programs, executed in the same order on the same operands.
    Draft rows are never poisoned and their finite flags are ignored
    (garbage proposals are simply refused by the verify, whose own
    per-row ``ok`` guard is returned)."""
    draft = make_paged_decode_fn(ms_draft, pc, psv)
    verify = make_paged_decode_fn(ms, pc, psv)

    def f(params_draft, params, caches_draft, caches, tok, pos, bt,
          poison, remaining, key):
        keys = jax.random.split(key, k + 1)
        n = tok.shape[0]
        no_poison = jnp.zeros((n,), jnp.bool_)
        garbage = jnp.full_like(bt, PG.GARBAGE_PAGE)
        prev = tok
        drafts = []
        for j in range(k):
            act = (remaining >= 0) & (j <= remaining)
            tok_j = jnp.where(act, prev, 0)
            pos_j = jnp.where(act, pos + j, 0)
            bt_j = jnp.where(act[:, None], bt, garbage)
            d, _, caches_draft = draft(params_draft, caches_draft, tok_j,
                                       pos_j, bt_j, no_poison, keys[j])
            drafts.append(d)
            prev = d
        drafts = jnp.stack(drafts)
        rows = n * (k + 1)
        base = jnp.arange(n) * (k + 1)
        tok_v = jnp.zeros((rows,), jnp.int32)
        pos_v = jnp.zeros((rows,), jnp.int32)
        bt_v = jnp.full((rows, bt.shape[1]), PG.GARBAGE_PAGE, jnp.int32)
        poison_v = jnp.zeros((rows,), jnp.bool_)
        for j in range(k + 1):
            act = (remaining >= 0) & (j <= remaining)
            u = tok if j == 0 else drafts[j - 1]
            tok_v = tok_v.at[base + j].set(jnp.where(act, u, 0))
            pos_v = pos_v.at[base + j].set(jnp.where(act, pos + j, 0))
            bt_v = bt_v.at[base + j].set(jnp.where(act[:, None], bt,
                                                   garbage))
            poison_v = poison_v.at[base + j].set(poison & act)
        yhat, ok, caches = verify(params, caches, tok_v, pos_v, bt_v,
                                  poison_v, keys[k])
        return drafts, yhat, ok, caches_draft, caches

    return f


def make_paged_prefill_fn(ms: T.ModelStructure, pc: ParallelContext, psv,
                          prompt_len: int):
    """Local exact-length prefill + page scatter: (params, caches, prompt
    [1, prompt_len], page_ids, slot, key) -> (first_tok [1], ok, caches).
    ``ok`` is the finite guard over the sampled position's logits AND the
    emitted cache (a poisoned prompt/params corrupts the kv it writes, not
    just the logits — the guard must trip before those pages are ever
    donated or decoded from). The cache emission length rounds up to whole
    pages; the forward itself is the exact prompt — no padding (the
    bit-identity contract). Shared by the tp=1 jit and the shard_map
    wrapper (sp stays off: exact odd-length prompts do not split over
    ranks)."""
    n_pg = -(-prompt_len // psv.page_size)
    emit_len = n_pg * psv.page_size

    def f(params, caches, prompt, page_ids, slot, key):
        logits, _, seq = T.forward_full(
            params, prompt, ms=ms, pc=pc, emit_cache=True,
            max_len=emit_len, kv_mode="heads",
            attn_impl=BK.PREFILL_ATTN_IMPL)
        # Same cast T.prefill applies to the ring cache.
        seq = jax.tree.map(
            lambda c: c.astype(psv.cache_dtype)
            if c.dtype in (jnp.float32, jnp.bfloat16) else c, seq)
        last = logits[:, prompt_len - 1]
        ok = _finite_flag(pc, last, *jax.tree.leaves(seq))
        if psv.temperature > 0:
            tok0 = E.vocab_parallel_sample(last, key, psv.temperature, pc)
        else:
            tok0 = E.vocab_parallel_argmax(last, pc)
        caches = PG.scatter_prefill(caches, seq, page_ids, slot)
        return tok0.astype(jnp.int32), ok, caches

    return f


def make_paged_bucket_prefill_fn(ms: T.ModelStructure, pc: ParallelContext,
                                 psv, bucket: int, rows: int,
                                 ctx_pages: int = 0):
    """Bucketed batched prefill + masked page scatter: (params, caches,
    prompts [rows, bucket], true_lens [rows], page_ids [rows, n_pg],
    [ctx_ids [rows, ctx_pages], ctx_lens [rows],] key)
    -> (first_tok [rows], ok [rows], caches).

    ONE launch prefills up to ``rows`` requests right-padded to
    ``bucket`` tokens. Bit-identity with the exact-length program holds
    because the forward runs the pinned-tile chunked attention impl
    (serve.bucketing): row i's logits at position ``true_lens[i] - 1``
    depend only on kv tiles covering [0, true_lens[i]) — right-padding
    and batching cannot move a bit. The per-row finite guard covers the
    sampled logits AND the row's emitted cache (tp-reduced like the
    decode guard), so one poisoned request fails alone while its
    bucket-mates' streams stay untouched. Pad rows (group smaller than
    ``rows``) carry ``true_lens == 1`` and all-garbage page ids: their
    junk never lands (``scatter_prefill_rows`` masks garbage-directed
    chunks) and the host ignores their outputs. Shared by the tp=1 jit
    and the shard_map wrapper (``make_sharded_prefill(bucket_rows=)``).

    ``ctx_pages > 0`` makes the program CTX-AWARE (prefix-on engines):
    radix-HIT rows ride the same launch as cold rows. Row i's matched
    prefix pages arrive in ``ctx_ids[i]`` (garbage-padded to the uniform
    ``ctx_pages`` width) with its true ctx length in ``ctx_lens[i]``;
    ``prompts[i]`` then holds only the SUFFIX (true_lens[i] = suffix
    length) and the forward runs with per-row start offsets. Cold rows
    pass ctx_len 0 + all-garbage ctx ids and reduce bit-identically to
    the plain (ctx_pages=0) program: their gathered ctx is finite junk
    that the per-row key rearrangement parks past the causal horizon,
    where pinned-tile masking zeroes it exactly (see
    blocks.attention_phase_full). One arity per engine keeps prefill
    compiles <= n_buckets even at high hit-rates.
    """
    def f(params, caches, prompts, true_lens, page_ids, *rest):
        if ctx_pages:
            ctx_ids, ctx_lens, key = rest
            ctx = PG.gather_ctx_rows(caches, ctx_ids)
            start = ctx_lens
        else:
            (key,) = rest
            ctx = None
            start = 0
        logits, _, seq = T.forward_full(
            params, prompts, ms=ms, pc=pc, emit_cache=True,
            max_len=bucket, kv_mode="heads", ctx_kv=ctx, start=start,
            attn_impl=BK.PREFILL_ATTN_IMPL)
        seq = jax.tree.map(
            lambda c: c.astype(psv.cache_dtype)
            if c.dtype in (jnp.float32, jnp.bfloat16) else c, seq)
        last = jnp.take_along_axis(
            logits, (true_lens - 1)[:, None, None], axis=1)[:, 0]
        bad = jnp.any(~jnp.isfinite(last), axis=-1).astype(jnp.int32)
        for seg in seq:
            for name, c in seg.items():
                if jnp.issubdtype(c.dtype, jnp.inexact):
                    ba = T.cache_batch_axis(name)
                    ax = tuple(i for i in range(c.ndim) if i != ba)
                    bad = bad | jnp.any(~jnp.isfinite(c),
                                        axis=ax).astype(jnp.int32)
        ok = pc.pmax_tp(bad) == 0
        if psv.temperature > 0:
            tok0 = E.vocab_parallel_sample(last, key, psv.temperature, pc)
        else:
            tok0 = E.vocab_parallel_argmax(last, pc)
        caches = PG.scatter_prefill_rows(caches, seq, page_ids)
        return tok0.astype(jnp.int32), ok, caches

    return f


def make_paged_suffix_prefill_fn(ms: T.ModelStructure, pc: ParallelContext,
                                 psv, n_ctx_pages: int, suffix_len: int):
    """Prefix-hit suffix prefill: (params, caches, suffix [1, suffix_len],
    ctx_ids [n_ctx_pages], sfx_ids, slot, key) -> (first_tok [1], ok,
    caches). Gathers the matched pages as read-only context kv, runs the
    forward over ONLY the unmatched suffix, and scatters the suffix pages.
    Every suffix row reduces over exactly ``ctx + suffix`` keys — the cold
    full-prompt program's reduction shape for the same row — so greedy
    outputs stay bit-identical to a cold run (fp32 pool). Copy-on-write
    holds by construction: the program writes only ``sfx_ids`` pages,
    never ``ctx_ids``. Runs under tp > 1 too: inside shard_map a
    kv-sharded pool's ``gather_ctx`` yields each rank's local shard and
    ``_fold_ctx_kv`` branches per rank (identity vs in-gather), audited
    against the core's per-rank head count. Shared by the tp=1 jit and
    the shard_map wrapper (``make_sharded_prefill(suffix_ctx_pages=)``).
    """
    ps = psv.page_size
    start = n_ctx_pages * ps
    n_sfx = -(-suffix_len // ps)
    emit_len = n_sfx * ps

    def f(params, caches, suffix, ctx_ids, sfx_ids, slot, key):
        ctx = PG.gather_ctx(caches, ctx_ids)
        logits, _, seq = T.forward_full(
            params, suffix, ms=ms, pc=pc, emit_cache=True,
            max_len=emit_len, kv_mode="heads", ctx_kv=ctx, start=start,
            attn_impl=BK.PREFILL_ATTN_IMPL)
        seq = jax.tree.map(
            lambda c: c.astype(psv.cache_dtype)
            if c.dtype in (jnp.float32, jnp.bfloat16) else c, seq)
        last = logits[:, suffix_len - 1]
        ok = _finite_flag(pc, last, *jax.tree.leaves(seq))
        if psv.temperature > 0:
            tok0 = E.vocab_parallel_sample(last, key, psv.temperature, pc)
        else:
            tok0 = E.vocab_parallel_argmax(last, pc)
        caches = PG.scatter_prefill(caches, seq, sfx_ids, slot)
        return tok0.astype(jnp.int32), ok, caches

    return f


@dataclass(frozen=True)
class AdmissionConfig:
    """Admission-side knobs: how much prefill work a step may take on and
    how the submit queue bounds itself. ``prefill_buckets`` is the bucket
    ladder for batched prefill — None picks the auto ladder
    (``bucketing.default_buckets``), an empty tuple disables bucketing
    (every prefill runs the exact-length program — the A/B reference),
    an explicit tuple is validated against the page geometry."""
    prefill_token_budget: int = 4096
    max_queue: int = 0
    prefill_buckets: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class DegradeConfig:
    """Overload degradation: the aggressive-Δ slot cohort (see
    PagedServeConfig docstring)."""
    enabled: bool = False
    slots: int = 0
    queue_depth: int = 1
    eff_depth: int = 0


@dataclass(frozen=True)
class SpecConfig:
    """Self-speculative decoding: shallow-Δ drafts, full-depth verify."""
    k: int = 0
    delta: int = 0


@dataclass(frozen=True)
class TelemetryConfig:
    """Observability retention + profiling hooks."""
    enabled: bool = True
    profile_decode: bool = False


@dataclass(frozen=True)
class PagedServeConfig:
    """Static geometry of the continuous-batching engine.

    Grouped view: the flat fields below decompose into four sub-configs —
    ``AdmissionConfig`` (budget, queue bound, bucket ladder),
    ``DegradeConfig``, ``SpecConfig``, ``TelemetryConfig`` — passable as
    the ``admission`` / ``degrade`` / ``spec`` / ``telemetry_cfg``
    kwargs. The flat kwargs stay accepted as a deprecation shim (every
    existing caller passes them), and after construction BOTH views are
    populated and consistent: group kwargs are copied onto the flats,
    then the canonical group objects are rebuilt from the flats.
    ``validate()`` is the one entry point for every cross-field rule; the
    engine calls it first thing.

    max_len must be a page multiple: the decode step attends over exactly
    ``pages_per_slot * page_size == max_len`` gathered positions, the same
    horizon a ring cache of ``max_len`` gives one-shot ``generate()`` —
    equal reduction shapes are part of the bit-identity contract.
    ``n_pages`` INCLUDES the reserved garbage page 0, so the allocatable
    capacity is ``n_pages - 1`` pages.

    prefix_cache: radix prefix sharing over whole pages — matched prompt
    pages are linked read-only into the block table and only the unmatched
    suffix is prefilled. Attention-only models (the engine silently
    disables it for mixers with recurrent state). Greedy prefix-hit
    outputs are bit-identical to a cold run when the pool holds fp32 and
    the donor computed the shared pages at compatible shapes (whole-page
    chunks are length-invariant by the suffix-prefill contract; see
    EXPERIMENTS.md).
    preempt_after: > 0 enables preemption — after that many consecutive
    steps with a blocked queue head, the youngest running request is
    parked (pages donated/released, tokens kept) and later resumed via
    radix re-link + bit-exact decode replay. 0 keeps PR 2's strict FCFS.

    max_queue: > 0 bounds the SUBMIT queue. A submission against a full
    queue sheds the queued request with the slackest deadline if the
    newcomer is strictly more urgent (EXPIRED with ``LoadShedError``),
    else raises ``QueueFullError`` — overload degrades by policy, never by
    unbounded memory growth. 0 keeps the queue unbounded.
    degrade_delta: reserve ``degrade_slots`` slots as a DEGRADED cohort
    running the same weights re-paired at an aggressive Δ
    (``degrade_eff_depth`` effective layers; 0 = maximal pairing). When the
    queue depth reaches ``degrade_queue_depth`` and the main cohort is
    full, new admissions overflow into the degraded cohort instead of
    waiting — the paper's retraining-free speed/quality family as an
    overload valve. tp=1 engines only for now.
    spec_k: > 0 turns on SELF-SPECULATIVE decoding (serve.speculative):
    each step drafts ``spec_k`` greedy tokens per running slot with the
    same weights re-paired at an aggressive Δ (``spec_delta`` effective
    layers, 0 = maximal pairing), then verifies all of them in ONE
    full-depth launch of the regular decode program at batch
    ``n_main * (spec_k + 1)`` — accepting the longest matched draft
    prefix plus the verifier's bonus token, and un-writing rejected
    positions from both cache trees. Greedy output streams stay
    BIT-IDENTICAL to the non-speculative engine (every committed token is
    a full-depth argmax over a committed history); acceptance only moves
    throughput. Greedy-only, tp=1, attention-only models (auto-disables
    with a warning for recurrent mixers), exclusive with degrade_delta
    for now.
    """
    n_slots: int = 8              # concurrent decode slots (fixed batch)
    page_size: int = 16           # tokens per cache page
    n_pages: int = 129            # pool size incl. the reserved garbage page
    max_len: int = 256            # per-request position cap (page multiple)
    prefill_token_budget: int = 4096   # admission budget per step
    temperature: float = 0.0      # 0 -> greedy (bit-identical to generate())
    cache_dtype: Any = jnp.bfloat16
    eos_token: int = -1           # -1: run every request to max_new
    prefix_cache: bool = False    # radix prefix sharing (CoW pages)
    preempt_after: int = 0        # blocked-head steps before preemption
    max_queue: int = 0            # bounded submit queue (0 = unbounded)
    degrade_delta: bool = False   # aggressive-Δ overload cohort
    degrade_slots: int = 0        # slots reserved for the degraded cohort
    degrade_queue_depth: int = 1  # queue depth that signals SLO pressure
    degrade_eff_depth: int = 0    # effective depth of the cohort (0 = max Δ)
    spec_k: int = 0               # speculative draft length (0 = off)
    spec_delta: int = 0           # drafter effective depth (0 = max Δ)
    # telemetry=False drops span/gauge-series/wall retention for unbounded
    # soaks; counters, compile events and the fault log stay live (engine
    # semantics read them). Telemetry never adds device launches and never
    # changes outputs — the serve-structural gate runs a workload both ways
    # and asserts bit-identity. profile_decode brackets each cohort's
    # decode launch in a jax.profiler StepTraceAnnotation (needs an active
    # jax.profiler trace to matter; off the hot path by default).
    telemetry: bool = True        # retain spans/gauge series/wall marks
    profile_decode: bool = False  # jax.profiler annotation around decode
    # Bucketed prefill ladder: None = auto (powers-of-two page multiples
    # capped at max_len), () = off, explicit tuple = validated ladder.
    prefill_buckets: Optional[Tuple[int, ...]] = None
    # Grouped sub-config kwargs (each overrides its flat fields when
    # given; rebuilt canonically in __post_init__ so both views agree).
    admission: Optional[AdmissionConfig] = None
    degrade: Optional[DegradeConfig] = None
    spec: Optional[SpecConfig] = None
    telemetry_cfg: Optional[TelemetryConfig] = None

    def __post_init__(self):
        # Frozen dataclass: object.__setattr__ is the sanctioned escape
        # hatch inside __post_init__.
        def put(name, value):
            object.__setattr__(self, name, value)

        if self.admission is not None:
            a = self.admission
            put("prefill_token_budget", a.prefill_token_budget)
            put("max_queue", a.max_queue)
            put("prefill_buckets", a.prefill_buckets)
        if self.degrade is not None:
            d = self.degrade
            put("degrade_delta", d.enabled)
            put("degrade_slots", d.slots)
            put("degrade_queue_depth", d.queue_depth)
            put("degrade_eff_depth", d.eff_depth)
        if self.spec is not None:
            put("spec_k", self.spec.k)
            put("spec_delta", self.spec.delta)
        if self.telemetry_cfg is not None:
            put("telemetry", self.telemetry_cfg.enabled)
            put("profile_decode", self.telemetry_cfg.profile_decode)
        if self.prefill_buckets is not None:
            put("prefill_buckets", tuple(self.prefill_buckets))
        # Canonical groups, rebuilt from the (possibly shimmed) flats.
        put("admission", AdmissionConfig(
            prefill_token_budget=self.prefill_token_budget,
            max_queue=self.max_queue,
            prefill_buckets=self.prefill_buckets))
        put("degrade", DegradeConfig(
            enabled=self.degrade_delta, slots=self.degrade_slots,
            queue_depth=self.degrade_queue_depth,
            eff_depth=self.degrade_eff_depth))
        put("spec", SpecConfig(k=self.spec_k, delta=self.spec_delta))
        put("telemetry_cfg", TelemetryConfig(
            enabled=self.telemetry, profile_decode=self.profile_decode))

    def validate(self, *, mesh: bool = False) -> None:
        """Every cross-field configuration rule, in one place. Actionable
        ValueErrors, not asserts: these are mistakes a user should be
        able to fix from the message alone (validate_paged_support
        style). ``mesh``: the engine runs under a tp > 1 mesh — some
        features are tp=1-only for now."""
        if self.max_len % self.page_size != 0:
            raise ValueError(
                f"max_len={self.max_len} is not a multiple of "
                f"page_size={self.page_size}: the decode step attends over "
                "exactly pages_per_slot * page_size positions, so a partial "
                "trailing page would change reduction shapes and break the "
                "bit-identity contract — pick max_len as a whole number of "
                "pages")
        if self.n_slots < 1:
            raise ValueError(
                f"n_slots={self.n_slots} must be >= 1: the decode program's "
                "fixed batch is the slot count, and an engine with no slots "
                "can never admit a request")
        if self.max_queue < 0:
            raise ValueError(f"max_queue={self.max_queue} must be >= 0 "
                             "(0 = unbounded)")
        if self.prefill_buckets:
            BK.validate_buckets(self.prefill_buckets,
                                page_size=self.page_size,
                                max_len=self.max_len)
        if self.degrade_delta:
            if not 1 <= self.degrade_slots < self.n_slots:
                raise ValueError(
                    f"degrade_delta needs 1 <= degrade_slots < n_slots "
                    f"(got degrade_slots={self.degrade_slots}, "
                    f"n_slots={self.n_slots}): the degraded cohort must "
                    "leave at least one main slot")
            if mesh:
                raise ValueError(
                    "degrade_delta is tp=1-only for now: the degraded "
                    "cohort would need its own sharded program pair and "
                    "replanned param placement")
        elif self.degrade_slots:
            raise ValueError(
                f"degrade_slots={self.degrade_slots} without degrade_delta: "
                "reserved degraded slots would simply idle — set "
                "degrade_delta=True or degrade_slots=0")
        if self.spec_k < 0:
            raise ValueError(f"spec_k={self.spec_k} must be >= 0 (0 = off)")
        if self.spec_k:
            if self.temperature > 0:
                raise ValueError(
                    "spec_k needs temperature=0.0: acceptance compares "
                    "greedy argmax ids — sampled verification would need "
                    "rejection sampling over full logit distributions, "
                    "which the vocab-parallel sampler never materialises")
            if mesh:
                raise ValueError(
                    "spec_k is tp=1-only for now: the draft and wide "
                    "verify programs need their own sharded wrappers and "
                    "replanned param placement")
            if self.degrade_delta:
                raise ValueError(
                    "spec_k is exclusive with degrade_delta for now: the "
                    "speculative controller drives the main cohort, and "
                    "composing it with a degraded cohort needs a draft "
                    "tree per cohort — pick one overload strategy")
        elif self.spec_delta:
            raise ValueError(
                f"spec_delta={self.spec_delta} without spec_k: set "
                "spec_k >= 1 to enable speculative decoding")

    @property
    def pages_per_slot(self) -> int:
        return self.max_len // self.page_size


class PagedEngine:
    """Continuous-batching serving engine: ``add_request / step / drain``.

    One ``step()`` is: chaos injection (when armed) -> deadline expiry ->
    FCFS admission (each admitted request prefills at its exact length and
    claims its pages; prompts and prefill outputs pass fault guards), then
    ONE fixed-shape decode program per ACTIVE cohort. Finished requests
    (EOS / max_new) release their slot and pages the same step, so the next
    admission reuses them; FAILED/CANCELLED/EXPIRED requests release within
    the step that terminates them.

    Greedy outputs are bit-identical per request to one-shot
    ``generate(params, prompt[None], max_new)`` with ``max_len`` equal to
    this engine's: prefill runs the identical forward at the exact prompt
    length, decode runs the identical per-row math (paged gather + same
    cores), and every cross-request interaction is row-independent — which
    is also why failing one slot leaves the survivors' streams untouched.

    ``mesh``: run the compiled programs under shard_map on a tp > 1 mesh
    (``ms`` must be built with the matching tp). The page pool shards its
    kv-head axis over the model axis like the ring cache; scheduling,
    block tables and per-slot positions stay host-side and tp-agnostic.
    The radix prefix cache runs under tp > 1 too: gathered ctx kv folds
    per rank (kv-sharded pool: identity; replicated pool: in-gather like
    the paged decode kernel's head map), so prefix-hit streams stay
    bit-identical to the tp=1 prefix-on engine and to sharded one-shot
    ``generate()``.

    ``fault_plan``: a ``repro.serve.faults.FaultPlan`` — each step applies
    that step's scheduled events through the same hooks real faults would
    take; ``fault_log`` records what actually fired (rid-stamped), making
    every outcome reproducible by (seed, step).
    """

    def __init__(self, params, ms: T.ModelStructure, psv: PagedServeConfig,
                 *, pc: Optional[ParallelContext] = None, key=None,
                 mesh=None, fault_plan: Optional[F.FaultPlan] = None):
        # Cross-field configuration rules live on the config itself
        # (PagedServeConfig.validate) — the engine calls it first thing,
        # then checks only what needs the model structure or mesh/pc.
        psv.validate(mesh=mesh is not None)
        PG.validate_paged_support(ms, psv.max_len)
        self.ms = ms
        self.psv = psv
        self.mesh = mesh
        self.n_main = psv.n_slots - (psv.degrade_slots
                                     if psv.degrade_delta else 0)
        self.n_deg = psv.n_slots - self.n_main
        # Degraded-cohort model: the SAME weights re-paired under an
        # aggressive Δ plan (retraining-free — repro.core.lp.replan), built
        # from the raw host params before any device placement.
        self.ms_deg = self.params_deg = None
        if psv.degrade_delta:
            cfg = ms.cfg
            if psv.degrade_eff_depth > 0:
                deg_plan = LP.plan_for_depth(cfg, psv.degrade_eff_depth,
                                             end=cfg.n_layers)
            else:
                deg_plan = LP.plan_range(cfg, 0, cfg.n_layers)
            if len(deg_plan.pairs) <= len(ms.plan.pairs):
                raise ValueError(
                    f"degraded plan pairs {len(deg_plan.pairs)} layers vs "
                    f"base {len(ms.plan.pairs)}: the degraded cohort must "
                    "be strictly MORE aggressive than the base plan "
                    "(lower degrade_eff_depth, or use a shallower base)")
            segs2, sp2 = LP.replan(cfg, params["segments"], ms.segments,
                                   deg_plan)
            self.ms_deg = T.build_structure(cfg, plan=deg_plan, tp=ms.tp)
            assert tuple(s.group.specs for s in self.ms_deg.segments) == \
                tuple(s.group.specs for s in segs2)
            self.params_deg = dict(params, segments=sp2)
        # Speculative drafter: the SAME weights re-paired at an aggressive
        # Δ (serve.speculative) — the paper's shallow configuration as a
        # free draft model. Eligibility-gated like the prefix cache:
        # recurrent mixers auto-disable with a warning instead of erroring,
        # and the engine then behaves exactly as spec_k=0 (bit-identical —
        # the fallback test pins it).
        self.spec_k = psv.spec_k
        self.ms_draft = self.params_draft = None
        if self.spec_k and not SP.spec_eligible(ms):
            warnings.warn(
                f"{ms.cfg.name}: speculative decoding auto-disabled — "
                "recurrent mixer state (mamba conv/h, RG-LRU h) advances "
                "on every launch and has no per-position representation "
                "to rewind (per-draft-step state snapshots are a "
                "follow-on); serving continues non-speculatively",
                stacklevel=2)
            self.spec_k = 0
        if self.spec_k:
            cfg = ms.cfg
            spec_plan = SP.draft_plan_for(cfg, ms.plan, psv.spec_delta)
            segs2, sp2 = LP.replan(cfg, params["segments"], ms.segments,
                                   spec_plan)
            self.ms_draft = T.build_structure(cfg, plan=spec_plan, tp=ms.tp)
            assert tuple(s.group.specs for s in self.ms_draft.segments) == \
                tuple(s.group.specs for s in segs2)
            self.params_draft = dict(params, segments=sp2)
        if mesh is not None:
            if pc is not None:
                raise ValueError(
                    "pass mesh OR pc, not both: with a mesh the engine "
                    "derives its ParallelContext from the mesh axes")
            self.pc = make_context(mesh, sp=False)
            if self.pc.tp_size != ms.tp:
                raise ValueError(
                    f"mesh model axis has {self.pc.tp_size} devices but ms "
                    f"was built with tp={ms.tp}: rebuild the structure with "
                    f"build_structure(cfg, tp={self.pc.tp_size}) (params "
                    "must be initialised/loaded at that tp as well)")
            self.params = jax.device_put(params, _tree_shardings(
                mesh, T.param_pspecs(ms)))
        else:
            self.pc = pc if pc is not None else ParallelContext()
            self.params = params
        # ONE instrumented path for every engine event: counters, spans,
        # gauges, compile events and fault records all live here (host-side
        # only — telemetry never adds device launches). Must exist before
        # the scheduler (span emission) and the compiled programs (compile
        # events).
        self.telemetry = Telemetry(enabled=psv.telemetry)
        self.telemetry.seed_counters(self.COUNTER_KEYS)
        self.telemetry.fault_counts.update(
            {k: 0 for k in F.ALL_FAULT_KINDS})
        # ONE home for every compiled program, keyed (cohort, program,
        # shape) — the same triple the telemetry compile-event stream
        # uses, so cache misses and compile accounting can never drift.
        self._programs = ProgramCache(self.telemetry)
        self.pool = PagePool(psv.n_pages)
        self.prefix = (PrefixCache(psv.page_size, telemetry=self.telemetry)
                       if psv.prefix_cache and self._prefix_eligible(ms)
                       else None)
        # Bucketed prefill needs the pinned-tile chunked impl's padding
        # transparency, which only the attention mixer family honours —
        # same eligibility gate as the prefix cache. None = auto ladder,
        # () = off (the exact-length A/B reference configuration).
        if psv.prefill_buckets == () or not self._prefix_eligible(ms):
            self._buckets: Tuple[int, ...] = ()
        elif psv.prefill_buckets is None:
            self._buckets = BK.default_buckets(psv.max_len, psv.page_size)
        else:
            self._buckets = psv.prefill_buckets
        self.sched = Scheduler(
            n_slots=psv.n_slots, pool=self.pool, page_size=psv.page_size,
            max_len=psv.max_len,
            prefill_token_budget=psv.prefill_token_budget,
            prefix_cache=self.prefix, preempt_after=psv.preempt_after,
            degrade_slots=self.n_deg, telemetry=self.telemetry,
            prefill_buckets=self._buckets)
        if mesh is not None:
            c_abs, c_specs = PG.paged_cache_meta(
                ms, n_slots=self.n_main, n_pages=psv.n_pages,
                page_size=psv.page_size, dtype=psv.cache_dtype)
            self.caches = jax.tree.map(
                lambda a, sh: jax.device_put(jnp.zeros(a.shape, a.dtype), sh),
                c_abs, _tree_shardings(mesh, c_specs))
        else:
            self.caches = PG.init_paged_caches(
                ms, n_slots=self.n_main, n_pages=psv.n_pages,
                page_size=psv.page_size, dtype=psv.cache_dtype)
        # The degraded cohort's cache tree spans the SAME page-id space
        # (one host-side PagePool partitions ids between cohorts by
        # allocation, not by range) but holds aggressive-plan kv.
        self.caches_deg = (PG.init_paged_caches(
            self.ms_deg, n_slots=self.n_deg, n_pages=psv.n_pages,
            page_size=psv.page_size, dtype=psv.cache_dtype)
            if self.n_deg else None)
        # The drafter's cache tree spans the SAME page-id space as the
        # main tree (one block table serves both); it holds
        # aggressive-plan kv that only ever feeds draft proposals — the
        # verify launch reads the MAIN tree, so draft bits can move
        # acceptance but never committed output.
        self.caches_draft = (PG.init_paged_caches(
            self.ms_draft, n_slots=self.n_main, n_pages=psv.n_pages,
            page_size=psv.page_size, dtype=psv.cache_dtype)
            if self.spec_k else None)
        P_slot = psv.pages_per_slot
        self.block_tables = np.full((self.n_main, P_slot), PG.GARBAGE_PAGE,
                                    np.int32)
        self.tok = np.zeros((self.n_main,), np.int32)
        self.pos = np.zeros((self.n_main,), np.int32)
        self.block_tables_deg = np.full((self.n_deg, P_slot),
                                        PG.GARBAGE_PAGE, np.int32)
        self.tok_deg = np.zeros((self.n_deg,), np.int32)
        self.pos_deg = np.zeros((self.n_deg,), np.int32)
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self.step_count = 0
        self.results: Dict[int, np.ndarray] = {}
        self._requests: Dict[int, Request] = {}
        self._decode = self._make_decode(COHORT_MAIN)
        self._decode_deg = (self._make_decode(COHORT_DEGRADED)
                            if self.n_deg else None)
        self._spec_step = None
        if self.spec_k:
            # ONE fused program holds both speculative bodies: the
            # k-step draft episode at the aggressive plan (batch n_main)
            # and the verifier — which IS the regular decode program at
            # a wider batch: n_main * (spec_k + 1) probe rows through
            # the same body the main cohort compiles at n_main (row
            # independence is what makes the wide launch bit-equal to
            # sequential steps). One build, one compile event per body:
            # the fused program lives under the draft key and the verify
            # body is note()d so the compile stream still shows both.
            self._spec_step = self._programs.get(
                SP.COHORT_SPEC_DRAFT, "decode", self.n_main,
                lambda: jax.jit(
                    make_spec_step_fn(self.ms_draft, ms, self.pc, psv,
                                      self.spec_k),
                    donate_argnums=(2, 3)))
            self._programs.note(SP.COHORT_SPEC_VERIFY, "decode",
                                self.n_main * (self.spec_k + 1))
        # rids whose draft tree was primed by a bucketed draft-cohort
        # prefill this step — _spec_prime then skips its full prefill.
        self._spec_primed: set = set()
        # Greedy + fp32 pool => suffix/replay recomputation is bit-exact
        # against the original run; the engine then self-checks the replay.
        self._exact = (psv.temperature == 0.0
                       and psv.cache_dtype == jnp.float32)
        # Chaos state: the plan schedules, the engine applies + logs.
        self._plan = fault_plan
        self._poison_slots: set = set()   # slots NaN-poisoned THIS step
        self._poison_next = 0             # deferred poison_prompt events
        self._storm_next = 0              # deferred deadline_storm victims

    #: Every monotone engine counter, pre-registered at 0. Per-step
    #: ``step()`` stats are DELTAS of the lifecycle subset over the step —
    #: one increment site per event, no parallel stats threading.
    COUNTER_KEYS = (
        "prefill_tokens", "hit_tokens", "resume_hit_tokens",
        "replay_tokens", "full_prefills", "suffix_prefills", "prefix_hits",
        "bucket_prefills", "bucket_groups", "pad_tokens",
        "submitted", "admitted", "decoded", "finished", "preempted",
        "failed", "expired", "cancelled", "shed", "degraded_admissions",
        "draft_steps", "verify_steps", "spec_accepted", "spec_rejected",
        "spec_rewound")
    #: The subset ``step()`` reports as per-step deltas.
    STEP_STAT_KEYS = ("admitted", "decoded", "finished", "preempted",
                      "failed", "expired")

    @property
    def counters(self) -> Dict[str, int]:
        """Monotone event counters (the live Telemetry dict)."""
        return self.telemetry.counters

    @property
    def fault_log(self) -> List[Dict[str, Any]]:
        return self.telemetry.fault_log

    @property
    def fault_counts(self) -> Dict[str, int]:
        return self.telemetry.fault_counts

    @staticmethod
    def _prefix_eligible(ms: T.ModelStructure) -> bool:
        """Prefix sharing resumes from cached kv alone: every mixer must be
        attention (recurrent conv/h state has no page representation) and
        the FFN a plain MLP (the MoE pair path has no pinned-order
        projection; see model.mlp.mlp_forward)."""
        return all(spec.mixer.startswith("attn") and not spec.cross_attn
                   and spec.ffn in ("mlp", None)
                   for seg in ms.segments for spec in seg.group.specs)

    # -- cohort plumbing ------------------------------------------------
    def _cohort_of_slot(self, slot: int) -> str:
        return COHORT_MAIN if slot < self.n_main else COHORT_DEGRADED

    def _arrays(self, cohort: str):
        """(tok, pos, block_tables, slot_base) for a cohort; slot indices
        into these arrays are ``global_slot - slot_base``."""
        if cohort == COHORT_MAIN:
            return self.tok, self.pos, self.block_tables, 0
        return self.tok_deg, self.pos_deg, self.block_tables_deg, self.n_main

    def _model(self, cohort: str):
        if cohort == COHORT_MAIN:
            return self.params, self.ms
        return self.params_deg, self.ms_deg

    def _get_caches(self, cohort: str):
        return self.caches if cohort == COHORT_MAIN else self.caches_deg

    def _set_caches(self, cohort: str, val) -> None:
        if cohort == COHORT_MAIN:
            self.caches = val
        else:
            self.caches_deg = val

    def _decode_fn(self, cohort: str):
        return self._decode if cohort == COHORT_MAIN else self._decode_deg

    # -- compiled programs ---------------------------------------------
    # Every builder below is compile-event-FREE: callers route through
    # ``self._programs.get(cohort, program, shape, build)``, which emits
    # the compile event exactly once per distinct key — the single
    # compile-accounting increment site.
    def _make_decode(self, cohort: str):
        size = self.n_main if cohort == COHORT_MAIN else self.n_deg

        def build():
            params_ms = self._model(cohort)[1] \
                if cohort == COHORT_DEGRADED else self.ms
            if self.mesh is not None:
                fn, _, _, _ = make_sharded_serve_step(
                    params_ms, self.mesh, None, batch=size, paged=self.psv)
                return fn
            local = make_paged_decode_fn(params_ms, self.pc, self.psv)
            return jax.jit(local, donate_argnums=(1,))

        return self._programs.get(cohort, "decode", size, build)

    def _prefill_fn(self, prompt_len: int, cohort: str):
        """Exact-length prefill + page scatter, compiled once per distinct
        (prompt length, cohort) — the cohorts differ in both the model
        structure (re-paired stack) and the cache tree's slot count."""
        def build():
            ms = self._model(cohort)[1]
            size = self.n_main if cohort == COHORT_MAIN else self.n_deg
            if self.mesh is not None:
                fn, _, _ = make_sharded_prefill(
                    ms, self.mesh, None, batch=1, prompt_len=prompt_len,
                    paged=self.psv, paged_slots=size)
                return fn
            local = make_paged_prefill_fn(ms, self.pc, self.psv, prompt_len)
            return jax.jit(local, donate_argnums=(1,))

        return self._programs.get(cohort, "prefill_full", prompt_len, build)

    def _bucket_ctx_pages(self, cohort: str) -> int:
        """Ctx-page width of the cohort's bucket programs. Prefix-ON main
        cohorts route EVERY bucket launch through the ctx-aware program
        (cold rows pass ctx_len 0 + all-garbage ids and reduce
        bit-identically to the plain program), so hits and colds share one
        compile and the ladder bound holds with hits present. The width is
        uniform: a radix match always leaves a >= 2-token (>= 1-page)
        suffix (scheduler._match_cap), so ctx pages <= pages_per_slot - 1.
        Draft-mirror and degraded launches keep the plain program (the
        radix tree never holds their plan's pages)."""
        if self.prefix is not None and cohort == COHORT_MAIN:
            return self.psv.pages_per_slot - 1
        return 0

    def _bucket_prefill_fn(self, bucket: int, rows: int, cohort: str):
        """Bucketed batched prefill: ``rows`` right-padded prompts through
        one ``[rows, bucket]`` launch. Compiled once per distinct
        (bucket, rows) — and rows is a pure function of (bucket, static
        config), so the cohort's compile count is bounded by the ladder
        length, not by arrivals. Prefix-on main cohorts build the
        ctx-aware arity (``_bucket_ctx_pages``) so radix-hit suffixes ride
        the same launch."""
        ctx_pages = self._bucket_ctx_pages(cohort)

        def build():
            if self.mesh is not None:
                fn, _, _ = make_sharded_prefill(
                    self.ms, self.mesh, None, batch=rows,
                    prompt_len=bucket, paged=self.psv,
                    paged_slots=self.n_main, bucket_rows=rows,
                    bucket_ctx_pages=ctx_pages)
                return fn
            ms = (self.ms_draft if cohort == SP.COHORT_SPEC_DRAFT
                  else self._model(cohort)[1])
            local = make_paged_bucket_prefill_fn(ms, self.pc, self.psv,
                                                 bucket, rows, ctx_pages)
            return jax.jit(local, donate_argnums=(1,))

        return self._programs.get(cohort, "prefill_bucket", (bucket, rows),
                                  build)

    def _suffix_fn(self, n_ctx_pages: int, suffix_len: int):
        """Prefix-hit exact-shape prefill, compiled once per (context
        pages, suffix length) — the fallback when the suffix misses the
        bucket ladder. Main cohort only (the radix tree never holds
        degraded-plan pages); runs under tp > 1 via the shard_map wrapper
        (the per-rank ctx fold in model.blocks)."""
        if self.mesh is not None:
            fn, _, _ = make_sharded_prefill(
                self.ms, self.mesh, None, batch=1, prompt_len=suffix_len,
                paged=self.psv, paged_slots=self.n_main,
                suffix_ctx_pages=n_ctx_pages)
            return fn
        local = make_paged_suffix_prefill_fn(self.ms, self.pc, self.psv,
                                             n_ctx_pages, suffix_len)
        return jax.jit(local, donate_argnums=(1,))

    def _draft_decode_fn(self):
        """Single-step draft decode, compiled lazily — only the resume
        catch-up path needs it (the decode phase runs the fused
        ``_draft_episode`` program instead)."""
        return self._programs.get(
            SP.COHORT_SPEC_DRAFT, "decode_catchup", self.n_main,
            lambda: jax.jit(
                make_paged_decode_fn(self.ms_draft, self.pc, self.psv),
                donate_argnums=(1,)))

    def _spec_prefill_fn(self, prompt_len: int):
        """Draft-tree prefill at the aggressive plan, compiled once per
        distinct prompt length (tp=1 only — spec_k validation)."""
        return self._programs.get(
            SP.COHORT_SPEC_DRAFT, "prefill_full", prompt_len,
            lambda: jax.jit(
                make_paged_prefill_fn(self.ms_draft, self.pc, self.psv,
                                      prompt_len),
                donate_argnums=(1,)))

    def _scrub_fn(self, cohort: str):
        """Compiled page/state scrub for one cohort (built lazily — the
        happy path never needs it). Fixed shapes: the page-id vector is
        padded with the garbage page."""
        def build():
            if self.mesh is not None:
                _, c_specs = PG.paged_cache_meta(
                    self.ms, n_slots=self.n_main, n_pages=self.psv.n_pages,
                    page_size=self.psv.page_size, dtype=self.psv.cache_dtype)
                wrapped = shard_map(PG.scrub_pages, mesh=self.mesh,
                                    in_specs=(c_specs, P(), P()),
                                    out_specs=c_specs, check_vma=False)
                return jax.jit(wrapped, donate_argnums=(0,))
            return jax.jit(PG.scrub_pages, donate_argnums=(0,))

        return self._programs.get(cohort, "scrub",
                                  self.psv.pages_per_slot, build)

    # -- public API ----------------------------------------------------
    def add_request(self, prompt, max_new: int,
                    eos_token: Optional[int] = None,
                    deadline: Optional[int] = None) -> int:
        """Queue a request; returns its id. Submit-time validation
        (``Scheduler.submit``) rejects malformed work with typed
        ``InvalidRequestError``s; the engine adds the vocabulary-range
        check (only it knows the model) and the bounded-queue policy.

        ``deadline``: ABSOLUTE engine step by which the request must
        finish; at the first step boundary where ``step_count >= deadline``
        it is EXPIRED and releases everything. None = no deadline.
        """
        arr = np.asarray(prompt)
        if arr.size and np.issubdtype(arr.dtype, np.integer):
            vocab = self.ms.cfg.vocab_size
            if (arr < 0).any() or (arr >= vocab).any():
                raise InvalidRequestError(
                    f"prompt holds token ids outside [0, {vocab}): "
                    f"min={int(arr.min())}, max={int(arr.max())}")
        if self.psv.max_queue and self.sched.n_queued >= self.psv.max_queue:
            self._shed_for(deadline)
        eos = self.psv.eos_token if eos_token is None else eos_token
        r = self.sched.submit(prompt, max_new, eos,
                              deadline=-1 if deadline is None else deadline,
                              step=self.step_count)
        self._requests[r.rid] = r
        # Deferred chaos events that needed a submission to land on.
        if self._poison_next > 0:
            self._poison_next -= 1
            r.prompt = r.prompt.copy()
            r.prompt[r.rid % r.prompt_len] = self.ms.cfg.vocab_size + 1
            self._log_fault(F.POISON_PROMPT, rid=r.rid, deferred=True)
        if self._storm_next > 0:
            self._storm_next -= 1
            r.deadline = self.step_count
            self._log_fault(F.DEADLINE_STORM, rid=r.rid, deferred=True)
        return r.rid

    def cancel(self, rid: int) -> bool:
        """Client-initiated abort. True when the request was live (now
        CANCELLED, slot and pages released immediately); False when it had
        already reached a terminal state (results are whatever it produced
        first). Unknown rids raise KeyError."""
        r = self._requests[rid]
        if r.status in TERMINAL_STATES:
            return False
        slot = r.slot
        self.sched.cancel(r, self.step_count)
        if slot >= 0:
            self._clear_slot(slot)
        self.results[rid] = np.asarray(r.out, np.int32)
        return True

    def _shed_for(self, newcomer_deadline: Optional[int]) -> None:
        """Bounded-queue policy: the queue is full. Shed the queued request
        with the SLACKEST deadline if the newcomer is strictly more urgent;
        otherwise reject the newcomer (``QueueFullError``). No-deadline
        requests are infinitely slack, so any deadlined newcomer displaces
        one; a no-deadline newcomer never displaces anything."""
        inf = float("inf")
        nd = inf if newcomer_deadline is None else newcomer_deadline
        victim = max(self.sched.queue,
                     key=lambda r: (inf if r.deadline < 0 else r.deadline,
                                    r.rid))
        vd = inf if victim.deadline < 0 else victim.deadline
        if nd >= vd:
            raise QueueFullError(
                f"queue at max_queue={self.psv.max_queue} and no queued "
                f"request is slacker than the newcomer (deadline "
                f"{newcomer_deadline})")
        self.sched.expire(victim, self.step_count, error=LoadShedError(
            f"rid={victim.rid} (deadline {victim.deadline}) shed for a "
            f"more urgent arrival (deadline {newcomer_deadline})"))
        self.results[victim.rid] = np.asarray(victim.out, np.int32)

    # -- fault containment ---------------------------------------------
    def _clear_slot(self, slot: int) -> None:
        tok, pos, bt, lo = self._arrays(self._cohort_of_slot(slot))
        bt[slot - lo] = PG.GARBAGE_PAGE
        tok[slot - lo] = 0
        pos[slot - lo] = 0

    def _scrub_slot(self, r: Request, private: List[int]) -> None:
        cohort = self._cohort_of_slot(r.slot)
        _, _, _, lo = self._arrays(cohort)
        ids = np.full((self.psv.pages_per_slot,), PG.GARBAGE_PAGE, np.int32)
        ids[:len(private)] = private
        fn = self._scrub_fn(cohort)
        self._set_caches(cohort, fn(self._get_caches(cohort),
                                    jnp.asarray(ids),
                                    jnp.int32(r.slot - lo)))
        if self.spec_k and cohort == COHORT_MAIN:
            # The draft tree scattered the same (possibly poisoned)
            # request's kv into the same page ids — scrub it too before
            # the pages return to the free list.
            fn = self._scrub_fn(SP.COHORT_SPEC_DRAFT)
            self.caches_draft = fn(self.caches_draft, jnp.asarray(ids),
                                   jnp.int32(r.slot - lo))

    def _fail(self, r: Request, error, *, scrub: bool) -> None:
        """Contain a per-request fault: FAILED terminal state, slot row
        cleared, all pages released this step. The FAILED transition (and
        its counter) is the scheduler's ``fail`` — one increment site.
        ``scrub``: the request may have written non-finite values into its
        pages — zero its PRIVATE pages before they return to the free
        list, and purge its own radix donations (defense in depth; see
        PrefixCache.purge_pages)."""
        slot = r.slot
        if slot >= 0 and scrub:
            private = r.pages[r.n_shared:]
            if private:
                self._scrub_slot(r, private)
        donated = list(r.donated_pages)
        self.sched.fail(r, self.step_count, error)
        if slot >= 0:
            self._clear_slot(slot)
        if scrub and donated and self.prefix is not None:
            self.prefix.purge_pages(donated, self.pool)
        self.results[r.rid] = np.asarray(r.out, np.int32)

    def _expire_pass(self) -> None:
        """Deadlines are honored at step boundaries: any live request whose
        deadline has passed is EXPIRED and releases everything now."""
        sc = self.step_count
        for r in [x for x in list(self.sched.queue)
                  if 0 <= x.deadline <= sc]:
            self.sched.expire(r, sc)
            self.results[r.rid] = np.asarray(r.out, np.int32)
        for r in [x for x in list(self.sched.running.values())
                  if 0 <= x.deadline <= sc]:
            slot = r.slot
            self.sched.expire(r, sc)
            self._clear_slot(slot)
            self.results[r.rid] = np.asarray(r.out, np.int32)

    def _validate_block_tables(self) -> None:
        """Pre-launch guard: every running slot's host block-table row must
        be exactly its request's pages followed by garbage padding. A
        mismatch (cosmic ray, buggy host code, injected corruption) would
        make the decode gather read/write pages the request does not own —
        caught HERE, it costs one request instead of silently corrupting
        whichever request owns the foreign page."""
        P_slot = self.psv.pages_per_slot
        for slot, r in sorted(self.sched.running.items()):
            _, _, bt, lo = self._arrays(self._cohort_of_slot(slot))
            expect = np.full((P_slot,), PG.GARBAGE_PAGE, np.int32)
            expect[:len(r.pages)] = r.pages
            if not np.array_equal(bt[slot - lo], expect):
                self._fail(r, BlockTableCorruptionError(
                    f"rid={r.rid} slot {slot}: block-table row "
                    f"{bt[slot - lo].tolist()} != owned pages "
                    f"{r.pages}"), scrub=False)

    # -- chaos ----------------------------------------------------------
    def _log_fault(self, kind: str, *, rid: Optional[int] = None,
                   slot: Optional[int] = None, applied: bool = True,
                   deferred: bool = False) -> None:
        self.telemetry.fault(self.step_count, kind, rid=rid, slot=slot,
                             applied=applied, deferred=deferred)

    def _inject(self) -> None:
        """Apply this step's scheduled fault events. Victim selection is a
        pure function of the (deterministic) engine state, so a fixed
        (seed, workload) reproduces the exact same fault_log and results —
        the property the chaos gate asserts by running the plan twice."""
        for ev in self._plan.at(self.step_count):
            if ev.kind == F.PAGE_ALLOC_FAIL:
                self.pool.fail_next_allocs(ev.payload)
                self._log_fault(ev.kind)
            elif ev.kind == F.NAN_LOGITS:
                slots = sorted(self.sched.running)
                if not slots:
                    self._log_fault(ev.kind, applied=False)
                    continue
                slot = slots[ev.index % len(slots)]
                self._poison_slots.add(slot)
                self._log_fault(ev.kind, rid=self.sched.running[slot].rid,
                                slot=slot)
            elif ev.kind == F.BLOCK_TABLE_CORRUPT:
                slots = sorted(self.sched.running)
                if not slots:
                    self._log_fault(ev.kind, applied=False)
                    continue
                slot = slots[ev.index % len(slots)]
                r = self.sched.running[slot]
                _, _, bt, lo = self._arrays(self._cohort_of_slot(slot))
                col = ev.index % self.psv.pages_per_slot
                bt[slot - lo, col] = (int(bt[slot - lo, col]) + ev.payload) \
                    % self.psv.n_pages
                self._log_fault(ev.kind, rid=r.rid, slot=slot)
            elif ev.kind == F.POISON_PROMPT:
                queued = [q for q in self.sched.queue]
                if not queued:
                    self._poison_next += 1
                    self._log_fault(ev.kind, applied=False, deferred=True)
                    continue
                r = queued[ev.index % len(queued)]
                r.prompt = r.prompt.copy()
                r.prompt[ev.index % r.prompt_len] = \
                    self.ms.cfg.vocab_size + ev.payload
                self._log_fault(ev.kind, rid=r.rid)
            elif ev.kind == F.DEADLINE_STORM:
                queued = [q for q in self.sched.queue][:ev.payload]
                if not queued:
                    self._storm_next += ev.payload
                    self._log_fault(ev.kind, applied=False, deferred=True)
                    continue
                for r in queued:
                    r.deadline = self.step_count
                    self._log_fault(ev.kind, rid=r.rid)

    # -- per-request device work ----------------------------------------
    def _run_prefill(self, r: Request, ctx: int) -> Tuple[int, bool]:
        """Stage-1 forward over the unmatched prompt suffix (the full
        prompt when ctx == 0). Returns (token sampled from the last prompt
        position's logits, finite-guard flag)."""
        ps = self.psv.page_size
        Lp = r.prompt_len
        n_pg_prompt = -(-Lp // ps)
        cohort = r.cohort
        caches = self._get_caches(cohort)
        params = self._model(cohort)[0]
        _, _, _, lo = self._arrays(cohort)
        slot = jnp.int32(r.slot - lo)
        self._key, sub = jax.random.split(self._key)
        if ctx == 0:
            fn = self._prefill_fn(Lp, cohort)
            tok0, ok, caches = fn(
                params, caches, jnp.asarray(r.prompt[None]),
                jnp.asarray(r.pages[:n_pg_prompt], jnp.int32), slot, sub)
            self.counters["prefill_tokens"] += Lp
            self.counters["full_prefills"] += 1
        else:
            m = ctx // ps
            Ls = Lp - ctx
            fn = self._programs.get(COHORT_MAIN, "prefill_suffix", (m, Ls),
                                    lambda: self._suffix_fn(m, Ls))
            tok0, ok, caches = fn(
                params, caches, jnp.asarray(r.prompt[None, ctx:]),
                jnp.asarray(r.pages[:m], jnp.int32),
                jnp.asarray(r.pages[m:n_pg_prompt], jnp.int32), slot, sub)
            self.counters["prefill_tokens"] += Ls
            self.counters["suffix_prefills"] += 1
        self._set_caches(cohort, caches)
        return int(tok0[0]), bool(ok)

    def _replay(self, r: Request, start: int) -> bool:
        """Resume catch-up: teacher-force the parked generated tokens whose
        kv fell outside the surviving radix prefix through the REGULAR
        decode program (all other slots masked to the garbage page, their
        rows ignored). Position p re-runs the exact computation that
        produced it originally — same program, same token, same kv bits —
        so with greedy sampling the replayed prediction must reproduce the
        parked token, which the engine asserts (the continuous form of the
        preempt-resume bit-identity gate). Returns False if the finite
        guard trips mid-replay (the caller fails the request).

        Recurrent state (mamba/rec conv/h) needs explicit protection: the
        masked slots' ATTENTION writes land on the garbage page, but the
        decode program advances EVERY slot's state each call — replay
        would corrupt concurrently running requests. The engine snapshots
        the state entries before replaying and restores every row except
        the replaying slot's afterwards (their true timeline has no step
        here)."""
        cohort = r.cohort
        tok_a, pos_a, bt_a, lo = self._arrays(cohort)
        size = tok_a.shape[0]
        loc = r.slot - lo
        decode = self._decode_fn(cohort)
        params = self._model(cohort)[0]
        Lp = r.prompt_len
        end = Lp + len(r.out) - 1      # exclusive; kv for end-1 is the
        if start >= end:               # resumed decode step's own write
            return True
        self.telemetry.span_event(r.rid, REPLAY, self.step_count,
                                  tokens=end - start)
        caches = self._get_caches(cohort)
        state_saved = [
            {name: np.asarray(v) for name, v in seg.items()
             if not PG.is_paged_entry(name)} for seg in caches]
        no_poison = jnp.zeros((size,), jnp.bool_)
        survived = True
        for p in range(start, end):
            tok_v = np.zeros((size,), np.int32)
            pos_v = np.zeros((size,), np.int32)
            bt = np.full_like(bt_a, PG.GARBAGE_PAGE)
            tok_v[loc] = r.out[p - Lp]
            pos_v[loc] = p
            bt[loc] = bt_a[loc]
            self._key, sub = jax.random.split(self._key)
            nxt, ok, caches = decode(
                params, caches, jnp.asarray(tok_v),
                jnp.asarray(pos_v), jnp.asarray(bt), no_poison, sub)
            if not bool(np.asarray(ok)[loc]):
                survived = False
                break
            if self._exact:
                got = int(np.asarray(nxt)[loc])
                assert got == r.out[p - Lp + 1], (
                    f"replay divergence at pos {p}: {got} != "
                    f"{r.out[p - Lp + 1]} (rid={r.rid})")
            self.counters["replay_tokens"] += 1
        for seg, saved in zip(caches, state_saved):
            for name, host in saved.items():
                sl = (slice(None),) * T.cache_batch_axis(name) + (loc,)
                merged = host.copy()
                merged[sl] = np.asarray(seg[name])[sl]
                # Re-place at the entry's current sharding: under a mesh the
                # state entries are model-sharded and a bare jnp.asarray
                # would silently collapse them onto one device.
                seg[name] = jax.device_put(merged, seg[name].sharding)
        self._set_caches(cohort, caches)
        return survived

    def _spec_prime(self, r: Request) -> None:
        """Warm the DRAFT cache tree for a freshly-started request: a full
        prompt prefill at the aggressive plan, then teacher-forced
        catch-up over any parked generated tokens (the resume path).

        Always the FULL prompt, even on a radix hit: draft kv has no page
        representation in the radix tree (its bits are plan-specific), but
        re-deriving it over shared pages is idempotent — same tokens at
        the same positions produce the same draft bits — which is why
        speculation composes with the prefix cache. Quality-only work:
        the verify launch reads the MAIN tree, so nothing here can move
        committed output, and the finite guards are ignored for the same
        reason (non-finite draft kv yields garbage proposals the verifier
        simply refuses)."""
        ps = self.psv.page_size
        Lp = r.prompt_len
        _, _, bt_a, lo = self._arrays(COHORT_MAIN)
        loc = r.slot - lo
        if r.rid in self._spec_primed:
            # The bucketed admission pass already primed the draft tree
            # through a mirrored draft-cohort group launch — only the
            # resume catch-up below remains.
            self._spec_primed.discard(r.rid)
        else:
            fn = self._spec_prefill_fn(Lp)
            self._key, sub = jax.random.split(self._key)
            _, _, self.caches_draft = fn(
                self.params_draft, self.caches_draft,
                jnp.asarray(r.prompt[None]),
                jnp.asarray(r.pages[:-(-Lp // ps)], jnp.int32),
                jnp.int32(loc), sub)
        # Resume catch-up: feed each parked token at its position through
        # the draft program (single active row, garbage-masked peers —
        # the _replay pattern), outputs ignored. No state snapshots
        # needed: speculation is attention-only.
        size = bt_a.shape[0]
        no_poison = jnp.zeros((size,), jnp.bool_)
        for p in range(Lp, Lp + len(r.out) - 1):
            tok_v = np.zeros((size,), np.int32)
            pos_v = np.zeros((size,), np.int32)
            bt = np.full_like(bt_a, PG.GARBAGE_PAGE)
            tok_v[loc] = r.out[p - Lp]
            pos_v[loc] = p
            bt[loc] = bt_a[loc]
            self._key, sub = jax.random.split(self._key)
            _, _, self.caches_draft = self._draft_decode_fn()(
                self.params_draft, self.caches_draft, jnp.asarray(tok_v),
                jnp.asarray(pos_v), jnp.asarray(bt), no_poison, sub)

    def _start(self, r: Request,
               pre: Optional[Tuple[int, bool]] = None) -> bool:
        """Bring an admitted request onto its slot: link its block table,
        consume the bucketed-prefill result planned for it (``pre``) or
        run the stage-1 prefill itself (full / suffix / skipped when the
        radix hit covers the whole prompt), and for resumed requests
        replay the parked generated positions. Returns False when a fault
        guard FAILED the request (admission rolled back: slot and pages
        already released). The device-boundary prompt guard ran in
        ``_plan_prefills`` — every request reaching here has in-vocab
        tokens."""
        ps = self.psv.page_size
        ctx = r.n_shared * ps
        Lp = r.prompt_len
        resumed = bool(r.out)
        tok_a, pos_a, bt_a, lo = self._arrays(r.cohort)
        row = bt_a[r.slot - lo]
        row[:] = PG.GARBAGE_PAGE
        row[:len(r.pages)] = r.pages
        # hit_tokens counts PROMPT tokens served from shared pages on FRESH
        # admissions only (a fresh match is prompt-only by the _match_cap);
        # a preemption resume re-linking its own donation is real savings
        # too but a different phenomenon — tracked under resume_hit_tokens
        # so hit_rate stays "prompt prefill work avoided by sharing".
        if resumed:
            self.counters["resume_hit_tokens"] += ctx
        else:
            self.counters["hit_tokens"] += ctx
            if ctx:
                self.counters["prefix_hits"] += 1
        if ctx < Lp:
            self.telemetry.span_event(
                r.rid, PREFILL, self.step_count,
                kind="full" if ctx == 0 else "suffix",
                hit_tokens=ctx, tokens=Lp - ctx, batched=pre is not None)
            if pre is not None:
                tok0, ok = pre
                self.counters["prefill_tokens"] += Lp - ctx
                self.counters["suffix_prefills" if ctx
                              else "full_prefills"] += 1
                self.counters["bucket_prefills"] += 1
            else:
                tok0, ok = self._run_prefill(r, ctx)
            if not ok:
                # The prefill may have scattered non-finite kv into the
                # request's pages before the guard was read — scrub.
                self._fail(r, NonFiniteLogitsError(
                    f"rid={r.rid}: non-finite logits/cache in prefill"),
                    scrub=True)
                return False
            if not resumed:
                r.out.append(tok0)
                self.telemetry.first_token(r.rid, self.step_count)
            elif self._exact:
                # Same program + same inputs as the original prefill: the
                # re-sampled first token must reproduce the parked one.
                assert tok0 == r.out[0], (tok0, r.out[0], r.rid)
        # Early donation: the prompt pages are complete now — concurrent
        # same-prefix requests admitted from the NEXT step on can share
        # them without waiting for this request to finish. (No-op for the
        # degraded cohort: its pages hold aggressive-plan bits.)
        self.sched.donate_prefilled(r, self.step_count)
        if resumed:
            if not self._replay(r, max(Lp, ctx)):
                self._fail(r, NonFiniteLogitsError(
                    f"rid={r.rid}: non-finite logits during decode replay"),
                    scrub=True)
                return False
        if self.spec_k:
            self._spec_prime(r)
        tok_a[r.slot - lo] = r.out[-1]
        pos_a[r.slot - lo] = r.pos
        return True

    def _finish(self, r: Request) -> None:
        slot = r.slot
        self.sched.finish(r, self.step_count)
        self._clear_slot(slot)
        self.results[r.rid] = np.asarray(r.out, np.int32)

    def _plan_prefills(self, admitted: List[Request]
                       ) -> Dict[int, Tuple[int, bool]]:
        """Pass 1 of admission: vocab-guard every admitted request, then
        pack the bucket-eligible prefills into (cohort, bucket) groups and
        launch each group ONCE. Returns rid -> (first token, finite-ok)
        for every request whose prefill ran batched; pass 2 (``_start``)
        consumes those instead of launching per request.

        Eligibility: the ladder is on, the request still has suffix
        tokens to compute (a full-prompt radix cover skips prefill
        entirely), and a rung holds the SUFFIX length. Radix-hit rows
        ride the same launch as cold rows through the ctx-aware bucket
        program (``_bucket_ctx_pages``): each row carries its own ctx
        pages + ctx length, cold rows pass zero ctx. Resumed re-prefills
        qualify too: the padded batched forward is bit-equal to the exact
        program, so the resume bit-identity assert still holds."""
        pre: Dict[int, Tuple[int, bool]] = {}
        ps = self.psv.page_size
        vocab = self.ms.cfg.vocab_size
        groups: Dict[Tuple[str, int], List[Request]] = {}
        for r in admitted:
            # Device-boundary prompt guard: submit-time validation ran,
            # but the prompt may have been corrupted since (the
            # poisoned-prompt chaos kind models a tokenizer/host bug). An
            # out-of-vocab id would index the embedding out of range —
            # fail the request, not the engine (and never launch a batch
            # holding it).
            if (r.prompt < 0).any() or (r.prompt >= vocab).any():
                self._fail(r, PoisonedPromptError(
                    f"rid={r.rid}: prompt token ids outside [0, {vocab}) "
                    f"at admission (min={int(r.prompt.min())}, "
                    f"max={int(r.prompt.max())})"), scrub=False)
                continue
            if not self._buckets:
                continue
            Ls = r.prompt_len - r.n_shared * ps
            if Ls <= 0:
                continue   # radix cover reaches the prompt: replay only
            b = BK.bucket_for(Ls, self._buckets)
            if b is not None:
                groups.setdefault((r.cohort, b), []).append(r)
        for (cohort, b), grp in sorted(groups.items()):
            pre.update(self._launch_bucket(cohort, b, grp))
        return pre

    def _launch_bucket(self, cohort: str, bucket: int, grp: List[Request]
                       ) -> Dict[int, Tuple[int, bool]]:
        """One bucket group: right-pad each row's SUFFIX (the full prompt
        when cold) to ``bucket``, launch chunks of the program's fixed row
        count (short chunks pad with inert rows: zero prompts, all-garbage
        page ids), slice each row's logits at its true length, and mask
        the page scatter so pad rows and pad pages write nothing. Under a
        ctx-aware program radix-hit rows additionally carry their matched
        ctx pages (garbage-padded to the uniform width) and ctx length."""
        ps = self.psv.page_size
        cohort_slots = self.n_main if cohort == COHORT_MAIN else self.n_deg
        rows = BK.rows_for_bucket(bucket, cohort_slots,
                                  self.psv.prefill_token_budget)
        ctx_pages = self._bucket_ctx_pages(cohort)
        fn = self._bucket_prefill_fn(bucket, rows, cohort)
        # Speculative mirror: the SAME group through the draft-plan
        # program warms the draft tree (quality-only — outputs ignored,
        # the trees are independent, and _spec_prime skips its own full
        # prefill for rids primed here). Radix-HIT rows are masked inert
        # in the mirror and NOT marked primed: the draft tree needs the
        # full prompt (its kv has no radix representation), so
        # _spec_prime runs their full-prompt draft prefill instead.
        draft_fn = (self._bucket_prefill_fn(bucket, rows,
                                            SP.COHORT_SPEC_DRAFT)
                    if self.spec_k and cohort == COHORT_MAIN else None)
        caches = self._get_caches(cohort)
        n_pg = bucket // ps
        out: Dict[int, Tuple[int, bool]] = {}
        for i0 in range(0, len(grp), rows):
            chunk = grp[i0:i0 + rows]
            prompts = np.zeros((rows, bucket), np.int32)
            true_lens = np.ones((rows,), np.int32)
            page_ids = np.full((rows, n_pg), PG.GARBAGE_PAGE, np.int32)
            ctx_ids = np.full((rows, ctx_pages), PG.GARBAGE_PAGE, np.int32)
            ctx_lens = np.zeros((rows,), np.int32)
            for i, r in enumerate(chunk):
                m = r.n_shared
                Ls = r.prompt_len - m * ps
                prompts[i, :Ls] = r.prompt[m * ps:]
                true_lens[i] = Ls
                npg = -(-r.prompt_len // ps)
                page_ids[i, :npg - m] = r.pages[m:npg]
                if m:
                    assert ctx_pages, (cohort, m)
                    ctx_ids[i, :m] = r.pages[:m]
                    ctx_lens[i] = m * ps
            self._key, sub = jax.random.split(self._key)
            if draft_fn is not None:
                hit = ctx_lens > 0
                d_prompts = np.where(hit[:, None], 0, prompts)
                d_lens = np.where(hit, 1, true_lens).astype(np.int32)
                d_pages = np.where(hit[:, None], PG.GARBAGE_PAGE,
                                   page_ids).astype(np.int32)
                _, _, self.caches_draft = draft_fn(
                    self.params_draft, self.caches_draft,
                    jnp.asarray(d_prompts), jnp.asarray(d_lens),
                    jnp.asarray(d_pages), sub)
            args = [jnp.asarray(prompts), jnp.asarray(true_lens),
                    jnp.asarray(page_ids)]
            if ctx_pages:
                args += [jnp.asarray(ctx_ids), jnp.asarray(ctx_lens)]
            tok0, ok, caches = fn(
                self._model(cohort)[0], caches, *args, sub)
            tok0, ok = np.asarray(tok0), np.asarray(ok)
            for i, r in enumerate(chunk):
                out[r.rid] = (int(tok0[i]), bool(ok[i]))
                if draft_fn is not None and not r.n_shared:
                    self._spec_primed.add(r.rid)
            self.counters["bucket_groups"] += 1
            self.counters["pad_tokens"] += rows * bucket - sum(
                r.prompt_len - r.n_shared * ps for r in chunk)
        self._set_caches(cohort, caches)
        return out

    def _admit(self, *, count_blocked: bool) -> None:
        degrade = (self.psv.degrade_delta
                   and self.sched.n_queued >= self.psv.degrade_queue_depth)
        admitted = self.sched.admit(self.step_count,
                                    count_blocked=count_blocked,
                                    degrade=degrade)
        pre = self._plan_prefills(admitted)
        for r in admitted:
            if r.status in TERMINAL_STATES:
                continue          # failed by the pass-1 vocab guard
            if r.cohort == COHORT_DEGRADED and not r.preemptions:
                self.counters["degraded_admissions"] += 1
            if not self._start(r, pre.get(r.rid)):
                continue
            # "admitted" counts requests that SURVIVED admission (slot
            # linked, prefill guards passed) — a request failed by a guard
            # inside _start counts under "failed" only.
            self.counters["admitted"] += 1
            if r.done():      # max_new == 1 (or instant EOS) on prefill
                self._finish(r)
            else:
                self.telemetry.span_event(r.rid, DECODE, self.step_count)

    def _decode_cohort(self, cohort: str) -> None:
        tok_a, pos_a, bt_a, lo = self._arrays(cohort)
        size = tok_a.shape[0]
        running = {s: r for s, r in self.sched.running.items()
                   if lo <= s < lo + size}
        if not running:
            return
        poison = np.zeros((size,), bool)
        for s in self._poison_slots:
            if lo <= s < lo + size:
                poison[s - lo] = True
        self._key, sub = jax.random.split(self._key)
        prof = (jax.profiler.StepTraceAnnotation(
                    f"paged_decode_{cohort}", step_num=self.step_count)
                if self.psv.profile_decode else contextlib.nullcontext())
        with prof:
            nxt, ok, caches = self._decode_fn(cohort)(
                self._model(cohort)[0], self._get_caches(cohort),
                jnp.asarray(tok_a), jnp.asarray(pos_a), jnp.asarray(bt_a),
                jnp.asarray(poison), sub)
        self._set_caches(cohort, caches)
        nxt = np.asarray(nxt)
        ok = np.asarray(ok)
        for slot, r in sorted(running.items()):
            loc = slot - lo
            if not bool(ok[loc]):
                # Non-finite logits on this row only: the decode step wrote
                # this slot's kv from finite inputs EXCEPT possibly under
                # real numeric poison, so scrub its private pages on the
                # way out; every other row is untouched (row independence).
                self._fail(r, NonFiniteLogitsError(
                    f"rid={r.rid}: non-finite logits in decode at step "
                    f"{self.step_count} (slot {slot})"),
                    scrub=True)
                continue
            r.out.append(int(nxt[loc]))
            tok_a[loc] = nxt[loc]
            pos_a[loc] += 1
            self.counters["decoded"] += 1
            if r.done():
                self._finish(r)

    def _rewind_pages(self, pairs: List[Tuple[int, int]]) -> None:
        """Un-write rejected speculative positions in BOTH cache trees.
        Fixed shape: at most ``n_main * spec_k`` positions can reject per
        step, padded with ``(GARBAGE_PAGE, 0)`` (paged_cache.rewind_tokens)
        so one compiled program per tree serves every episode."""
        cap = self.n_main * self.spec_k
        assert len(pairs) <= cap, (len(pairs), cap)
        pages = np.zeros((cap,), np.int32)
        offs = np.zeros((cap,), np.int32)
        for i, (p, o) in enumerate(pairs):
            pages[i], offs[i] = p, o
        rewind = self._programs.get(
            SP.COHORT_SPEC_VERIFY, "rewind", cap,
            lambda: jax.jit(PG.rewind_tokens, donate_argnums=(0,)))
        pg, of = jnp.asarray(pages), jnp.asarray(offs)
        self.caches = rewind(self.caches, pg, of)
        self.caches_draft = rewind(self.caches_draft, pg, of)

    def _decode_spec(self) -> None:
        """Speculative main-cohort step: ONE fused ``spec_k``-step draft
        episode launch at the aggressive plan, ONE full-depth verify
        launch at batch
        ``n_main * (spec_k + 1)``, host-side acceptance, then an un-write
        of every rejected position (serve.speculative has the math and
        the soundness argument). Replaces ``_decode_cohort(COHORT_MAIN)``
        when spec_k > 0; greedy streams are bit-identical to it because
        every committed token is a full-depth argmax over an
        exactly-committed history computed by the same decode body."""
        tok_a, pos_a, bt_a, lo = self._arrays(COHORT_MAIN)
        size = tok_a.shape[0]
        running = {s: r for s, r in self.sched.running.items()
                   if lo <= s < lo + size}
        if not running:
            return
        k = self.spec_k
        remaining = np.full((size,), -1, np.int64)
        for s, r in running.items():
            remaining[s - lo] = r.max_new - len(r.out)
        poison = np.zeros((size,), bool)
        for s in self._poison_slots:
            if lo <= s < lo + size:
                poison[s - lo] = True
        # One fused launch runs the whole episode: k greedy draft
        # proposals per slot at the aggressive plan (each internal step
        # feeds the previous proposal at the next position), the probe-
        # row packing, and every slot's k+1 rows through ONE regular
        # full-depth decode (launches-per-verify == 1 — the
        # spec-structural gate). make_spec_step_fn is the device-side
        # twin of speculative.build_draft_step/build_verify_batch.
        # Draft rows are never poisoned — poison targets the slot's
        # COMMITTED stream, which only the verify rows can move.
        self._key, sub = jax.random.split(self._key)
        prof = (jax.profiler.StepTraceAnnotation(
                    "paged_decode_spec_step", step_num=self.step_count)
                if self.psv.profile_decode else contextlib.nullcontext())
        with prof:
            d, yhat, ok, self.caches_draft, self.caches = self._spec_step(
                self.params_draft, self.params, self.caches_draft,
                self.caches, jnp.asarray(tok_a), jnp.asarray(pos_a),
                jnp.asarray(bt_a), jnp.asarray(poison),
                jnp.asarray(remaining.astype(np.int32)), sub)
        drafts = np.asarray(d)
        self.counters["draft_steps"] += k
        self.counters["verify_steps"] += 1
        yhat = np.asarray(yhat).reshape(size, k + 1)
        okm = np.asarray(ok).reshape(size, k + 1)
        zero_pairs: List[Tuple[int, int]] = []
        for slot, r in sorted(running.items()):
            loc = slot - lo
            rem = int(remaining[loc])
            j_hi = min(k, rem)
            if not okm[loc, :j_hi + 1].all():
                # Any live probe row non-finite fails the slot (the
                # non-spec engine's containment semantics: a poisoned
                # slot emits no token); peers are untouched by row
                # independence. Scrub covers the draft tree too.
                self._fail(r, NonFiniteLogitsError(
                    f"rid={r.rid}: non-finite logits in speculative "
                    f"verify at step {self.step_count} (slot {slot})"),
                    scrub=True)
                continue
            p0 = int(pos_a[loc])
            a_max = min(k, rem - 1)
            a = SP.accept_length(drafts[:, loc], yhat[loc], a_max)
            self.counters["spec_accepted"] += a
            self.counters["spec_rejected"] += a_max - a
            committed = 0
            for t in SP.commit_tokens(drafts[:, loc], yhat[loc], a):
                r.out.append(t)
                committed += 1
                self.counters["decoded"] += 1
                if r.done():     # EOS can cut inside the accepted run
                    break
            self.telemetry.observe("spec_accept", committed)
            self.telemetry.spec_episode(self.step_count, slot, r.rid,
                                        probed=a_max, accepted=a,
                                        committed=committed)
            if r.done():
                self._finish(r)
                continue
            tok_a[loc] = r.out[-1]
            pos_a[loc] = r.pos
            start, stop = SP.stale_span(p0, a, j_hi)
            if start < stop:
                zero_pairs += PG.rewind_plan(
                    r.pages, r.n_shared, start, stop,
                    self.psv.page_size)[0]
        if zero_pairs:
            self._rewind_pages(zero_pairs)
            self.counters["spec_rewound"] += len(zero_pairs)

    def _step_gauges(self, hit0: int, faults0: Dict[str, int]) -> None:
        """Per-step gauge samples, taken AFTER the step's work: queue
        depth, pool live/free/refcount-shared pages, per-step radix hit
        tokens (fresh + resume), per-cohort slot occupancy, and faults by
        kind (only steps where a kind fired emit a sample). All pure host
        reads — no device work."""
        tel, sc = self.telemetry, self.step_count
        tel.gauge("queue_depth", sc, self.sched.n_queued)
        tel.gauge("pages_live", sc, self.pool.live)
        tel.gauge("pages_free", sc, self.pool.n_free)
        tel.gauge("pages_shared", sc, self.pool.shared)
        tel.gauge("hit_tokens_step", sc,
                  tel.counters["hit_tokens"]
                  + tel.counters["resume_hit_tokens"] - hit0)
        n_run_main = sum(1 for s in self.sched.running if s < self.n_main)
        tel.gauge(f"slots_live/{COHORT_MAIN}", sc, n_run_main)
        if self.n_deg:
            tel.gauge(f"slots_live/{COHORT_DEGRADED}", sc,
                      self.sched.n_running - n_run_main)
        for kind, n in tel.fault_counts.items():
            d = n - faults0.get(kind, 0)
            if d:
                tel.gauge(f"faults/{kind}", sc, d)

    def step(self) -> Dict[str, int]:
        """One engine iteration: chaos injection (when armed) -> deadline
        expiry -> admission+prefill (with blocked-head preemption when
        enabled) -> block-table validation -> one decode program per active
        cohort. Returns the step's lifecycle event counts — computed as
        telemetry counter DELTAS over the step, so there is exactly one
        increment site per event and the per-step view can never drift
        from the monotone totals."""
        tel = self.telemetry
        before = {k: tel.counters[k] for k in self.STEP_STAT_KEYS}
        hit0 = tel.counters["hit_tokens"] + tel.counters["resume_hit_tokens"]
        faults0 = dict(tel.fault_counts)
        if self._plan is not None:
            self._inject()
        self._expire_pass()
        self._admit(count_blocked=True)
        if self.sched.should_preempt():
            _victim, slot = self.sched.preempt_youngest(self.step_count)
            self._clear_slot(slot)
            # The freed pages/slot may unblock the head immediately.
            self._admit(count_blocked=False)
        self._validate_block_tables()
        if self.spec_k:
            self._decode_spec()
        else:
            self._decode_cohort(COHORT_MAIN)
        if self.n_deg:
            self._decode_cohort(COHORT_DEGRADED)
        self._poison_slots.clear()
        self.pool.check_balance()
        if self.prefix is not None:
            self.prefix.check_locks()
        self._step_gauges(hit0, faults0)
        tel.mark_step(self.step_count)
        self.step_count += 1
        stats = {k: tel.counters[k] - before[k] for k in self.STEP_STAT_KEYS}
        stats["live_pages"] = self.pool.live
        return stats

    def drain(self) -> Dict[int, np.ndarray]:
        """Step until every submitted request reached a TERMINAL state;
        returns {rid: generated tokens}. Backwards-compatible: the dict
        maps every rid (including FAILED/CANCELLED/EXPIRED, whose value is
        the partial output produced before termination) — per-request
        status is ``engine.request(rid).state`` and the typed error
        ``engine.request(rid).error``. Cancelling or expiring mid-flight
        can therefore never hang the drain: terminal requests leave the
        queue/running sets the step they terminate."""
        while self.sched.n_queued or self.sched.n_running:
            self.step()
        return dict(self.results)

    @property
    def occupancy(self) -> float:
        """Fraction of allocatable cache pages currently live."""
        return self.pool.live / max(self.psv.n_pages - 1, 1)

    def request(self, rid: int) -> Request:
        return self._requests[rid]

    # -- telemetry exporters -------------------------------------------
    def metrics_snapshot(self) -> Dict[str, Any]:
        """JSON-able metrics snapshot: counters, last-value gauges,
        histograms, compile events, fault counts, request-state census,
        span-derived latency (step percentiles + ``wall`` ms annotations),
        prefix hit rate, pool accounting, and pool-occupancy series stats.
        Everything outside ``wall*`` keys is a pure function of the
        step-denominated event stream (same-seed runs snapshot
        identically once wall fields are stripped)."""
        snap = self.telemetry.snapshot(step=self.step_count)
        snap["pool"] = {
            "allocated_total": self.pool.allocated_total,
            "freed_total": self.pool.freed_total,
            "shared_total": self.pool.shared_total,
            "alloc_faults": self.pool.alloc_faults,
            "live": self.pool.live,
        }
        cap = max(self.psv.n_pages - 1, 1)
        series = self.telemetry.gauge_series.get("pages_live", [])
        if series:
            vals = [v for _, v in series]
            snap["occupancy"] = {
                "mean": round(sum(vals) / len(vals) / cap, 3),
                "max": round(max(vals) / cap, 3),
            }
        snap["preemptions"] = self.sched.preemptions_total
        if self.spec_k:
            c = self.telemetry.counters
            probed = c["spec_accepted"] + c["spec_rejected"]
            # One histogram observation per slot per verify = one episode;
            # its mean is committed tokens per full-depth verification of
            # a slot — the speedup lever (> 1 means each full-depth pass
            # commits more than a one-token step would).
            h = self.telemetry.hists.get("spec_accept")
            snap["spec"] = {
                "k": self.spec_k,
                "draft_eff_depth": self.ms_draft.effective_depth,
                "accept_per_verify": round(h.sum / h.count, 3)
                                     if h and h.count else 0.0,
                "accept_rate": round(c["spec_accepted"] / probed, 3)
                               if probed else 0.0,
            }
        return snap

    def metrics_text(self) -> str:
        """Prometheus-style text exposition of the scalar channels."""
        return self.telemetry.prom_text()

    def dump_trace(self, path: str) -> str:
        """Write the Chrome/Perfetto ``trace_event`` JSON for this run
        (repro.serve.trace). Needs spans/gauge series, so the engine must
        run with ``telemetry=True`` (the default)."""
        return write_trace(self.telemetry, path, n_slots=self.psv.n_slots)


# ---------------------------------------------------------------------------
# Sharded wrappers (mesh execution + dry-run lowering)
# ---------------------------------------------------------------------------

def _tree_shardings(mesh, pspecs):
    """PartitionSpec tree -> NamedSharding tree (P is a tuple: need is_leaf)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(ms: T.ModelStructure, *, batch: int, sv: ServeConfig,
                 pc: ParallelContext, shard_batch: bool = True):
    """(abstract, pspec) for the global cache; batch sharded over dp when
    ``shard_batch`` (batch==1 long-context cells replicate it)."""
    abs_, ps_ = T.cache_meta(ms, batch=batch, max_len=sv.max_len,
                             kv_mode=sv.kv_mode, dtype=sv.cache_dtype)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None

    def add_dp(path, spec):
        # Shard the batch axis over dp: axis 1 for per-layer entries
        # ([count, batch, ...]), axis 2 for stacked pair entries
        # ([count, 2, batch, ...]) — see T.cache_batch_axis.
        parts = list(spec)
        parts[T.cache_batch_axis(path[-1].key)] = dp_ax
        return P(*parts)

    ps2 = jax.tree_util.tree_map_with_path(
        add_dp, ps_, is_leaf=lambda x: isinstance(x, P))
    return abs_, ps2


def make_sharded_serve_step(ms: T.ModelStructure, mesh, sv: ServeConfig,
                            *, batch: int, shard_batch: bool = True,
                            paged: Optional[PagedServeConfig] = None):
    """jit(shard_map(serve_step)) + its in/out specs, for execution and the
    decode-shape dry-run.

    ``paged`` threads the continuous-batching engine's pool through the
    same wrapper: the local step becomes the paged decode (params, caches,
    tok, pos, block_tables, poison, key) -> (next_tok, ok, caches) with
    the pool's pspecs from ``paged_cache_meta`` (kv-head axis over
    "model", everything else replicated) and tok/pos/block tables/poison
    replicated — host-side scheduling is tp-agnostic, so the ONLY sharded
    state is the pool itself; the finite flag ``ok`` is pmax-reduced over
    tp inside the step so its replicated out-spec holds. ``sv`` may be
    None in that mode; ``batch`` is the slot count.
    """
    if paged is not None:
        pc = make_context(mesh, sp=False)
        local = make_paged_decode_fn(ms, pc, paged)
        p_specs = T.param_pspecs(ms)
        c_abs, c_specs = PG.paged_cache_meta(
            ms, n_slots=batch, n_pages=paged.n_pages,
            page_size=paged.page_size, dtype=paged.cache_dtype)
        wrapped = shard_map(
            local, mesh=mesh,
            in_specs=(p_specs, c_specs, P(), P(), P(), P(), P()),
            out_specs=(P(), P(), c_specs),
            check_vma=False)
        return jax.jit(wrapped, donate_argnums=(1,)), c_abs, c_specs, pc
    pc = make_context(mesh, sp=False)
    local = make_serve_step(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    c_abs, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc,
                                  shard_batch=shard_batch)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None
    tok_spec = P(dp_ax)
    wrapped = shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, tok_spec, c_specs, P(), P()),
        out_specs=(tok_spec, c_specs),
        check_vma=False)
    return jax.jit(wrapped, donate_argnums=(2,)), c_abs, c_specs, pc


def make_sharded_prefill(ms: T.ModelStructure, mesh, sv: ServeConfig,
                         *, batch: int, prompt_len: int, sp: bool = True,
                         paged: Optional[PagedServeConfig] = None,
                         paged_slots: Optional[int] = None,
                         bucket_rows: Optional[int] = None,
                         bucket_ctx_pages: int = 0,
                         suffix_ctx_pages: Optional[int] = None):
    """jit(shard_map(prefill)) for the ring cache (default), or — with
    ``paged`` — the engine's exact-length prefill + page scatter: the
    forward runs replicated over the sequence (sp off: prompt lengths are
    exact, not tp-multiples), each rank scatters its LOCAL kv-head shard
    of the emitted pages into its pool shard, and page ids/slot stay
    host-side and tp-agnostic. ``paged_slots`` overrides the cache tree's
    slot count (cohort-partitioned engines build per-cohort trees).
    ``bucket_rows``: build the BUCKETED batched prefill instead —
    ``prompt_len`` is the bucket width and the program takes
    ``[bucket_rows, prompt_len]`` right-padded prompts plus per-row true
    lengths and page-id rows; ``bucket_ctx_pages > 0`` adds the per-row
    ctx operands (radix-hit rows ride the bucket — the ctx gather and
    per-rank fold run inside shard_map over each rank's pool shard).
    ``suffix_ctx_pages``: build the exact-shape SUFFIX prefill instead —
    ``prompt_len`` is the suffix length. Every non-tree operand is
    replicated (P()), so the spec count just follows the local program's
    arity. Returns (fn, cache_pspecs, pc)."""
    if paged is not None:
        pc = make_context(mesh, sp=False)
        if suffix_ctx_pages is not None:
            local = make_paged_suffix_prefill_fn(
                ms, pc, paged, suffix_ctx_pages, prompt_len)
            n_rep = 5   # suffix, ctx_ids, sfx_ids, slot, key
        elif bucket_rows is not None:
            local = make_paged_bucket_prefill_fn(
                ms, pc, paged, prompt_len, bucket_rows, bucket_ctx_pages)
            # prompts, true_lens, page_ids, [ctx_ids, ctx_lens,] key
            n_rep = 4 + (2 if bucket_ctx_pages else 0)
        else:
            local = make_paged_prefill_fn(ms, pc, paged, prompt_len)
            n_rep = 4   # prompt, page_ids, slot, key
        p_specs = T.param_pspecs(ms)
        _, c_specs = PG.paged_cache_meta(
            ms, n_slots=paged_slots or paged.n_slots, n_pages=paged.n_pages,
            page_size=paged.page_size, dtype=paged.cache_dtype)
        wrapped = shard_map(
            local, mesh=mesh,
            in_specs=(p_specs, c_specs) + (P(),) * n_rep,
            out_specs=(P(), P(), c_specs),
            check_vma=False)
        return jax.jit(wrapped, donate_argnums=(1,)), c_specs, pc
    pc = make_context(mesh, sp=sp)
    local = make_prefill(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    _, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = dp if len(dp) > 1 else dp[0]
    in_specs = [p_specs, P(dp_ax, None)]
    # Extras ride positionally after ``tokens``: a [B, prefix_len, D]
    # patch-embedding prefix (vlm) and/or [B, enc_seq, D] encoder frames
    # (encdec) — both [B, S, D] with only the batch axis dp-sharded, so
    # every extra takes the same spec.
    n_extras = int(bool(ms.cfg.prefix_len)) + int(bool(ms.enc_segments))
    in_specs.extend([P(dp_ax, None, None)] * n_extras)

    def local_n(params, tokens, *extras):
        prefix = frames = None
        i = 0
        if ms.cfg.prefix_len:
            prefix = extras[i]; i += 1
        if ms.enc_segments:
            frames = extras[i]; i += 1
        return local(params, tokens, prefix, frames)

    wrapped = shard_map(
        local_n, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp_ax, "model"), c_specs),
        check_vma=False)
    return jax.jit(wrapped), c_specs, pc


def make_sharded_generate(ms: T.ModelStructure, mesh, sv: ServeConfig,
                          *, batch: int, prompt_len: int):
    """Build the one-shot sharded generation loop ONCE (prefill + serve
    step jits are per-instance, so reusing the returned closure is what
    makes a warm call actually warm the next one). Returns
    ``gen(params, prompts [batch, prompt_len], n_new, key=None) ->
    [batch, n_new] np.int32``.

    The prefill runs without sequence parallelism so the forward matches
    the engine's exact-length paged prefill shape-for-shape (SP would need
    prompt_len % tp == 0 and regroup the sequence reductions).
    """
    assert sv.temperature == 0.0, "sharded generation is the greedy reference"
    # Fail fast rather than silently dropping the prefix/frames extras the
    # ring prefill would expect positionally (transformer.forward_full runs
    # prefix-LM archs WITHOUT their prefix when prefix_embed is None).
    assert not ms.cfg.prefix_len and not ms.enc_segments, (
        f"{ms.cfg.name}: sharded one-shot generation does not take "
        "prefix/encoder extras yet")
    pre, _, _ = make_sharded_prefill(ms, mesh, sv, batch=batch,
                                     prompt_len=prompt_len, sp=False)
    step, _, _, _ = make_sharded_serve_step(ms, mesh, sv, batch=batch,
                                            shard_batch=False)

    def gen(params, prompts, n_new: int, key=None) -> np.ndarray:
        prompts = jnp.asarray(prompts, jnp.int32)
        assert prompts.shape == (batch, prompt_len), prompts.shape
        logits, caches = pre(params, prompts)
        # Gathered full-vocab logits: argmax's first-max tie-break equals
        # vocab_parallel_argmax's smallest-global-id rule.
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [np.asarray(tok)]
        key_ = key if key is not None else jax.random.PRNGKey(0)
        for i in range(n_new - 1):
            key_, sub = jax.random.split(key_)
            tok, caches = step(params, tok, caches, jnp.int32(prompt_len + i),
                               sub)
            toks.append(np.asarray(tok))
        return np.stack(toks, axis=1).astype(np.int32)

    return gen


def sharded_generate(params, prompts, n_new: int, *, ms: T.ModelStructure,
                     mesh, sv: ServeConfig, key=None) -> np.ndarray:
    """One-shot greedy generation under shard_map (ring cache, host decode
    loop): the tp > 1 reference stream the sharded paged engine is gated
    against. ``prompts``: [B, S] token ids. Returns [B, n_new] np.int32.
    One-off convenience over ``make_sharded_generate`` — compiles fresh
    programs per call; loops should build the factory once."""
    prompts = jnp.asarray(prompts, jnp.int32)
    B, S = prompts.shape
    return make_sharded_generate(ms, mesh, sv, batch=B, prompt_len=S)(
        params, prompts, n_new, key)
