"""Serving engine: batched prefill + autoregressive decode with LP models.

The engine exposes the three programs the assigned shapes lower:
  prefill_step  — logits + cache from a full prompt batch   (prefill_32k)
  serve_step    — ONE new token against the cache            (decode_32k /
                  long_500k; this is where LP's sync halving shows up —
                  seq=1 matmuls are tiny, so decode latency on a TP mesh is
                  dominated by the per-layer all-reduces the paper removes)
  generate      — host loop / scanned loop over serve_step

Sampling is vocab-parallel (Gumbel-max over the sharded vocabulary), so full
logits are never gathered.

Continuous batching
-------------------
``PagedEngine`` is the deployment-shaped entry point: requests of different
lengths arrive at different times, share ONE paged pair-KV cache pool
(repro.serve.paged_cache), and finish independently — ``add_request`` /
``step`` / ``drain``. The decode step stays ONE compiled program: the batch
is a fixed set of ``n_slots`` decode slots (idle slots point at the garbage
page and their outputs are ignored on the host), with per-slot positions
and a block table as the only per-step inputs. Prefill compiles per
distinct prompt length and runs the EXACT prompt (no right-padding), which
is what makes engine outputs bit-identical to one-shot ``generate()`` —
padding would change reduction shapes and perturb low bits. Admission is
FCFS with a prefill token budget (repro.serve.scheduler) so prefill bursts
interleave with, rather than starve, running decodes.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.model import embedding as E
from repro.model import transformer as T
from repro.parallel.context import ParallelContext, make_context
from repro.serve import paged_cache as PG
from repro.serve.scheduler import PagePool, Request, Scheduler

PyTree = Any


@dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024           # KV-cache length
    temperature: float = 0.0      # 0 -> greedy
    kv_mode: str = "heads"        # heads | seq  (seq-sharded KV cache)
    cache_dtype: Any = jnp.bfloat16
    attn_impl: str = "auto"


# ---------------------------------------------------------------------------
# Local step functions (run under shard_map or plain)
# ---------------------------------------------------------------------------

def make_prefill(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    def prefill_fn(params, tokens, prefix=None, frames=None):
        logits, caches = T.prefill(
            params, tokens, ms=ms, pc=pc, max_len=sv.max_len,
            prefix_embed=prefix, enc_frames=frames, kv_mode=sv.kv_mode,
            attn_impl=sv.attn_impl, cache_dtype=sv.cache_dtype)
        return logits, caches
    return prefill_fn


def make_serve_step(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    """serve_step(params, tok [B], caches, t, key) -> (next_tok [B], caches).

    One full decode iteration: embed -> stack (1 psum per LP group phase) ->
    head -> vocab-parallel sample.
    """
    def serve_fn(params, tok, caches, t, key):
        logits, caches = T.decode_step(params, tok, caches, t, ms=ms, pc=pc,
                                       kv_mode=sv.kv_mode)
        if sv.temperature > 0:
            nxt = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
        else:
            nxt = E.vocab_parallel_argmax(logits, pc)
        return nxt.astype(jnp.int32), caches
    return serve_fn


def generate(params, prompts, n_new: int, *, ms: T.ModelStructure,
             pc: ParallelContext, sv: ServeConfig, key=None,
             prefix=None, frames=None):
    """Greedy/temperature generation: returns [B, n_new] new tokens.

    The decode loop is a lax.scan (one compiled program regardless of
    n_new), carrying (tok, caches, t, key).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    prefill_fn = make_prefill(ms, pc, sv)
    step_fn = make_serve_step(ms, pc, sv)
    logits, caches = prefill_fn(params, prompts, prefix, frames)
    if sv.temperature > 0:
        tok0 = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
    else:
        tok0 = E.vocab_parallel_argmax(logits, pc)
    tok0 = tok0.astype(jnp.int32)
    t0 = prompts.shape[1] + (ms.cfg.prefix_len if prefix is not None else 0)

    def body(carry, i):
        tok, caches, key = carry
        key, sub = jax.random.split(key)
        # ``tok`` sits at absolute position t0 + i; its logits predict i+1.
        nxt, caches = step_fn(params, tok, caches, t0 + i, sub)
        return (nxt, caches, key), tok

    (last, _, _), toks = lax.scan(body, (tok0, caches, key),
                                  jnp.arange(n_new - 1))
    return jnp.concatenate([toks.T, last[:, None]], axis=1)


# ---------------------------------------------------------------------------
# Continuous batching over the paged pair-KV cache pool
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PagedServeConfig:
    """Static geometry of the continuous-batching engine.

    max_len must be a page multiple: the decode step attends over exactly
    ``pages_per_slot * page_size == max_len`` gathered positions, the same
    horizon a ring cache of ``max_len`` gives one-shot ``generate()`` —
    equal reduction shapes are part of the bit-identity contract.
    ``n_pages`` INCLUDES the reserved garbage page 0, so the allocatable
    capacity is ``n_pages - 1`` pages.
    """
    n_slots: int = 8              # concurrent decode slots (fixed batch)
    page_size: int = 16           # tokens per cache page
    n_pages: int = 129            # pool size incl. the reserved garbage page
    max_len: int = 256            # per-request position cap (page multiple)
    prefill_token_budget: int = 4096   # admission budget per step
    temperature: float = 0.0      # 0 -> greedy (bit-identical to generate())
    cache_dtype: Any = jnp.bfloat16
    eos_token: int = -1           # -1: run every request to max_new

    @property
    def pages_per_slot(self) -> int:
        return self.max_len // self.page_size


class PagedEngine:
    """Continuous-batching serving engine: ``add_request / step / drain``.

    One ``step()`` is: FCFS admission (each admitted request prefills at its
    exact length and claims its pages), then ONE fixed-shape decode program
    over all ``n_slots`` slots. Finished requests (EOS / max_new) release
    their slot and pages the same step, so the next admission reuses them.

    Greedy outputs are bit-identical per request to one-shot
    ``generate(params, prompt[None], max_new)`` with ``max_len`` equal to
    this engine's: prefill runs the identical forward at the exact prompt
    length, decode runs the identical per-row math (paged gather + same
    cores), and every cross-request interaction is row-independent.
    """

    def __init__(self, params, ms: T.ModelStructure, psv: PagedServeConfig,
                 *, pc: Optional[ParallelContext] = None, key=None):
        assert psv.max_len % psv.page_size == 0, (psv.max_len, psv.page_size)
        assert psv.n_slots >= 1
        PG.validate_paged_support(ms, psv.max_len)
        self.params = params
        self.ms = ms
        self.psv = psv
        self.pc = pc if pc is not None else ParallelContext()
        self.pool = PagePool(psv.n_pages)
        self.sched = Scheduler(
            n_slots=psv.n_slots, pool=self.pool, page_size=psv.page_size,
            max_len=psv.max_len,
            prefill_token_budget=psv.prefill_token_budget)
        self.caches = PG.init_paged_caches(
            ms, n_slots=psv.n_slots, n_pages=psv.n_pages,
            page_size=psv.page_size, dtype=psv.cache_dtype)
        P_slot = psv.pages_per_slot
        self.block_tables = np.full((psv.n_slots, P_slot), PG.GARBAGE_PAGE,
                                    np.int32)
        self.tok = np.zeros((psv.n_slots,), np.int32)
        self.pos = np.zeros((psv.n_slots,), np.int32)
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self.step_count = 0
        self.results: Dict[int, np.ndarray] = {}
        self._requests: Dict[int, Request] = {}
        self._decode = self._make_decode()
        self._prefills: Dict[int, Any] = {}   # prompt_len -> jitted prefill

    # -- compiled programs ---------------------------------------------
    def _make_decode(self):
        ms, pc, psv = self.ms, self.pc, self.psv

        def f(params, caches, tok, pos, bt, key):
            logits, caches = T.decode_step(
                params, tok, caches, pos, ms=ms, pc=pc,
                cache_layout="paged", block_tables=bt)
            if psv.temperature > 0:
                nxt = E.vocab_parallel_sample(logits, key, psv.temperature, pc)
            else:
                nxt = E.vocab_parallel_argmax(logits, pc)
            return nxt.astype(jnp.int32), caches

        return jax.jit(f, donate_argnums=(1,))

    def _prefill_fn(self, prompt_len: int):
        """Exact-length prefill + page scatter, compiled once per distinct
        prompt length (the cache emission length rounds up to whole pages;
        the forward itself is the exact prompt — no padding)."""
        ms, pc, psv = self.ms, self.pc, self.psv
        n_pg = -(-prompt_len // psv.page_size)
        emit_len = n_pg * psv.page_size

        def f(params, caches, prompt, page_ids, slot, key):
            logits, _, seq = T.forward_full(
                params, prompt, ms=ms, pc=pc, emit_cache=True,
                max_len=emit_len, kv_mode="heads")
            # Same cast T.prefill applies to the ring cache.
            seq = jax.tree.map(
                lambda c: c.astype(psv.cache_dtype)
                if c.dtype in (jnp.float32, jnp.bfloat16) else c, seq)
            last = logits[:, prompt_len - 1]
            if psv.temperature > 0:
                tok0 = E.vocab_parallel_sample(last, key, psv.temperature, pc)
            else:
                tok0 = E.vocab_parallel_argmax(last, pc)
            caches = PG.scatter_prefill(caches, seq, page_ids, slot)
            return tok0.astype(jnp.int32), caches

        return jax.jit(f, donate_argnums=(1,))

    # -- public API ----------------------------------------------------
    def add_request(self, prompt, max_new: int,
                    eos_token: Optional[int] = None) -> int:
        """Queue a request; returns its id. Fails fast if the request could
        NEVER fit the pool (otherwise exhaustion just queues it)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        total = prompt.shape[0] + max_new
        if total > self.psv.max_len:
            raise ValueError(
                f"request needs {total} positions > max_len={self.psv.max_len}")
        need = PG.pages_needed(prompt.shape[0], max_new, self.psv.page_size)
        if need > self.psv.n_pages - 1:
            raise ValueError(
                f"request needs {need} pages > pool capacity "
                f"{self.psv.n_pages - 1}")
        eos = self.psv.eos_token if eos_token is None else eos_token
        r = self.sched.submit(prompt, max_new, eos)
        self._requests[r.rid] = r
        return r.rid

    def _prefill(self, r: Request) -> None:
        fn = self._prefills.get(r.prompt_len)
        if fn is None:
            fn = self._prefills[r.prompt_len] = \
                self._prefill_fn(r.prompt_len)
        n_pg = -(-r.prompt_len // self.psv.page_size)
        page_ids = jnp.asarray(r.pages[:n_pg], jnp.int32)
        self._key, sub = jax.random.split(self._key)
        tok0, self.caches = fn(self.params, self.caches,
                               jnp.asarray(r.prompt[None]), page_ids,
                               jnp.int32(r.slot), sub)
        r.out.append(int(tok0[0]))
        row = self.block_tables[r.slot]
        row[:] = PG.GARBAGE_PAGE
        row[:len(r.pages)] = r.pages
        self.tok[r.slot] = r.out[-1]
        self.pos[r.slot] = r.pos          # == prompt_len

    def _finish(self, r: Request) -> None:
        slot = r.slot
        self.sched.finish(r, self.step_count)
        self.block_tables[slot] = PG.GARBAGE_PAGE
        self.tok[slot] = 0
        self.pos[slot] = 0
        self.results[r.rid] = np.asarray(r.out, np.int32)

    def step(self) -> Dict[str, int]:
        """One engine iteration: admission+prefill, then one decode program
        over every slot. Returns counters for the step."""
        stats = {"admitted": 0, "decoded": 0, "finished": 0,
                 "live_pages": 0}
        for r in self.sched.admit(self.step_count):
            self._prefill(r)
            stats["admitted"] += 1
            if r.done():      # max_new == 1 (or instant EOS) on prefill
                self._finish(r)
                stats["finished"] += 1
        if self.sched.n_running:
            self._key, sub = jax.random.split(self._key)
            nxt, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.tok),
                jnp.asarray(self.pos), jnp.asarray(self.block_tables), sub)
            nxt = np.asarray(nxt)
            for slot, r in list(self.sched.running.items()):
                r.out.append(int(nxt[slot]))
                self.tok[slot] = nxt[slot]
                self.pos[slot] += 1
                stats["decoded"] += 1
                if r.done():
                    self._finish(r)
                    stats["finished"] += 1
        self.pool.check_balance()
        stats["live_pages"] = self.pool.live
        self.step_count += 1
        return stats

    def drain(self) -> Dict[int, np.ndarray]:
        """Step until every submitted request finished; returns
        {rid: generated tokens}."""
        while self.sched.n_queued or self.sched.n_running:
            self.step()
        return dict(self.results)

    @property
    def occupancy(self) -> float:
        """Fraction of allocatable cache pages currently live."""
        return self.pool.live / max(self.psv.n_pages - 1, 1)

    def request(self, rid: int) -> Request:
        return self._requests[rid]


# ---------------------------------------------------------------------------
# Sharded wrappers (mesh execution + dry-run lowering)
# ---------------------------------------------------------------------------

def cache_pspecs(ms: T.ModelStructure, *, batch: int, sv: ServeConfig,
                 pc: ParallelContext, shard_batch: bool = True):
    """(abstract, pspec) for the global cache; batch sharded over dp when
    ``shard_batch`` (batch==1 long-context cells replicate it)."""
    abs_, ps_ = T.cache_meta(ms, batch=batch, max_len=sv.max_len,
                             kv_mode=sv.kv_mode, dtype=sv.cache_dtype)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None

    def add_dp(path, spec):
        # Shard the batch axis over dp: axis 1 for per-layer entries
        # ([count, batch, ...]), axis 2 for stacked pair entries
        # ([count, 2, batch, ...]) — see T.cache_batch_axis.
        parts = list(spec)
        parts[T.cache_batch_axis(path[-1].key)] = dp_ax
        return P(*parts)

    ps2 = jax.tree_util.tree_map_with_path(
        add_dp, ps_, is_leaf=lambda x: isinstance(x, P))
    return abs_, ps2


def make_sharded_serve_step(ms: T.ModelStructure, mesh, sv: ServeConfig,
                            *, batch: int, shard_batch: bool = True):
    """jit(shard_map(serve_step)) + its in/out specs, for execution and the
    decode-shape dry-run."""
    pc = make_context(mesh, sp=False)
    local = make_serve_step(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    c_abs, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc,
                                  shard_batch=shard_batch)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None
    tok_spec = P(dp_ax)
    wrapped = shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, tok_spec, c_specs, P(), P()),
        out_specs=(tok_spec, c_specs),
        check_vma=False)
    return jax.jit(wrapped, donate_argnums=(2,)), c_abs, c_specs, pc


def make_sharded_prefill(ms: T.ModelStructure, mesh, sv: ServeConfig,
                         *, batch: int, prompt_len: int, sp: bool = True):
    pc = make_context(mesh, sp=sp)
    local = make_prefill(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    _, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = dp if len(dp) > 1 else dp[0]
    in_specs = [p_specs, P(dp_ax, None)]
    # Extras ride positionally after ``tokens``: a [B, prefix_len, D]
    # patch-embedding prefix (vlm) and/or [B, enc_seq, D] encoder frames
    # (encdec) — both [B, S, D] with only the batch axis dp-sharded, so
    # every extra takes the same spec.
    n_extras = int(bool(ms.cfg.prefix_len)) + int(bool(ms.enc_segments))
    in_specs.extend([P(dp_ax, None, None)] * n_extras)

    def local_n(params, tokens, *extras):
        prefix = frames = None
        i = 0
        if ms.cfg.prefix_len:
            prefix = extras[i]; i += 1
        if ms.enc_segments:
            frames = extras[i]; i += 1
        return local(params, tokens, prefix, frames)

    wrapped = shard_map(
        local_n, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp_ax, "model"), c_specs),
        check_vma=False)
    return jax.jit(wrapped), c_specs, pc
