"""Serving engine: batched prefill + autoregressive decode with LP models.

The engine exposes the three programs the assigned shapes lower:
  prefill_step  — logits + cache from a full prompt batch   (prefill_32k)
  serve_step    — ONE new token against the cache            (decode_32k /
                  long_500k; this is where LP's sync halving shows up —
                  seq=1 matmuls are tiny, so decode latency on a TP mesh is
                  dominated by the per-layer all-reduces the paper removes)
  generate      — host loop / scanned loop over serve_step

Sampling is vocab-parallel (Gumbel-max over the sharded vocabulary), so full
logits are never gathered.

Continuous batching
-------------------
``PagedEngine`` is the deployment-shaped entry point: requests of different
lengths arrive at different times, share ONE paged pair-KV cache pool
(repro.serve.paged_cache), and finish independently — ``add_request`` /
``step`` / ``drain``. The decode step stays ONE compiled program: the batch
is a fixed set of ``n_slots`` decode slots (idle slots point at the garbage
page and their outputs are ignored on the host), with per-slot positions
and a block table as the only per-step inputs. Prefill compiles per
distinct prompt length and runs the EXACT prompt (no right-padding), which
is what makes engine outputs bit-identical to one-shot ``generate()`` —
padding would change reduction shapes and perturb low bits. Admission is
FCFS with a prefill token budget (repro.serve.scheduler) so prefill bursts
interleave with, rather than starve, running decodes.

Prefix sharing & preemption (PagedServeConfig.prefix_cache/preempt_after):
admission radix-matches the prompt against donated whole pages
(repro.serve.prefix_cache) — matched pages link read-only into the block
table (copy-on-write: the first written page is always private) and only
the unmatched suffix runs through ``_suffix_fn``, a forward over the
suffix with the matched pages gathered as context kv whose rows reduce at
the cold program's exact shapes. A blocked queue head preempts the
youngest running request: its tokens park on the Request, its whole
written pages are donated (reclaimable, radix-hittable at resume), and
resume replays the parked positions through the regular decode program —
the engine asserts every replayed token reproduces the parked one.

Sharded paged serving (``PagedEngine(mesh=...)``): the same engine loop
drives shard_map-compiled programs on a tp > 1 mesh. The page pool shards
its kv-head axis over the "model" axis exactly like the ring cache, every
host-side structure (scheduler, block tables, positions, page ids) is
tp-agnostic, and greedy decode streams stay bit-identical to the tp=1
engine and to one-shot ``sharded_generate`` (the sharded-structural CI
gate). Prefix sharing auto-disables under tp > 1 for now.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.model import embedding as E
from repro.model import transformer as T
from repro.parallel.context import ParallelContext, make_context
from repro.serve import paged_cache as PG
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import PagePool, Request, Scheduler

PyTree = Any


@dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024           # KV-cache length
    temperature: float = 0.0      # 0 -> greedy
    kv_mode: str = "heads"        # heads | seq  (seq-sharded KV cache)
    cache_dtype: Any = jnp.bfloat16
    attn_impl: str = "auto"


# ---------------------------------------------------------------------------
# Local step functions (run under shard_map or plain)
# ---------------------------------------------------------------------------

def make_prefill(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    def prefill_fn(params, tokens, prefix=None, frames=None):
        logits, caches = T.prefill(
            params, tokens, ms=ms, pc=pc, max_len=sv.max_len,
            prefix_embed=prefix, enc_frames=frames, kv_mode=sv.kv_mode,
            attn_impl=sv.attn_impl, cache_dtype=sv.cache_dtype)
        return logits, caches
    return prefill_fn


def make_serve_step(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    """serve_step(params, tok [B], caches, t, key) -> (next_tok [B], caches).

    One full decode iteration: embed -> stack (1 psum per LP group phase) ->
    head -> vocab-parallel sample.
    """
    def serve_fn(params, tok, caches, t, key):
        logits, caches = T.decode_step(params, tok, caches, t, ms=ms, pc=pc,
                                       kv_mode=sv.kv_mode)
        if sv.temperature > 0:
            nxt = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
        else:
            nxt = E.vocab_parallel_argmax(logits, pc)
        return nxt.astype(jnp.int32), caches
    return serve_fn


def generate(params, prompts, n_new: int, *, ms: T.ModelStructure,
             pc: ParallelContext, sv: ServeConfig, key=None,
             prefix=None, frames=None):
    """Greedy/temperature generation: returns [B, n_new] new tokens.

    The decode loop is a lax.scan (one compiled program regardless of
    n_new), carrying (tok, caches, t, key).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    prefill_fn = make_prefill(ms, pc, sv)
    step_fn = make_serve_step(ms, pc, sv)
    logits, caches = prefill_fn(params, prompts, prefix, frames)
    if sv.temperature > 0:
        tok0 = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
    else:
        tok0 = E.vocab_parallel_argmax(logits, pc)
    tok0 = tok0.astype(jnp.int32)
    t0 = prompts.shape[1] + (ms.cfg.prefix_len if prefix is not None else 0)

    def body(carry, i):
        tok, caches, key = carry
        key, sub = jax.random.split(key)
        # ``tok`` sits at absolute position t0 + i; its logits predict i+1.
        nxt, caches = step_fn(params, tok, caches, t0 + i, sub)
        return (nxt, caches, key), tok

    (last, _, _), toks = lax.scan(body, (tok0, caches, key),
                                  jnp.arange(n_new - 1))
    return jnp.concatenate([toks.T, last[:, None]], axis=1)


# ---------------------------------------------------------------------------
# Continuous batching over the paged pair-KV cache pool
# ---------------------------------------------------------------------------

def make_paged_decode_fn(ms: T.ModelStructure, pc: ParallelContext, psv):
    """Local paged decode step: (params, caches, tok [n_slots], pos
    [n_slots], block_tables, key) -> (next_tok [n_slots], caches).

    The SAME body runs under plain jit (tp=1 engine) and inside shard_map
    over a tp mesh (``make_sharded_serve_step(paged=...)``): tok/pos/block
    tables are replicated host-side inputs, the pool's kv-head axis is the
    only sharded dim, and sampling is vocab-parallel so full logits never
    materialise.
    """
    def f(params, caches, tok, pos, bt, key):
        logits, caches = T.decode_step(
            params, tok, caches, pos, ms=ms, pc=pc,
            cache_layout="paged", block_tables=bt)
        if psv.temperature > 0:
            nxt = E.vocab_parallel_sample(logits, key, psv.temperature, pc)
        else:
            nxt = E.vocab_parallel_argmax(logits, pc)
        return nxt.astype(jnp.int32), caches

    return f


def make_paged_prefill_fn(ms: T.ModelStructure, pc: ParallelContext, psv,
                          prompt_len: int):
    """Local exact-length prefill + page scatter: (params, caches, prompt
    [1, prompt_len], page_ids, slot, key) -> (first_tok [1], caches). The
    cache emission length rounds up to whole pages; the forward itself is
    the exact prompt — no padding (the bit-identity contract). Shared by
    the tp=1 jit and the shard_map wrapper (sp stays off: exact odd-length
    prompts do not split over ranks)."""
    n_pg = -(-prompt_len // psv.page_size)
    emit_len = n_pg * psv.page_size

    def f(params, caches, prompt, page_ids, slot, key):
        logits, _, seq = T.forward_full(
            params, prompt, ms=ms, pc=pc, emit_cache=True,
            max_len=emit_len, kv_mode="heads")
        # Same cast T.prefill applies to the ring cache.
        seq = jax.tree.map(
            lambda c: c.astype(psv.cache_dtype)
            if c.dtype in (jnp.float32, jnp.bfloat16) else c, seq)
        last = logits[:, prompt_len - 1]
        if psv.temperature > 0:
            tok0 = E.vocab_parallel_sample(last, key, psv.temperature, pc)
        else:
            tok0 = E.vocab_parallel_argmax(last, pc)
        caches = PG.scatter_prefill(caches, seq, page_ids, slot)
        return tok0.astype(jnp.int32), caches

    return f


@dataclass(frozen=True)
class PagedServeConfig:
    """Static geometry of the continuous-batching engine.

    max_len must be a page multiple: the decode step attends over exactly
    ``pages_per_slot * page_size == max_len`` gathered positions, the same
    horizon a ring cache of ``max_len`` gives one-shot ``generate()`` —
    equal reduction shapes are part of the bit-identity contract.
    ``n_pages`` INCLUDES the reserved garbage page 0, so the allocatable
    capacity is ``n_pages - 1`` pages.

    prefix_cache: radix prefix sharing over whole pages — matched prompt
    pages are linked read-only into the block table and only the unmatched
    suffix is prefilled. Attention-only models (the engine silently
    disables it for mixers with recurrent state). Greedy prefix-hit
    outputs are bit-identical to a cold run when the pool holds fp32 and
    the donor computed the shared pages at compatible shapes (whole-page
    chunks are length-invariant by the suffix-prefill contract; see
    EXPERIMENTS.md).
    preempt_after: > 0 enables preemption — after that many consecutive
    steps with a blocked queue head, the youngest running request is
    parked (pages donated/released, tokens kept) and later resumed via
    radix re-link + bit-exact decode replay. 0 keeps PR 2's strict FCFS.
    """
    n_slots: int = 8              # concurrent decode slots (fixed batch)
    page_size: int = 16           # tokens per cache page
    n_pages: int = 129            # pool size incl. the reserved garbage page
    max_len: int = 256            # per-request position cap (page multiple)
    prefill_token_budget: int = 4096   # admission budget per step
    temperature: float = 0.0      # 0 -> greedy (bit-identical to generate())
    cache_dtype: Any = jnp.bfloat16
    eos_token: int = -1           # -1: run every request to max_new
    prefix_cache: bool = False    # radix prefix sharing (CoW pages)
    preempt_after: int = 0        # blocked-head steps before preemption

    @property
    def pages_per_slot(self) -> int:
        return self.max_len // self.page_size


class PagedEngine:
    """Continuous-batching serving engine: ``add_request / step / drain``.

    One ``step()`` is: FCFS admission (each admitted request prefills at its
    exact length and claims its pages), then ONE fixed-shape decode program
    over all ``n_slots`` slots. Finished requests (EOS / max_new) release
    their slot and pages the same step, so the next admission reuses them.

    Greedy outputs are bit-identical per request to one-shot
    ``generate(params, prompt[None], max_new)`` with ``max_len`` equal to
    this engine's: prefill runs the identical forward at the exact prompt
    length, decode runs the identical per-row math (paged gather + same
    cores), and every cross-request interaction is row-independent.

    ``mesh``: run the compiled programs under shard_map on a tp > 1 mesh
    (``ms`` must be built with the matching tp). The page pool shards its
    kv-head axis over the model axis like the ring cache; scheduling,
    block tables and per-slot positions stay host-side and tp-agnostic.
    The radix prefix cache auto-disables under tp > 1 for now — the
    suffix-prefill ctx path assumes replicated kv (radix-aware sharded
    serving is a ROADMAP follow-on) — while preemption still works via
    full re-prefill + bit-exact decode replay.
    """

    def __init__(self, params, ms: T.ModelStructure, psv: PagedServeConfig,
                 *, pc: Optional[ParallelContext] = None, key=None,
                 mesh=None):
        assert psv.max_len % psv.page_size == 0, (psv.max_len, psv.page_size)
        assert psv.n_slots >= 1
        PG.validate_paged_support(ms, psv.max_len)
        self.ms = ms
        self.psv = psv
        self.mesh = mesh
        if mesh is not None:
            assert pc is None, "pc is derived from mesh; pass one or the other"
            self.pc = make_context(mesh, sp=False)
            assert self.pc.tp_size == ms.tp, (
                f"mesh model axis ({self.pc.tp_size}) != ms.tp ({ms.tp})")
            self.params = jax.device_put(params, _tree_shardings(
                mesh, T.param_pspecs(ms)))
        else:
            self.pc = pc if pc is not None else ParallelContext()
            self.params = params
        self.pool = PagePool(psv.n_pages)
        self.prefix = (PrefixCache(psv.page_size)
                       if psv.prefix_cache and ms.tp == 1
                       and self._prefix_eligible(ms)
                       else None)
        self.sched = Scheduler(
            n_slots=psv.n_slots, pool=self.pool, page_size=psv.page_size,
            max_len=psv.max_len,
            prefill_token_budget=psv.prefill_token_budget,
            prefix_cache=self.prefix, preempt_after=psv.preempt_after)
        if mesh is not None:
            c_abs, c_specs = PG.paged_cache_meta(
                ms, n_slots=psv.n_slots, n_pages=psv.n_pages,
                page_size=psv.page_size, dtype=psv.cache_dtype)
            self.caches = jax.tree.map(
                lambda a, sh: jax.device_put(jnp.zeros(a.shape, a.dtype), sh),
                c_abs, _tree_shardings(mesh, c_specs))
        else:
            self.caches = PG.init_paged_caches(
                ms, n_slots=psv.n_slots, n_pages=psv.n_pages,
                page_size=psv.page_size, dtype=psv.cache_dtype)
        P_slot = psv.pages_per_slot
        self.block_tables = np.full((psv.n_slots, P_slot), PG.GARBAGE_PAGE,
                                    np.int32)
        self.tok = np.zeros((psv.n_slots,), np.int32)
        self.pos = np.zeros((psv.n_slots,), np.int32)
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self.step_count = 0
        self.results: Dict[int, np.ndarray] = {}
        self._requests: Dict[int, Request] = {}
        self._decode = self._make_decode()
        self._prefills: Dict[Any, Any] = {}   # program-shape key -> jit fn
        # Greedy + fp32 pool => suffix/replay recomputation is bit-exact
        # against the original run; the engine then self-checks the replay.
        self._exact = (psv.temperature == 0.0
                       and psv.cache_dtype == jnp.float32)
        self.counters = {"prefill_tokens": 0, "hit_tokens": 0,
                         "resume_hit_tokens": 0, "replay_tokens": 0,
                         "full_prefills": 0, "suffix_prefills": 0,
                         "prefix_hits": 0}

    @staticmethod
    def _prefix_eligible(ms: T.ModelStructure) -> bool:
        """Prefix sharing resumes from cached kv alone: every mixer must be
        attention (recurrent conv/h state has no page representation) and
        the FFN a plain MLP (the MoE pair path has no pinned-order
        projection; see model.mlp.mlp_forward)."""
        return all(spec.mixer.startswith("attn") and not spec.cross_attn
                   and spec.ffn in ("mlp", None)
                   for seg in ms.segments for spec in seg.group.specs)

    # -- compiled programs ---------------------------------------------
    def _make_decode(self):
        if self.mesh is not None:
            fn, _, _, _ = make_sharded_serve_step(
                self.ms, self.mesh, None, batch=self.psv.n_slots,
                paged=self.psv)
            return fn
        local = make_paged_decode_fn(self.ms, self.pc, self.psv)
        return jax.jit(local, donate_argnums=(1,))

    def _prefill_fn(self, prompt_len: int):
        """Exact-length prefill + page scatter, compiled once per distinct
        prompt length (the cache emission length rounds up to whole pages;
        the forward itself is the exact prompt — no padding)."""
        if self.mesh is not None:
            fn, _, _ = make_sharded_prefill(
                self.ms, self.mesh, None, batch=1, prompt_len=prompt_len,
                paged=self.psv)
            return fn
        local = make_paged_prefill_fn(self.ms, self.pc, self.psv, prompt_len)
        return jax.jit(local, donate_argnums=(1,))

    def _suffix_fn(self, n_ctx_pages: int, suffix_len: int):
        """Prefix-hit prefill: gather the matched pages as read-only
        context kv, run the forward over ONLY the unmatched suffix, and
        scatter the suffix pages. Compiled once per (context pages, suffix
        length) shape. Every suffix row reduces over exactly
        ``ctx + suffix`` keys — the cold full-prompt program's reduction
        shape for the same row — so greedy outputs stay bit-identical to a
        cold run (fp32 pool). Copy-on-write holds by construction: the
        program writes only ``sfx_ids`` pages, never ``ctx_ids``.
        """
        ms, pc, psv = self.ms, self.pc, self.psv
        assert ms.tp == 1, "prefix sharing is tp=1 only (auto-disabled)"
        ps = psv.page_size
        start = n_ctx_pages * ps
        n_sfx = -(-suffix_len // ps)
        emit_len = n_sfx * ps

        def f(params, caches, suffix, ctx_ids, sfx_ids, slot, key):
            ctx = PG.gather_ctx(caches, ctx_ids)
            logits, _, seq = T.forward_full(
                params, suffix, ms=ms, pc=pc, emit_cache=True,
                max_len=emit_len, kv_mode="heads", ctx_kv=ctx, start=start)
            seq = jax.tree.map(
                lambda c: c.astype(psv.cache_dtype)
                if c.dtype in (jnp.float32, jnp.bfloat16) else c, seq)
            last = logits[:, suffix_len - 1]
            if psv.temperature > 0:
                tok0 = E.vocab_parallel_sample(last, key, psv.temperature, pc)
            else:
                tok0 = E.vocab_parallel_argmax(last, pc)
            caches = PG.scatter_prefill(caches, seq, sfx_ids, slot)
            return tok0.astype(jnp.int32), caches

        return jax.jit(f, donate_argnums=(1,))

    # -- public API ----------------------------------------------------
    def add_request(self, prompt, max_new: int,
                    eos_token: Optional[int] = None) -> int:
        """Queue a request; returns its id. Fails fast if the request could
        NEVER fit the pool (otherwise exhaustion just queues it)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        total = prompt.shape[0] + max_new
        if total > self.psv.max_len:
            raise ValueError(
                f"request needs {total} positions > max_len={self.psv.max_len}")
        need = PG.pages_needed(prompt.shape[0], max_new, self.psv.page_size)
        if need > self.psv.n_pages - 1:
            raise ValueError(
                f"request needs {need} pages > pool capacity "
                f"{self.psv.n_pages - 1}")
        eos = self.psv.eos_token if eos_token is None else eos_token
        r = self.sched.submit(prompt, max_new, eos)
        self._requests[r.rid] = r
        return r.rid

    def _run_prefill(self, r: Request, ctx: int):
        """Stage-1 forward over the unmatched prompt suffix (the full
        prompt when ctx == 0). Returns the token sampled from the last
        prompt position's logits."""
        ps = self.psv.page_size
        Lp = r.prompt_len
        n_pg_prompt = -(-Lp // ps)
        self._key, sub = jax.random.split(self._key)
        if ctx == 0:
            key = ("full", Lp)
            fn = self._prefills.get(key)
            if fn is None:
                fn = self._prefills[key] = self._prefill_fn(Lp)
            tok0, self.caches = fn(
                self.params, self.caches, jnp.asarray(r.prompt[None]),
                jnp.asarray(r.pages[:n_pg_prompt], jnp.int32),
                jnp.int32(r.slot), sub)
            self.counters["prefill_tokens"] += Lp
            self.counters["full_prefills"] += 1
        else:
            m = ctx // ps
            Ls = Lp - ctx
            key = ("sfx", m, Ls)
            fn = self._prefills.get(key)
            if fn is None:
                fn = self._prefills[key] = self._suffix_fn(m, Ls)
            tok0, self.caches = fn(
                self.params, self.caches, jnp.asarray(r.prompt[None, ctx:]),
                jnp.asarray(r.pages[:m], jnp.int32),
                jnp.asarray(r.pages[m:n_pg_prompt], jnp.int32),
                jnp.int32(r.slot), sub)
            self.counters["prefill_tokens"] += Ls
            self.counters["suffix_prefills"] += 1
        return int(tok0[0])

    def _replay(self, r: Request, start: int) -> None:
        """Resume catch-up: teacher-force the parked generated tokens whose
        kv fell outside the surviving radix prefix through the REGULAR
        decode program (all other slots masked to the garbage page, their
        rows ignored). Position p re-runs the exact computation that
        produced it originally — same program, same token, same kv bits —
        so with greedy sampling the replayed prediction must reproduce the
        parked token, which the engine asserts (the continuous form of the
        preempt-resume bit-identity gate).

        Recurrent state (mamba/rec conv/h) needs explicit protection: the
        masked slots' ATTENTION writes land on the garbage page, but the
        decode program advances EVERY slot's state each call — replay
        would corrupt concurrently running requests. The engine snapshots
        the state entries before replaying and restores every row except
        the replaying slot's afterwards (their true timeline has no step
        here)."""
        n_slots = self.psv.n_slots
        Lp = r.prompt_len
        end = Lp + len(r.out) - 1      # exclusive; kv for end-1 is the
        if start >= end:               # resumed decode step's own write
            return
        state_saved = [
            {name: np.asarray(v) for name, v in seg.items()
             if not PG.is_paged_entry(name)} for seg in self.caches]
        for p in range(start, end):
            tok_v = np.zeros((n_slots,), np.int32)
            pos_v = np.zeros((n_slots,), np.int32)
            bt = np.full_like(self.block_tables, PG.GARBAGE_PAGE)
            tok_v[r.slot] = r.out[p - Lp]
            pos_v[r.slot] = p
            bt[r.slot] = self.block_tables[r.slot]
            self._key, sub = jax.random.split(self._key)
            nxt, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(tok_v),
                jnp.asarray(pos_v), jnp.asarray(bt), sub)
            if self._exact:
                got = int(np.asarray(nxt)[r.slot])
                assert got == r.out[p - Lp + 1], (
                    f"replay divergence at pos {p}: {got} != "
                    f"{r.out[p - Lp + 1]} (rid={r.rid})")
            self.counters["replay_tokens"] += 1
        for seg, saved in zip(self.caches, state_saved):
            for name, host in saved.items():
                sl = (slice(None),) * T.cache_batch_axis(name) + (r.slot,)
                merged = host.copy()
                merged[sl] = np.asarray(seg[name])[sl]
                # Re-place at the entry's current sharding: under a mesh the
                # state entries are model-sharded and a bare jnp.asarray
                # would silently collapse them onto one device.
                seg[name] = jax.device_put(merged, seg[name].sharding)

    def _start(self, r: Request) -> None:
        """Bring an admitted request onto its slot: link its block table,
        run the stage-1 prefill (full / suffix / skipped when the radix hit
        covers the whole prompt), and for resumed requests replay the
        parked generated positions."""
        ps = self.psv.page_size
        ctx = r.n_shared * ps
        Lp = r.prompt_len
        resumed = bool(r.out)
        row = self.block_tables[r.slot]
        row[:] = PG.GARBAGE_PAGE
        row[:len(r.pages)] = r.pages
        # hit_tokens counts PROMPT tokens served from shared pages on FRESH
        # admissions only (a fresh match is prompt-only by the _match_cap);
        # a preemption resume re-linking its own donation is real savings
        # too but a different phenomenon — tracked under resume_hit_tokens
        # so hit_rate stays "prompt prefill work avoided by sharing".
        if resumed:
            self.counters["resume_hit_tokens"] += ctx
        else:
            self.counters["hit_tokens"] += ctx
            if ctx:
                self.counters["prefix_hits"] += 1
        if ctx < Lp:
            tok0 = self._run_prefill(r, ctx)
            if not resumed:
                r.out.append(tok0)
            elif self._exact:
                # Same program + same inputs as the original prefill: the
                # re-sampled first token must reproduce the parked one.
                assert tok0 == r.out[0], (tok0, r.out[0], r.rid)
        # Early donation: the prompt pages are complete now — concurrent
        # same-prefix requests admitted from the NEXT step on can share
        # them without waiting for this request to finish.
        self.sched.donate_prefilled(r, self.step_count)
        if resumed:
            self._replay(r, max(Lp, ctx))
        self.tok[r.slot] = r.out[-1]
        self.pos[r.slot] = r.pos

    def _finish(self, r: Request) -> None:
        slot = r.slot
        self.sched.finish(r, self.step_count)
        self.block_tables[slot] = PG.GARBAGE_PAGE
        self.tok[slot] = 0
        self.pos[slot] = 0
        self.results[r.rid] = np.asarray(r.out, np.int32)

    def _admit(self, stats: Dict[str, int], *, count_blocked: bool) -> None:
        for r in self.sched.admit(self.step_count,
                                  count_blocked=count_blocked):
            self._start(r)
            stats["admitted"] += 1
            if r.done():      # max_new == 1 (or instant EOS) on prefill
                self._finish(r)
                stats["finished"] += 1

    def step(self) -> Dict[str, int]:
        """One engine iteration: admission+prefill (with blocked-head
        preemption when enabled), then one decode program over every slot.
        Returns counters for the step."""
        stats = {"admitted": 0, "decoded": 0, "finished": 0,
                 "preempted": 0, "live_pages": 0}
        self._admit(stats, count_blocked=True)
        if self.sched.should_preempt():
            _victim, slot = self.sched.preempt_youngest(self.step_count)
            self.block_tables[slot] = PG.GARBAGE_PAGE
            self.tok[slot] = 0
            self.pos[slot] = 0
            stats["preempted"] += 1
            # The freed pages/slot may unblock the head immediately.
            self._admit(stats, count_blocked=False)
        if self.sched.n_running:
            self._key, sub = jax.random.split(self._key)
            nxt, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(self.tok),
                jnp.asarray(self.pos), jnp.asarray(self.block_tables), sub)
            nxt = np.asarray(nxt)
            for slot, r in list(self.sched.running.items()):
                r.out.append(int(nxt[slot]))
                self.tok[slot] = nxt[slot]
                self.pos[slot] += 1
                stats["decoded"] += 1
                if r.done():
                    self._finish(r)
                    stats["finished"] += 1
        self.pool.check_balance()
        if self.prefix is not None:
            self.prefix.check_locks()
        stats["live_pages"] = self.pool.live
        self.step_count += 1
        return stats

    def drain(self) -> Dict[int, np.ndarray]:
        """Step until every submitted request finished; returns
        {rid: generated tokens}."""
        while self.sched.n_queued or self.sched.n_running:
            self.step()
        return dict(self.results)

    @property
    def occupancy(self) -> float:
        """Fraction of allocatable cache pages currently live."""
        return self.pool.live / max(self.psv.n_pages - 1, 1)

    def request(self, rid: int) -> Request:
        return self._requests[rid]


# ---------------------------------------------------------------------------
# Sharded wrappers (mesh execution + dry-run lowering)
# ---------------------------------------------------------------------------

def _tree_shardings(mesh, pspecs):
    """PartitionSpec tree -> NamedSharding tree (P is a tuple: need is_leaf)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def cache_pspecs(ms: T.ModelStructure, *, batch: int, sv: ServeConfig,
                 pc: ParallelContext, shard_batch: bool = True):
    """(abstract, pspec) for the global cache; batch sharded over dp when
    ``shard_batch`` (batch==1 long-context cells replicate it)."""
    abs_, ps_ = T.cache_meta(ms, batch=batch, max_len=sv.max_len,
                             kv_mode=sv.kv_mode, dtype=sv.cache_dtype)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None

    def add_dp(path, spec):
        # Shard the batch axis over dp: axis 1 for per-layer entries
        # ([count, batch, ...]), axis 2 for stacked pair entries
        # ([count, 2, batch, ...]) — see T.cache_batch_axis.
        parts = list(spec)
        parts[T.cache_batch_axis(path[-1].key)] = dp_ax
        return P(*parts)

    ps2 = jax.tree_util.tree_map_with_path(
        add_dp, ps_, is_leaf=lambda x: isinstance(x, P))
    return abs_, ps2


def make_sharded_serve_step(ms: T.ModelStructure, mesh, sv: ServeConfig,
                            *, batch: int, shard_batch: bool = True,
                            paged: Optional[PagedServeConfig] = None):
    """jit(shard_map(serve_step)) + its in/out specs, for execution and the
    decode-shape dry-run.

    ``paged`` threads the continuous-batching engine's pool through the
    same wrapper: the local step becomes the paged decode (params, caches,
    tok, pos, block_tables, key) with the pool's pspecs from
    ``paged_cache_meta`` (kv-head axis over "model", everything else
    replicated) and tok/pos/block tables replicated — host-side scheduling
    is tp-agnostic, so the ONLY sharded state is the pool itself. ``sv``
    may be None in that mode; ``batch`` is the slot count.
    """
    if paged is not None:
        pc = make_context(mesh, sp=False)
        local = make_paged_decode_fn(ms, pc, paged)
        p_specs = T.param_pspecs(ms)
        c_abs, c_specs = PG.paged_cache_meta(
            ms, n_slots=batch, n_pages=paged.n_pages,
            page_size=paged.page_size, dtype=paged.cache_dtype)
        wrapped = shard_map(
            local, mesh=mesh,
            in_specs=(p_specs, c_specs, P(), P(), P(), P()),
            out_specs=(P(), c_specs),
            check_vma=False)
        return jax.jit(wrapped, donate_argnums=(1,)), c_abs, c_specs, pc
    pc = make_context(mesh, sp=False)
    local = make_serve_step(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    c_abs, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc,
                                  shard_batch=shard_batch)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None
    tok_spec = P(dp_ax)
    wrapped = shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, tok_spec, c_specs, P(), P()),
        out_specs=(tok_spec, c_specs),
        check_vma=False)
    return jax.jit(wrapped, donate_argnums=(2,)), c_abs, c_specs, pc


def make_sharded_prefill(ms: T.ModelStructure, mesh, sv: ServeConfig,
                         *, batch: int, prompt_len: int, sp: bool = True,
                         paged: Optional[PagedServeConfig] = None):
    """jit(shard_map(prefill)) for the ring cache (default), or — with
    ``paged`` — the engine's exact-length prefill + page scatter: the
    forward runs replicated over the sequence (sp off: prompt lengths are
    exact, not tp-multiples), each rank scatters its LOCAL kv-head shard
    of the emitted pages into its pool shard, and page ids/slot stay
    host-side and tp-agnostic. Returns (fn, cache_pspecs, pc)."""
    if paged is not None:
        pc = make_context(mesh, sp=False)
        local = make_paged_prefill_fn(ms, pc, paged, prompt_len)
        p_specs = T.param_pspecs(ms)
        _, c_specs = PG.paged_cache_meta(
            ms, n_slots=paged.n_slots, n_pages=paged.n_pages,
            page_size=paged.page_size, dtype=paged.cache_dtype)
        wrapped = shard_map(
            local, mesh=mesh,
            in_specs=(p_specs, c_specs, P(), P(), P(), P()),
            out_specs=(P(), c_specs),
            check_vma=False)
        return jax.jit(wrapped, donate_argnums=(1,)), c_specs, pc
    pc = make_context(mesh, sp=sp)
    local = make_prefill(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    _, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = dp if len(dp) > 1 else dp[0]
    in_specs = [p_specs, P(dp_ax, None)]
    # Extras ride positionally after ``tokens``: a [B, prefix_len, D]
    # patch-embedding prefix (vlm) and/or [B, enc_seq, D] encoder frames
    # (encdec) — both [B, S, D] with only the batch axis dp-sharded, so
    # every extra takes the same spec.
    n_extras = int(bool(ms.cfg.prefix_len)) + int(bool(ms.enc_segments))
    in_specs.extend([P(dp_ax, None, None)] * n_extras)

    def local_n(params, tokens, *extras):
        prefix = frames = None
        i = 0
        if ms.cfg.prefix_len:
            prefix = extras[i]; i += 1
        if ms.enc_segments:
            frames = extras[i]; i += 1
        return local(params, tokens, prefix, frames)

    wrapped = shard_map(
        local_n, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp_ax, "model"), c_specs),
        check_vma=False)
    return jax.jit(wrapped), c_specs, pc


def make_sharded_generate(ms: T.ModelStructure, mesh, sv: ServeConfig,
                          *, batch: int, prompt_len: int):
    """Build the one-shot sharded generation loop ONCE (prefill + serve
    step jits are per-instance, so reusing the returned closure is what
    makes a warm call actually warm the next one). Returns
    ``gen(params, prompts [batch, prompt_len], n_new, key=None) ->
    [batch, n_new] np.int32``.

    The prefill runs without sequence parallelism so the forward matches
    the engine's exact-length paged prefill shape-for-shape (SP would need
    prompt_len % tp == 0 and regroup the sequence reductions).
    """
    assert sv.temperature == 0.0, "sharded generation is the greedy reference"
    # Fail fast rather than silently dropping the prefix/frames extras the
    # ring prefill would expect positionally (transformer.forward_full runs
    # prefix-LM archs WITHOUT their prefix when prefix_embed is None).
    assert not ms.cfg.prefix_len and not ms.enc_segments, (
        f"{ms.cfg.name}: sharded one-shot generation does not take "
        "prefix/encoder extras yet")
    pre, _, _ = make_sharded_prefill(ms, mesh, sv, batch=batch,
                                     prompt_len=prompt_len, sp=False)
    step, _, _, _ = make_sharded_serve_step(ms, mesh, sv, batch=batch,
                                            shard_batch=False)

    def gen(params, prompts, n_new: int, key=None) -> np.ndarray:
        prompts = jnp.asarray(prompts, jnp.int32)
        assert prompts.shape == (batch, prompt_len), prompts.shape
        logits, caches = pre(params, prompts)
        # Gathered full-vocab logits: argmax's first-max tie-break equals
        # vocab_parallel_argmax's smallest-global-id rule.
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        toks = [np.asarray(tok)]
        key_ = key if key is not None else jax.random.PRNGKey(0)
        for i in range(n_new - 1):
            key_, sub = jax.random.split(key_)
            tok, caches = step(params, tok, caches, jnp.int32(prompt_len + i),
                               sub)
            toks.append(np.asarray(tok))
        return np.stack(toks, axis=1).astype(np.int32)

    return gen


def sharded_generate(params, prompts, n_new: int, *, ms: T.ModelStructure,
                     mesh, sv: ServeConfig, key=None) -> np.ndarray:
    """One-shot greedy generation under shard_map (ring cache, host decode
    loop): the tp > 1 reference stream the sharded paged engine is gated
    against. ``prompts``: [B, S] token ids. Returns [B, n_new] np.int32.
    One-off convenience over ``make_sharded_generate`` — compiles fresh
    programs per call; loops should build the factory once."""
    prompts = jnp.asarray(prompts, jnp.int32)
    B, S = prompts.shape
    return make_sharded_generate(ms, mesh, sv, batch=B, prompt_len=S)(
        params, prompts, n_new, key)
