"""Serving engine: batched prefill + autoregressive decode with LP models.

The engine exposes the three programs the assigned shapes lower:
  prefill_step  — logits + cache from a full prompt batch   (prefill_32k)
  serve_step    — ONE new token against the cache            (decode_32k /
                  long_500k; this is where LP's sync halving shows up —
                  seq=1 matmuls are tiny, so decode latency on a TP mesh is
                  dominated by the per-layer all-reduces the paper removes)
  generate      — host loop / scanned loop over serve_step

Sampling is vocab-parallel (Gumbel-max over the sharded vocabulary), so full
logits are never gathered.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.model import embedding as E
from repro.model import transformer as T
from repro.parallel.context import ParallelContext, make_context

PyTree = Any


@dataclass(frozen=True)
class ServeConfig:
    max_len: int = 1024           # KV-cache length
    temperature: float = 0.0      # 0 -> greedy
    kv_mode: str = "heads"        # heads | seq  (seq-sharded KV cache)
    cache_dtype: Any = jnp.bfloat16
    attn_impl: str = "auto"


# ---------------------------------------------------------------------------
# Local step functions (run under shard_map or plain)
# ---------------------------------------------------------------------------

def make_prefill(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    def prefill_fn(params, tokens, prefix=None, frames=None):
        logits, caches = T.prefill(
            params, tokens, ms=ms, pc=pc, max_len=sv.max_len,
            prefix_embed=prefix, enc_frames=frames, kv_mode=sv.kv_mode,
            attn_impl=sv.attn_impl, cache_dtype=sv.cache_dtype)
        return logits, caches
    return prefill_fn


def make_serve_step(ms: T.ModelStructure, pc: ParallelContext, sv: ServeConfig):
    """serve_step(params, tok [B], caches, t, key) -> (next_tok [B], caches).

    One full decode iteration: embed -> stack (1 psum per LP group phase) ->
    head -> vocab-parallel sample.
    """
    def serve_fn(params, tok, caches, t, key):
        logits, caches = T.decode_step(params, tok, caches, t, ms=ms, pc=pc,
                                       kv_mode=sv.kv_mode)
        if sv.temperature > 0:
            nxt = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
        else:
            nxt = E.vocab_parallel_argmax(logits, pc)
        return nxt.astype(jnp.int32), caches
    return serve_fn


def generate(params, prompts, n_new: int, *, ms: T.ModelStructure,
             pc: ParallelContext, sv: ServeConfig, key=None,
             prefix=None, frames=None):
    """Greedy/temperature generation: returns [B, n_new] new tokens.

    The decode loop is a lax.scan (one compiled program regardless of
    n_new), carrying (tok, caches, t, key).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    prefill_fn = make_prefill(ms, pc, sv)
    step_fn = make_serve_step(ms, pc, sv)
    logits, caches = prefill_fn(params, prompts, prefix, frames)
    if sv.temperature > 0:
        tok0 = E.vocab_parallel_sample(logits, key, sv.temperature, pc)
    else:
        tok0 = E.vocab_parallel_argmax(logits, pc)
    tok0 = tok0.astype(jnp.int32)
    t0 = prompts.shape[1] + (ms.cfg.prefix_len if prefix is not None else 0)

    def body(carry, i):
        tok, caches, key = carry
        key, sub = jax.random.split(key)
        # ``tok`` sits at absolute position t0 + i; its logits predict i+1.
        nxt, caches = step_fn(params, tok, caches, t0 + i, sub)
        return (nxt, caches, key), tok

    (last, _, _), toks = lax.scan(body, (tok0, caches, key),
                                  jnp.arange(n_new - 1))
    return jnp.concatenate([toks.T, last[:, None]], axis=1)


# ---------------------------------------------------------------------------
# Sharded wrappers (mesh execution + dry-run lowering)
# ---------------------------------------------------------------------------

def cache_pspecs(ms: T.ModelStructure, *, batch: int, sv: ServeConfig,
                 pc: ParallelContext, shard_batch: bool = True):
    """(abstract, pspec) for the global cache; batch sharded over dp when
    ``shard_batch`` (batch==1 long-context cells replicate it)."""
    abs_, ps_ = T.cache_meta(ms, batch=batch, max_len=sv.max_len,
                             kv_mode=sv.kv_mode, dtype=sv.cache_dtype)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None

    def add_dp(path, spec):
        # Shard the batch axis over dp: axis 1 for per-layer entries
        # ([count, batch, ...]), axis 2 for stacked pair entries
        # ([count, 2, batch, ...]) — see T.cache_batch_axis.
        parts = list(spec)
        parts[T.cache_batch_axis(path[-1].key)] = dp_ax
        return P(*parts)

    ps2 = jax.tree_util.tree_map_with_path(
        add_dp, ps_, is_leaf=lambda x: isinstance(x, P))
    return abs_, ps2


def make_sharded_serve_step(ms: T.ModelStructure, mesh, sv: ServeConfig,
                            *, batch: int, shard_batch: bool = True):
    """jit(shard_map(serve_step)) + its in/out specs, for execution and the
    decode-shape dry-run."""
    pc = make_context(mesh, sp=False)
    local = make_serve_step(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    c_abs, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc,
                                  shard_batch=shard_batch)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = (dp if len(dp) > 1 else dp[0]) if shard_batch else None
    tok_spec = P(dp_ax)
    wrapped = shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, tok_spec, c_specs, P(), P()),
        out_specs=(tok_spec, c_specs),
        check_vma=False)
    return jax.jit(wrapped, donate_argnums=(2,)), c_abs, c_specs, pc


def make_sharded_prefill(ms: T.ModelStructure, mesh, sv: ServeConfig,
                         *, batch: int, prompt_len: int, sp: bool = True):
    pc = make_context(mesh, sp=sp)
    local = make_prefill(ms, pc, sv)
    p_specs = T.param_pspecs(ms)
    _, c_specs = cache_pspecs(ms, batch=batch, sv=sv, pc=pc)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = dp if len(dp) > 1 else dp[0]
    in_specs = [p_specs, P(dp_ax, None)]
    n_extra = 0
    if ms.cfg.prefix_len:
        in_specs.append(P(dp_ax, None, None))
        n_extra += 1
    if ms.enc_segments:
        if not ms.cfg.prefix_len:
            in_specs.append(P(dp_ax, None, None))
        else:
            in_specs.append(P(dp_ax, None, None))
        n_extra += 1

    def local_n(params, tokens, *extras):
        prefix = frames = None
        i = 0
        if ms.cfg.prefix_len:
            prefix = extras[i]; i += 1
        if ms.enc_segments:
            frames = extras[i]; i += 1
        return local(params, tokens, prefix, frames)

    wrapped = shard_map(
        local_n, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp_ax, "model"), c_specs),
        check_vma=False)
    return jax.jit(wrapped), c_specs, pc
