"""Render EXPERIMENTS.md tables from the dry-run JSON artifacts.

    PYTHONPATH=src python -m repro.analysis.report
"""
from __future__ import annotations

import json
import os
import sys

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")


def load(name):
    p = os.path.join(RESULTS, f"{name}.json")
    if not os.path.exists(p):
        return {}
    with open(p) as f:
        return json.load(f)


def roofline_table(d, *, title):
    lines = [f"### {title}", "",
             "| cell | bneck | t_comp (s) | t_mem (s) | t_coll (s) | "
             "coll ops | peak GB | MF/HF | roofline |",
             "|---|---|---|---|---|---|---|---|---|"]
    for k in sorted(d):
        v = d[k]
        if "skipped" in v:
            lines.append(f"| {k} | — | — | — | — | — | — | — | "
                         f"skip: {v['skipped'][:40]} |")
            continue
        if "error" in v:
            lines.append(f"| {k} | ERROR {v['error'][:60]} | | | | | | | |")
            continue
        r = v["roofline"]
        m = v["memory"].get("peak_gb", float("nan"))
        lines.append(
            f"| {k} | {r['bottleneck']} | {r['t_compute_s']:.4f} | "
            f"{r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
            f"{int(r.get('coll_ops', 0))} | {m:.2f} | "
            f"{r['useful_fraction']:.3f} | {r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def summary_stats(d):
    ok = [v for v in d.values() if "roofline" in v]
    sk = [v for v in d.values() if "skipped" in v]
    er = [v for v in d.values() if "error" in v]
    over = [f"{v['arch']}/{v['shape']}" for v in ok
            if v["memory"].get("peak_gb", 0) > 16]
    return (f"{len(ok)} compiled, {len(sk)} documented skips, "
            f"{len(er)} errors; cells over the 16 GB HBM budget: "
            f"{', '.join(over) if over else 'none'}")


def main():
    for name, title in [("dryrun", "Single pod — (data=16, model=16), 256 chips"),
                        ("dryrun_mp", "Multi-pod — (pod=2, data=16, model=16), 512 chips")]:
        d = load(name)
        if not d:
            print(f"[{name}: no results yet]\n")
            continue
        print(roofline_table(d, title=title))
        print()
        print(f"**Summary:** {summary_stats(d)}")
        print()


if __name__ == "__main__":
    main()
