"""Roofline analysis from the compiled dry-run artifact.

TPU v5e target constants (per chip):
    peak bf16 compute : 197 TFLOP/s
    HBM bandwidth     : 819 GB/s
    ICI link bandwidth: ~50 GB/s   (intra-pod; DCI cross-pod is ~10x slower)

The three terms (seconds, per device, per step):
    compute    = HLO_FLOPs / peak
    memory     = HLO_bytes_accessed / hbm_bw
    collective = wire_bytes / ici_bw

cost_analysis() of the SPMD-partitioned module reports per-device FLOPs and
bytes. collective bytes are NOT in cost_analysis — ``collective_bytes``
parses the compiled HLO and sums result-shape bytes of every collective op
(all-reduce counts 2x for the ring's reduce+broadcast halves; cross-pod
groups are reported separately because they traverse DCI).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per chip (conservative single-link)
DCI_BW = 5e9              # bytes/s cross-pod (assumed 10x slower than ICI)
COLL_LAT = 1e-6           # per-collective launch+hop latency (the term the
                          # paper's LP attacks at decode: 2 ARs/layer -> 1)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<ty>\w+)\[(?P<dims>[\d,]*)\][^ ]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

_TUPLE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(ty: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(ty, 4)


def _group_size(line: str) -> int:
    """Participants per replica group (ring length) for a collective op."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [n_groups,group_size]
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len([x for x in m.group(1).split(",") if x.strip() != ""]), 1)
    return 2  # unknown: conservative

def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device wire bytes by collective kind, parsed from (SPMD-
    partitioned, hence per-device-shaped) HLO.

    Ring-algorithm wire model per device, with n = replica-group size and
    R = RESULT bytes (per-device local shape):
      all-gather          R is the gathered (full) tensor: (n-1)/n * R
      reduce-scatter      R is the scattered shard:        (n-1) * R
      all-reduce          R is the full tensor:          2*(n-1)/n * R
      all-to-all          (n-1)/n * R
      collective-permute  R (one neighbour hop)
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if m.group("ty"):
            b = _shape_bytes(m.group("ty"), m.group("dims"))
        else:  # tuple result: sum elements
            head = line.split(op)[0]
            b = sum(_shape_bytes(t, d) for t, d in _TUPLE_ELEM_RE.findall(head))
        n = _group_size(line)
        if op == "all-reduce":
            wire = 2.0 * b * (n - 1) / n
        elif op == "reduce-scatter":
            wire = float(b) * (n - 1)
        elif op == "collective-permute":
            wire = float(b)
        else:  # all-gather, all-to-all
            wire = float(b) * (n - 1) / n
        out[op] = out.get(op, 0.0) + wire
        out["total"] = out.get("total", 0.0) + wire
        out[f"count:{op}"] = out.get(f"count:{op}", 0) + 1
        out["n_ops"] = out.get("n_ops", 0) + 1
    return out


@dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll: Dict[str, float]
    model_flops: float = 0.0
    chips: int = 256
    useful_bytes: float = 0.0  # per-device payload bytes (weights + cache)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def n_coll(self) -> float:
        return self.coll.get("n_ops", 0.0)

    @property
    def t_collective(self) -> float:
        """Wire time + per-op latency. At decode (tiny payloads) the latency
        term dominates — exactly the cost LP halves."""
        return self.coll.get("total", 0.0) / ICI_BW + COLL_LAT * self.n_coll

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (per device)."""
        per_dev = self.model_flops / self.chips
        return per_dev / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the binding roofline achieved: time the step would
        take if it only did USEFUL work at the respective peak, over the
        bound time. For compute-bound steps this is MFU; for bandwidth-bound
        steps (decode) it is the fraction of HBM bandwidth spent on payload
        (weights + cache)."""
        per_dev = self.model_flops / self.chips
        t_useful = max(per_dev / PEAK_FLOPS, self.useful_bytes / HBM_BW)
        if self.t_bound == 0:
            return 0.0
        return t_useful / self.t_bound

    def row(self) -> Dict[str, object]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_gflops": self.flops / 1e9,
            "hlo_gbytes": self.bytes_accessed / 1e9,
            "coll_gbytes": self.coll.get("total", 0.0) / 1e9,
            "coll_ops": self.n_coll,
            "t_coll_latency_s": COLL_LAT * self.n_coll,
            "model_gflops_total": self.model_flops / 1e9,
            "useful_fraction": self.useful_fraction,
            "useful_gbytes": self.useful_bytes / 1e9,
            "bw_utilization": (self.useful_bytes / self.bytes_accessed
                               if self.bytes_accessed else 0.0),
            "roofline_fraction": self.roofline_fraction,
        }


def attention_flops(cfg, shape, *, tp: int = 16) -> float:
    """GLOBAL attention-core FLOPs for one step (additive
    correction: the tiled XLA core hides its kv-scan trip count from
    cost_analysis, so the dry-run adds the true core FLOPs analytically).

    qk^T + pv = 4 * S_kv_visible * hd flops per (query, head).
    """
    specs = cfg.layer_specs()
    S = shape.seq_len
    hd = cfg.head_dim
    hq = -(-max(cfg.n_heads, 1) // tp) * tp  # padded global head count
    total = 0.0
    for spec in specs:
        m = spec.mixer
        if not m.startswith("attn"):
            continue
        if shape.step == "decode":
            if m == "attn_local" and cfg.window:
                kv = min(cfg.window, S)
            elif m == "attn_chunked" and cfg.chunk:
                kv = min(cfg.chunk, S)
            else:
                kv = S
            per_seq = 4.0 * kv * hd * hq
            total += per_seq * shape.global_batch
            if spec.cross_attn:
                total += 4.0 * cfg.enc_seq * hd * hq * shape.global_batch
        else:
            if m == "attn_local" and cfg.window:
                vis = S * min(cfg.window, S)  # ~window per query
            elif m == "attn_chunked" and cfg.chunk:
                c = min(cfg.chunk, S)
                vis = (S // max(c, 1)) * (c * (c + 1) / 2)
            elif m == "attn_bidir":
                vis = S * S
            else:
                vis = S * (S + 1) / 2  # causal
            per_seq = 4.0 * vis * hd * hq
            total += per_seq * shape.global_batch
            if spec.cross_attn:
                total += 4.0 * S * cfg.enc_seq * hd * hq * shape.global_batch
    if cfg.enc_layers and shape.step != "decode":
        total += cfg.enc_layers * 4.0 * cfg.enc_seq ** 2 * hd * hq * shape.global_batch
    # train: forward + backward (2x fwd for the two grad matmuls each)
    if shape.step == "train":
        total *= 3.0
    return total  # GLOBAL; caller divides by chip count


def model_flops(cfg, shape, *, lp_plan=None) -> float:
    """MODEL_FLOPS per step: 6·N·D train, 2·N·D prefill, 2·N·B decode
    (N = active params excl. embeddings — the standard MFU convention)."""
    n_active = cfg.param_count(active_only=True)
    n_embed = cfg.vocab_size * cfg.d_model
    n = n_active - n_embed  # lm-head matmul is counted, lookup is not
    if shape.step == "train":
        return 6.0 * n * shape.tokens
    if shape.step == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


# ---------------------------------------------------------------------------
# Jaxpr structural counters (decode launch accounting)
# ---------------------------------------------------------------------------

def jaxpr_primitive_count(jaxpr, name: str) -> int:
    """Occurrences of primitive ``name`` in one EXECUTION of ``jaxpr``:
    scan bodies are weighted by their trip count, so the result is the true
    per-step launch count (e.g. ``pallas_call`` launches in one decode
    step) even when the stack is compiled as compact segment scans.

    Control flow whose execution count is not static is approximated:
    ``cond`` takes the MAX across branches (exactly one runs) and
    ``while`` bodies count once (a lower bound — trip counts are dynamic).

    ``jaxpr`` may be a ClosedJaxpr, a Jaxpr, or anything with a ``.jaxpr``.
    """
    inner = getattr(jaxpr, "jaxpr", jaxpr)

    def subcount(v):
        if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
            return jaxpr_primitive_count(v, name)
        return 0

    total = 0
    for eqn in inner.eqns:
        if eqn.primitive.name == name:
            total += 1
        if eqn.primitive.name == "cond":
            branches = eqn.params.get("branches", ())
            total += max((subcount(b) for b in branches), default=0)
            continue
        mult = eqn.params.get("length", 1) if eqn.primitive.name == "scan" else 1
        for v in eqn.params.values():
            if isinstance(v, (tuple, list)):
                total += mult * sum(subcount(x) for x in v)
            else:
                total += mult * subcount(v)
    return total
