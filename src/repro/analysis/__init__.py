from repro.analysis.roofline import Roofline, collective_bytes, model_flops  # noqa: F401
