"""Assigned input shapes for the LM-family architectures (40 cells total).

``step`` selects which program the dry-run lowers:
  train   -> train_step(tokens, labels)
  prefill -> prefill_step(tokens) -> logits + KV cache
  decode  -> serve_step(one new token against a pre-filled KV cache)
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and if not, why (documented skips)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""


def cells(cfg: ArchConfig):
    """All applicable (shape, skip_reason) pairs for an architecture."""
    out = []
    for s in ALL_SHAPES:
        ok, why = applicable(cfg, s)
        out.append((s, ok, why))
    return out
