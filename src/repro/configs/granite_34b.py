"""Granite-34B-Code — GPTBigCode-style: MQA (kv=1), non-gated GeLU MLP,
LayerNorm, learned absolute positions. [arXiv:2405.04324; hf]
"""
from repro.configs.base import ArchConfig, LayerSpec, register

GRANITE_34B = register(ArchConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    pos_embed="learned",
    max_position=8192,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    mlp_gated=False,
    mlp_act="gelu",
    norm_kind="layernorm",
    attn_bias=True,
    mlp_bias=True,
    notes="Deepest assigned arch (88L) — the scan-based stack keeps HLO size "
          "flat in depth. MQA kv=1 is replicated across TP for train/prefill "
          "and sequence-sharded for decode.",
))
