"""Yi-6B — llama-arch dense decoder with GQA. [arXiv:2403.04652; hf]"""
from repro.configs.base import ArchConfig, LayerSpec, register

YI_6B = register(ArchConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    mlp_gated=True,
    mlp_act="silu",
    norm_kind="rmsnorm",
    notes="Llama-style GQA; RoPE theta 5M for 4k context.",
))
