"""TinyLlama-1.1B — llama2-arch small. [arXiv:2401.02385; hf]"""
from repro.configs.base import ArchConfig, LayerSpec, register

TINYLLAMA_1B = register(ArchConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=5632,
    vocab_size=32000,
    rope_theta=10_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    mlp_gated=True,
    mlp_act="silu",
    norm_kind="rmsnorm",
))
