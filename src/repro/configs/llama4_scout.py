"""Llama-4-Scout-17B-16E backbone — MoE (16 experts, top-1, shared expert),
iRoPE attention: 3 chunked-attention layers per 1 global NoPE layer.
[hf:meta-llama/Llama-4-Scout-17B-16E]

40 heads do not divide the 16-way TP axis; padded to 48 (DESIGN.md).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

LLAMA4_SCOUT = register(ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,              # per-expert width
    vocab_size=202048,
    rope_theta=500_000.0,
    chunk=8192,
    block_pattern=(
        LayerSpec(mixer="attn_chunked", ffn="moe"),
        LayerSpec(mixer="attn_chunked", ffn="moe"),
        LayerSpec(mixer="attn_chunked", ffn="moe"),
        LayerSpec(mixer="attn_global", ffn="moe"),
    ),
    mlp_gated=True,
    mlp_act="silu",
    norm_kind="rmsnorm",
    moe_experts=16,
    moe_top_k=1,
    moe_shared_expert=True,
    subquadratic=True,      # 3/4 of layers use chunk-8192 attention
    notes="Chunked attention keeps 500k-decode KV per chip bounded; the 12 "
          "global NoPE layers shard their KV along sequence over the model axis.",
))
