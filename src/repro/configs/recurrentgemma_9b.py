"""RecurrentGemma-9B — Griffin hybrid: RG-LRU recurrent blocks + local
attention at a 2:1 ratio (pattern rec, rec, attn_local). [arXiv:2402.19427]
"""
from repro.configs.base import ArchConfig, LayerSpec, register

RECURRENTGEMMA_9B = register(ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,  # 12 full (rec,rec,attn) periods + trailing (rec, rec)
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    window=2048,
    rope_theta=10_000.0,
    block_pattern=(
        LayerSpec(mixer="rec", ffn="mlp"),
        LayerSpec(mixer="rec", ffn="mlp"),
        LayerSpec(mixer="attn_local", ffn="mlp"),
    ),
    mlp_gated=True,
    mlp_act="gelu",          # GeGLU
    norm_kind="rmsnorm",
    norm_plus_one=True,      # gemma-style (1 + scale)
    lru_width=4096,
    rec_conv=4,
    subquadratic=True,       # window-2048 attention + constant-state RG-LRU
    notes="LP pairs the two consecutive RG-LRU layers of each period; the "
          "lone local-attention layer stays sequential.",
))
