"""Whisper-medium backbone — encoder-decoder transformer; the conv audio
frontend is a STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356]

24 encoder layers (bidirectional attention) + 24 decoder layers (causal
self-attention + cross-attention). LayerNorm, GeLU, non-gated MLP, learned
positions — faithful to the original.
"""
from repro.configs.base import ArchConfig, LayerSpec, register

WHISPER_MEDIUM = register(ArchConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,          # decoder depth
    enc_layers=24,        # encoder depth
    enc_seq=1500,         # 30 s of audio at 50 Hz after the conv stub
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    pos_embed="learned",
    max_position=32768 + 8,  # decode_32k exercises a 32k decoder context
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp", cross_attn=True),),
    mlp_gated=False,
    mlp_act="gelu",
    norm_kind="layernorm",
    attn_bias=True,
    mlp_bias=True,
    notes="Conv frontend stubbed: encoder consumes (B, 1500, d_model) frame "
          "embeddings. Decoder-side LP pairs both self- and cross-attention "
          "sub-blocks of consecutive layers.",
))
