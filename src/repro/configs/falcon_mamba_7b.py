"""Falcon-Mamba-7B — pure Mamba-1 SSM, attention-free. [arXiv:2410.05355]"""
from repro.configs.base import ArchConfig, LayerSpec, register

FALCON_MAMBA_7B = register(ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                 # attention-free, FFN-free: each layer is one mixer
    vocab_size=65024,
    block_pattern=(LayerSpec(mixer="mamba", ffn=None),),
    norm_kind="rmsnorm",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,           # d_inner = 8192
    dt_rank=256,
    pos_embed="none",
    subquadratic=True,      # constant-size recurrent state
    notes="LP generalises to paired residual mixer blocks: "
          "y = x + M_k(LN_k x) + M_{k+1}(LN_{k+1} x) — one psum per pair.",
))
