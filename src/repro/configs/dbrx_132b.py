"""DBRX-132B — fine-grained MoE: 16 experts, top-4. [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig, LayerSpec, register

DBRX_132B = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    rope_theta=500_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="moe"),),
    mlp_gated=True,
    mlp_act="silu",
    norm_kind="layernorm",
    moe_experts=16,
    moe_top_k=4,
    notes="Largest assigned arch (132B total / ~36B active). ZeRO-1 over the "
          "data axis is mandatory for the optimizer state to fit 16 GB chips.",
))
