"""Architecture & shape registry. Importing this package registers all
assigned architectures."""
from repro.configs.base import (  # noqa: F401
    ArchConfig,
    LayerSpec,
    get_config,
    list_archs,
    reduced_config,
    register,
)
from repro.configs.shapes import (  # noqa: F401
    ALL_SHAPES,
    SHAPES,
    ShapeConfig,
    applicable,
    cells,
)

# Registration side effects — one module per assigned architecture.
from repro.configs import (  # noqa: F401
    dbrx_132b,
    falcon_mamba_7b,
    granite_34b,
    llama4_scout,
    minicpm_2b,
    paligemma_3b,
    recurrentgemma_9b,
    tinyllama_1b,
    whisper_medium,
    yi_6b,
)

ASSIGNED_ARCHS = (
    "yi-6b",
    "minicpm-2b",
    "granite-34b",
    "tinyllama-1.1b",
    "whisper-medium",
    "recurrentgemma-9b",
    "falcon-mamba-7b",
    "llama4-scout-17b-a16e",
    "dbrx-132b",
    "paligemma-3b",
)
