"""PaliGemma-3B backbone — Gemma-2B decoder + SigLIP vision frontend STUB
(input_specs provides 256 precomputed patch embeddings as a full-attention
prefix). [arXiv:2407.07726; hf]

8 heads do not divide the 16-way TP axis; padded to 16 (DESIGN.md).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

PALIGEMMA_3B = register(ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    rope_theta=10_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    mlp_gated=True,
    mlp_act="gelu",          # GeGLU
    norm_kind="rmsnorm",
    norm_plus_one=True,
    tie_embeddings=True,
    prefix_len=256,          # SigLIP patch tokens (prefix-LM attention)
    notes="Prefix tokens attend bidirectionally (prefix-LM mask); text suffix "
          "is causal. The SigLIP tower is outside the assignment scope (stub).",
))
