"""Architecture + shape configuration for the LP framework.

Every assigned architecture is expressed as an ``ArchConfig`` built from a
repeating ``LayerSpec`` pattern so the scan-based stack assembly
(`repro.model.transformer`) can compile one homogeneous body per pattern
position regardless of total depth.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

#: Temporal-mixing kinds understood by the model zoo.
MIXERS = (
    "attn",          # causal full attention (RoPE unless pos_embed overrides)
    "attn_bidir",    # bidirectional attention (whisper encoder)
    "attn_local",    # sliding-window causal attention
    "attn_chunked",  # llama4-style chunked causal attention
    "attn_global",   # causal full attention without RoPE (llama4 NoPE layers)
    "rec",           # RG-LRU recurrent block (recurrentgemma)
    "mamba",         # Mamba-1 selective SSM mixer (whole layer, no separate FFN)
)

FFNS = ("mlp", "moe", None)


@dataclass(frozen=True)
class LayerSpec:
    """One layer position inside the repeating block pattern."""

    mixer: str = "attn"
    ffn: Optional[str] = "mlp"
    cross_attn: bool = False  # decoder cross-attention (whisper)

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # Attention details
    rope_theta: float = 10_000.0
    window: int = 0          # sliding-window size for attn_local
    chunk: int = 0           # chunk size for attn_chunked
    pos_embed: str = "rope"  # rope | learned | none
    max_position: int = 8192  # learned-position table size
    qk_norm: bool = False

    # Block structure
    block_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    mlp_gated: bool = True
    mlp_act: str = "silu"
    norm_kind: str = "rmsnorm"   # rmsnorm | layernorm
    norm_plus_one: bool = False  # gemma-style (1 + scale) RMSNorm
    attn_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    moe_shared_expert: bool = False

    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # RG-LRU (recurrentgemma)
    lru_width: int = 0  # 0 -> d_model
    rec_conv: int = 4

    # Encoder-decoder (whisper): encoder depth + frontend-stub sequence length
    enc_layers: int = 0
    enc_seq: int = 1500

    # VLM (paligemma): number of precomputed patch-embedding prefix tokens
    prefix_len: int = 0

    # Sub-quadratic? (decides long_500k applicability)
    subquadratic: bool = False

    notes: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.dt_rank == 0 and self.family == "ssm":
            object.__setattr__(self, "dt_rank", math.ceil(self.d_model / 16))
        if self.lru_width == 0 and self.family == "hybrid":
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        """Expand the repeating pattern to n_layers entries (truncating the
        final repeat when n_layers % len(pattern) != 0, e.g. recurrentgemma)."""
        period = len(self.block_pattern)
        reps = math.ceil(self.n_layers / period)
        return tuple((self.block_pattern * reps)[: self.n_layers])

    def param_count(self, *, active_only: bool = False) -> int:
        """Approximate parameter count (embedding + per-layer), used for the
        6·N·D MODEL_FLOPS roofline term."""
        n = 0
        n += self.vocab_size * self.d_model  # embedding
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # unembedding
        for spec in self.layer_specs():
            n += self._layer_params(spec, active_only=active_only)
        # Encoder stack (whisper): self-attention + MLP, no cross-attention.
        enc_spec = LayerSpec(mixer="attn_bidir", ffn="mlp")
        for _ in range(self.enc_layers):
            n += self._layer_params(enc_spec, active_only=active_only)
        return n

    def _layer_params(self, spec: LayerSpec, *, active_only: bool) -> int:
        d = self.d_model
        n = 0
        if spec.mixer.startswith("attn"):
            q = self.n_heads * self.head_dim * d
            kv = 2 * self.n_kv_heads * self.head_dim * d
            o = self.n_heads * self.head_dim * d
            n += q + kv + o
        elif spec.mixer == "rec":
            w = self.lru_width
            n += 2 * d * w  # in projections (x, gate branch)
            n += w * d      # out projection
            n += self.rec_conv * w + 3 * w  # conv + lru gates
        elif spec.mixer == "mamba":
            di = self.d_inner
            n += d * 2 * di               # in_proj
            n += self.ssm_conv * di       # conv1d
            n += di * (self.dt_rank + 2 * self.ssm_state)  # x_proj
            n += self.dt_rank * di + di   # dt_proj
            n += di * self.ssm_state + di  # A_log, D
            n += di * d                   # out_proj
        if spec.cross_attn:
            q = self.n_heads * self.head_dim * d
            kv = 2 * self.n_kv_heads * self.head_dim * d
            o = self.n_heads * self.head_dim * d
            n += q + kv + o
        if spec.ffn == "mlp":
            mats = 3 if self.mlp_gated else 2
            n += mats * d * self.d_ff
        elif spec.ffn == "moe":
            mats = 3 if self.mlp_gated else 2
            per_expert = mats * d * self.d_ff
            experts = self.moe_top_k if active_only else self.moe_experts
            n += experts * per_expert
            if self.moe_shared_expert:
                n += per_expert
            n += d * self.moe_experts  # router
        return n


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, f"duplicate arch {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    # Import side-effect registration lazily to avoid cycles.
    from repro import configs as _  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    from repro import configs as _  # noqa: F401

    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------

def reduced_config(cfg: ArchConfig, *, n_layers: int | None = None) -> ArchConfig:
    """Scale an architecture down to CPU-smoke size, preserving its family
    structure (pattern, gating, norm kind, MoE/SSM topology)."""
    period = len(cfg.block_pattern)
    layers = n_layers if n_layers is not None else max(2 * period, 2)
    heads = min(cfg.n_heads, 4) or 4  # attn-free archs (n_heads=0) still need d_model
    kv = max(1, min(cfg.n_kv_heads, heads))
    hd = 16
    d_model = heads * hd * 2
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=layers,
        d_model=d_model,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=hd,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=512,
        window=min(cfg.window, 16) if cfg.window else 0,
        chunk=min(cfg.chunk, 16) if cfg.chunk else 0,
        max_position=512,
        moe_experts=min(cfg.moe_experts, 4) if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        dt_rank=8 if cfg.family == "ssm" else 0,
        lru_width=d_model if cfg.family == "hybrid" else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_seq=24 if cfg.enc_layers else 1500,
        prefix_len=8 if cfg.prefix_len else 0,
    )
