"""MiniCPM-2B — llama-like dense MHA, trained with WSD schedule.
[arXiv:2404.06395; hf]

36 heads do not divide the 16-way TP axis; the runtime pads to 48 heads with
zero-initialised heads (see DESIGN.md §Hardware-adaptation).
"""
from repro.configs.base import ArchConfig, LayerSpec, register

MINICPM_2B = register(ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=10_000.0,
    block_pattern=(LayerSpec(mixer="attn", ffn="mlp"),),
    mlp_gated=True,
    mlp_act="silu",
    norm_kind="rmsnorm",
    notes="MHA (kv=36). The paper's WSD LR schedule is implemented in "
          "repro.train.optimizer and enabled by this arch's train recipe.",
))
