"""Distributed trainer: ZeRO-sharded AdamW train step, built once as a LOCAL
function and run either directly (CPU unit tests) or inside shard_map over
the production mesh (launch/train.py, launch/dryrun.py).

State layout
------------
  params : model-dtype tree, GLOBAL shapes. Two leaf families:
           * regular leaves — tp-sharded via the param pspecs, replicated
             over dp; optimizer state is flat ZeRO shards (dp, tp, chunk)
           * FSDP leaves (ms.fsdp segments) — flat (count, data, tp, chunk)
             shards; the forward all-gathers one group at a time and AD
             reduce-scatters the grads (repro.parallel.fsdp)
  master : fp32 master weights; same flat layouts
  m, v   : AdamW moments, like master
  step   : int32 scalar
  err    : optional int8-compression error feedback (compress_pod)

Collective schedule per step (the distributed-optimization tricks):
  * grads for tp-REPLICATED leaves: one psum over `model`
  * regular-leaf ZeRO reduction: hierarchical psum_scatter — exact over the
    intra-pod `data` axis, optionally int8+error-feedback compressed over
    the cross-pod `pod` (DCI) axis
  * FSDP-leaf grads: reduce_scatter over `data` comes out of AD; cross-pod
    one psum (optionally compressed)
  * global-norm clip: one scalar psum
  * fresh forward params: one all_gather over dp for regular leaves; FSDP
    leaves stay flat (gathers happen per group inside the forward)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.model import transformer as T
from repro.model.params import abstract_tree, init_tree, is_pd, pspec_tree
from repro.parallel import zero
from repro.parallel.compress import compress_psum
from repro.parallel.context import ParallelContext
from repro.train.optimizer import OptConfig, adamw_update, schedule_lr

PyTree = Any


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum: int = 1                 # gradient-accumulation microbatches
    remat: bool = False            # activation checkpointing per group
    param_dtype: Any = jnp.float32  # bf16 on TPU
    compress_pod: bool = False     # int8+EF gradient compression on `pod`
    finetune_lp_only: bool = False  # paper Table 2: train LP segments only
    aux_weight: float = 1e-2
    attn_impl: str = "auto"
    scan_impl: str = "chunked"


# ---------------------------------------------------------------------------
# Leaf metadata (regular vs FSDP)
# ---------------------------------------------------------------------------

def _local_shape(shape, pspec, tp: int):
    out = []
    for i, dim in enumerate(shape):
        ax = pspec[i] if i < len(pspec) else None
        names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        f = 1
        for nm in names:
            f *= tp if nm == "model" else 1
        assert dim % f == 0, (shape, pspec, tp)
        out.append(dim // f)
    return tuple(out)


def _tp_sharded(pspec) -> bool:
    for ax in pspec:
        names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        if "model" in names:
            return True
    return False


def _chunk(shape, pspec, pc: ParallelContext) -> int:
    n = 1
    for d in _local_shape(shape, pspec, pc.tp_size):
        n *= d
    return -(-n // pc.dp_size)


def _sharded_dim(pspec) -> Optional[int]:
    for i, ax in enumerate(pspec):
        names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        if "model" in names:
            return i
    return None


@dataclass(frozen=True)
class LeafInfo:
    pd: Any                 # PD descriptor (of the STORED layout)
    pspec: Any
    wd: float               # weight-decay mask
    tp_sharded: bool        # distinct values across the model axis?
    fsdp: bool


def _leaf_meta(ms: T.ModelStructure):
    """(template, treedef, [LeafInfo]) in flattened order."""
    tmpl = T.model_template(ms)
    leaves, treedef = jax.tree.flatten(tmpl, is_leaf=is_pd)

    wd_t = jax.tree.map(lambda pd: 1.0 if len(pd.shape) >= 2 else 0.0,
                        tmpl, is_leaf=is_pd)
    tpf_t = jax.tree.map(lambda pd: _tp_sharded(pd.pspec), tmpl, is_leaf=is_pd)
    ff_t = jax.tree.map(lambda pd: False, tmpl, is_leaf=is_pd)
    if ms.fsdp:
        metas = T.segment_metas(ms)
        wd_t["segments"] = [m.treedef.unflatten(list(m.wd_flags)) for m in metas]
        tpf_t["segments"] = [m.treedef.unflatten(list(m.tp_flags)) for m in metas]
        ff_t["segments"] = [jax.tree.map(lambda pd: True, st, is_leaf=is_pd)
                            for st in tmpl["segments"]]
    infos = [
        LeafInfo(pd, pd.pspec, wd, tpf, ff)
        for pd, wd, tpf, ff in zip(
            leaves, treedef.flatten_up_to(wd_t), treedef.flatten_up_to(tpf_t),
            treedef.flatten_up_to(ff_t))
    ]
    return tmpl, treedef, infos


# ---------------------------------------------------------------------------
# Flat-state packing for REGULAR leaves
# ---------------------------------------------------------------------------

def to_flat_global(x, pspec, pc: ParallelContext):
    """GLOBAL param tensor -> GLOBAL flat state leaf (dp, tp, chunk)."""
    tp, dp = pc.tp_size, pc.dp_size
    d = _sharded_dim(pspec)
    if d is None:
        locs = jnp.broadcast_to(x.reshape(1, -1), (tp, x.size))
    else:
        s = x.shape[d]
        locs = jnp.moveaxis(
            x.reshape(*x.shape[:d], tp, s // tp, *x.shape[d + 1:]), d, 0
        ).reshape(tp, -1)
    n = locs.shape[1]
    pad = (-n) % dp
    if pad:
        locs = jnp.pad(locs, ((0, 0), (0, pad)))
    return locs.reshape(tp, dp, -1).transpose(1, 0, 2).astype(jnp.float32)


def from_flat_global(flat, shape, pspec, pc: ParallelContext, dtype=jnp.float32):
    """Inverse of ``to_flat_global`` (mesh-agnostic checkpoint path)."""
    tp = pc.tp_size
    d = _sharded_dim(pspec)
    loc_shape = _local_shape(shape, pspec, tp)
    n = 1
    for s in loc_shape:
        n *= s
    locs = flat.transpose(1, 0, 2).reshape(tp, -1)[:, :n]
    if d is None:
        return locs[0].reshape(shape).astype(dtype)
    parts = locs.reshape(tp, *loc_shape)
    out = jnp.moveaxis(parts, 0, d)
    return out.reshape(shape).astype(dtype)


def _pod_data(pc: ParallelContext) -> Tuple[int, int]:
    if "pod" not in pc.dp_axes:
        return 1, pc.dp_size
    return pc.pod_size, pc.dp_size // pc.pod_size


# ---------------------------------------------------------------------------
# State construction
# ---------------------------------------------------------------------------

def init_state(ms: T.ModelStructure, key, pc: ParallelContext,
               tc: TrainConfig) -> Dict[str, Any]:
    """GLOBAL train state (pure function of key — jit with out_shardings to
    materialise sharded on a mesh)."""
    tmpl, treedef, infos = _leaf_meta(ms)
    params32 = T.init_params(ms, key, jnp.float32)  # FSDP leaves pre-packed
    flat_p = treedef.flatten_up_to(params32)
    master = treedef.unflatten([
        x if li.fsdp else to_flat_global(x, li.pspec, pc)
        for x, li in zip(flat_p, infos)])
    state = {
        "params": jax.tree.map(lambda x: x.astype(tc.param_dtype), params32),
        "master": master,
        "m": jax.tree.map(jnp.zeros_like, master),
        "v": jax.tree.map(jnp.zeros_like, master),
        "step": jnp.zeros((), jnp.int32),
    }
    if tc.compress_pod:
        state["err"] = _err_init(ms, pc, tc)
    return state


def _err_shape(li: LeafInfo, pc: ParallelContext):
    pod, _ = _pod_data(pc)
    if li.fsdp:
        return li.pd.shape  # (count, data, tp, chunk) — same layout
    return (pc.dp_size, pc.tp_size, pod, _chunk(li.pd.shape, li.pspec, pc))


def _err_init(ms, pc, tc):
    _, treedef, infos = _leaf_meta(ms)
    return treedef.unflatten(
        [jnp.zeros(_err_shape(li, pc), jnp.float32) for li in infos])


def _err_pspec(li: LeafInfo, pc: ParallelContext):
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = dp if len(dp) > 1 else dp[0]
    if li.fsdp:
        return li.pspec
    return P(dp_ax, "model", None, None)


def state_pspecs(ms: T.ModelStructure, pc: ParallelContext,
                 tc: TrainConfig) -> Dict[str, Any]:
    tmpl, treedef, infos = _leaf_meta(ms)
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = dp if len(dp) > 1 else dp[0]
    flat_spec = treedef.unflatten([
        li.pspec if li.fsdp else P(dp_ax, "model", None) for li in infos])
    out = {
        "params": pspec_tree(tmpl),
        "master": flat_spec,
        "m": flat_spec,
        "v": jax.tree.map(lambda x: x, flat_spec),
        "step": P(),
    }
    if tc.compress_pod:
        out["err"] = treedef.unflatten([_err_pspec(li, pc) for li in infos])
    return out


def abstract_state(ms: T.ModelStructure, pc: ParallelContext,
                   tc: TrainConfig) -> Dict[str, Any]:
    tmpl, treedef, infos = _leaf_meta(ms)
    flat = treedef.unflatten([
        jax.ShapeDtypeStruct(
            li.pd.shape if li.fsdp else
            (pc.dp_size, pc.tp_size, _chunk(li.pd.shape, li.pspec, pc)),
            jnp.float32)
        for li in infos])
    out = {
        "params": abstract_tree(tmpl, tc.param_dtype),
        "master": flat,
        "m": jax.tree.map(lambda x: x, flat),
        "v": jax.tree.map(lambda x: x, flat),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if tc.compress_pod:
        out["err"] = treedef.unflatten([
            jax.ShapeDtypeStruct(_err_shape(li, pc), jnp.float32)
            for li in infos])
    return out


# ---------------------------------------------------------------------------
# Gradient reduction for REGULAR leaves (hierarchical, pod-compressed)
# ---------------------------------------------------------------------------

def _reduce_grads(g32, err, pc: ParallelContext, tc: TrainConfig):
    """Local fp32 grad leaf -> this rank's mean-grad flat shard (chunk,)."""
    dp = pc.dp_size
    flat = zero.flatten_leaf(g32, dp)  # (dp, chunk)
    if dp == 1:
        return flat[0], err
    pod, data = _pod_data(pc)
    if pod == 1 or not tc.compress_pod:
        return pc.psum_scatter_dp(flat, axis=0)[0] / dp, err
    chunk = flat.shape[1]
    f3 = flat.reshape(pod, data, chunk)
    s1 = lax.psum_scatter(f3, "data", scatter_dimension=1, tiled=True)
    s1 = s1.reshape(pod, chunk)
    s2, new_err = compress_psum(s1, ("pod",), err)
    row = lax.axis_index("pod")
    shard = lax.dynamic_index_in_dim(s2, row, axis=0, keepdims=False)
    return shard / dp, new_err


def _reduce_grads_fsdp(g32, err, li: LeafInfo, pc: ParallelContext,
                       tc: TrainConfig):
    """FSDP leaf: AD already reduce-scattered over `data`; finish the mean
    across `pod` (and sync tp-replicated leaves)."""
    if not li.tp_sharded:
        g32 = pc.psum_tp(g32)
    pod, _ = _pod_data(pc)
    if pod > 1:
        if tc.compress_pod:
            g32, err = compress_psum(g32, ("pod",), err)
        else:
            g32 = lax.psum(g32, "pod")
    return g32 / pc.dp_size, err


# ---------------------------------------------------------------------------
# The train step (local function — identical under shard_map and on CPU)
# ---------------------------------------------------------------------------

def make_train_step(ms: T.ModelStructure, pc: ParallelContext, tc: TrainConfig):
    tmpl, treedef, infos = _leaf_meta(ms)
    ft_mask = None
    if tc.finetune_lp_only:
        # Paper Table 2: only the LP-paired segments are trainable.
        full = jax.tree.map(lambda pd: 0.0, tmpl, is_leaf=is_pd)
        full["segments"] = [
            jax.tree.map(lambda pd: 1.0 if seg.group.pair else 0.0, st,
                         is_leaf=is_pd)
            for st, seg in zip(tmpl["segments"], ms.segments)]
        ft_mask = treedef.flatten_up_to(full)

    def loss_of(params, micro):
        return T.loss_fn(params, micro, ms=ms, pc=pc, remat=tc.remat,
                         attn_impl=tc.attn_impl, scan_impl=tc.scan_impl,
                         aux_weight=tc.aux_weight)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if tc.accum == 1:
            (loss, parts), grads = grad_fn(params, batch)
            return loss, parts, jax.tree.map(
                lambda g: g.astype(jnp.float32), grads)

        def micro_of(i):
            return jax.tree.map(
                lambda x: x.reshape(tc.accum, x.shape[0] // tc.accum,
                                    *x.shape[1:])[i], batch)

        def body(carry, i):
            acc, loss_sum = carry
            (loss, parts), grads = grad_fn(params, micro_of(i))
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / tc.accum, acc, grads)
            return (acc, loss_sum + loss / tc.accum), parts

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), parts = lax.scan(body, (zeros, jnp.float32(0.0)),
                                        jnp.arange(tc.accum))
        parts = jax.tree.map(lambda x: x.mean(), parts)
        return loss, parts, grads

    pod, _ = _pod_data(pc)

    def step_fn(state, batch):
        params = state["params"]
        loss, parts, grads = compute_grads(params, batch)

        flat_g = treedef.flatten_up_to(grads)
        if ft_mask is not None:
            flat_g = [g * m for g, m in zip(flat_g, ft_mask)]

        errs = (treedef.flatten_up_to(state["err"]) if "err" in state
                else [None] * len(flat_g))
        shards, new_errs = [], []
        for g, e, li in zip(flat_g, errs, infos):
            if li.fsdp:
                # local grad view (count, 1, 1, chunk); err same layout
                s, ne = _reduce_grads_fsdp(g, e, li, pc, tc)
            else:
                if not li.tp_sharded:
                    g = pc.psum_tp(g)
                e0 = e[0, 0] if e is not None else None
                s, ne = _reduce_grads(g, e0, pc, tc)
                if ne is not None:
                    ne = ne[None, None]
            shards.append(s)
            new_errs.append(ne)

        # Global grad-norm: shards partition over (data x leaves); fsdp
        # leaves are pod-replicated (divide by pod); tp-sharded leaves need
        # the model-axis psum, replicated ones must count once.
        sq_sh = jnp.float32(0.0)
        sq_rp = jnp.float32(0.0)
        for s, li in zip(shards, infos):
            contrib = jnp.sum(jnp.square(s))
            if li.fsdp:
                contrib = contrib / pod
            if li.tp_sharded:
                sq_sh = sq_sh + contrib
            else:
                sq_rp = sq_rp + contrib
        sq = pc.psum_dp(pc.psum_tp(sq_sh) + sq_rp)
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, tc.opt.grad_clip / jnp.maximum(gnorm, 1e-12))

        lr = schedule_lr(tc.opt, state["step"])
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(state["master"])
        flat_like = treedef.flatten_up_to(params)
        new_p, new_m, new_v, new_params = [], [], [], []
        for g, m_, v_, p_, li, like in zip(
                shards, flat_m, flat_v, flat_p, infos, flat_like):
            if li.fsdp:
                np_, nm, nv = adamw_update(g * scale, m_, v_, p_,
                                           state["step"], tc.opt, lr=lr,
                                           wd_mask=li.wd)
                new_p.append(np_)
                new_m.append(nm)
                new_v.append(nv)
                new_params.append(np_.astype(tc.param_dtype))
            else:
                m0, v0, p0 = m_[0, 0], v_[0, 0], p_[0, 0]
                np_, nm, nv = adamw_update(g * scale, m0, v0, p0,
                                           state["step"], tc.opt, lr=lr,
                                           wd_mask=li.wd)
                new_p.append(np_[None, None])
                new_m.append(nm[None, None])
                new_v.append(nv[None, None])
                # Fresh forward tensor: ONE all_gather over dp. ``like`` is
                # the rank-LOCAL view, so reshape straight back to it.
                full = pc.all_gather_dp(np_[None, :], axis=0)
                new_params.append(full.reshape(-1)[:like.size]
                                  .reshape(like.shape).astype(tc.param_dtype))

        new_state = {
            "params": treedef.unflatten(new_params),
            "master": treedef.unflatten(new_p),
            "m": treedef.unflatten(new_m),
            "v": treedef.unflatten(new_v),
            "step": state["step"] + 1,
        }
        if "err" in state:
            new_state["err"] = treedef.unflatten(new_errs)
        metrics = {
            "loss": pc.pmean_dp(loss),
            "xent": pc.pmean_dp(parts["xent"]),
            "grad_norm": gnorm,
            "lr": lr,
        }
        return new_state, metrics

    return step_fn


def state_from_params(params32, ms: T.ModelStructure, pc: ParallelContext,
                      tc: TrainConfig) -> Dict[str, Any]:
    """Fresh optimizer state around EXISTING fp32 params (e.g. an LP-converted
    pretrained model about to be recovery-fine-tuned, paper Table 2)."""
    tmpl, treedef, infos = _leaf_meta(ms)
    flat_p = treedef.flatten_up_to(params32)
    master = treedef.unflatten([
        x.astype(jnp.float32) if li.fsdp else to_flat_global(x, li.pspec, pc)
        for x, li in zip(flat_p, infos)])
    state = {
        "params": jax.tree.map(lambda x: x.astype(tc.param_dtype), params32),
        "master": master,
        "m": jax.tree.map(jnp.zeros_like, master),
        "v": jax.tree.map(jnp.zeros_like, master),
        "step": jnp.zeros((), jnp.int32),
    }
    if tc.compress_pod:
        state["err"] = _err_init(ms, pc, tc)
    return state


def make_eval_step(ms: T.ModelStructure, pc: ParallelContext, tc: TrainConfig):
    def eval_fn(params, batch):
        loss, parts = T.loss_fn(params, batch, ms=ms, pc=pc,
                                attn_impl=tc.attn_impl, scan_impl=tc.scan_impl,
                                aux_weight=tc.aux_weight)
        return {"loss": pc.pmean_dp(loss), "xent": pc.pmean_dp(parts["xent"])}
    return eval_fn


# ---------------------------------------------------------------------------
# Sharded wrappers
# ---------------------------------------------------------------------------

def batch_pspecs(pc: ParallelContext, batch_tree):
    dp = tuple(pc.dp_axes) if pc.dp_axes else (None,)
    dp_ax = dp if len(dp) > 1 else dp[0]
    return jax.tree.map(lambda x: P(dp_ax, *([None] * (x.ndim - 1))), batch_tree)


def make_sharded_train_step(ms: T.ModelStructure, mesh, tc: TrainConfig,
                            batch_abstract, *, sp: bool = True, donate=True):
    """jit(shard_map(train_step)) over the production mesh.

    Returns (jitted_fn, state_pspec_tree, batch_pspec_tree, pc).
    """
    from repro.parallel.context import make_context

    pc = make_context(mesh, sp=sp)
    local = make_train_step(ms, pc, tc)
    s_specs = state_pspecs(ms, pc, tc)
    b_specs = batch_pspecs(pc, batch_abstract)
    from repro.compat import shard_map
    wrapped = shard_map(
        local, mesh=mesh,
        in_specs=(s_specs, b_specs),
        out_specs=(s_specs, {"loss": P(), "xent": P(), "grad_norm": P(),
                             "lr": P()}),
        check_vma=False)
    jitted = jax.jit(wrapped, donate_argnums=(0,) if donate else ())
    return jitted, s_specs, b_specs, pc
