from repro.train.optimizer import OptConfig, schedule_lr  # noqa: F401
from repro.train.trainer import (  # noqa: F401
    TrainConfig,
    abstract_state,
    init_state,
    make_eval_step,
    make_sharded_train_step,
    make_train_step,
    state_pspecs,
)
from repro.train import checkpoint  # noqa: F401
