"""Fault-tolerant, mesh-agnostic checkpointing.

Checkpoints store MESH-AGNOSTIC content: master weights and AdamW moments in
their unflattened PARAM shapes (fp32) plus the step counter. Restoring onto a
different mesh (elastic re-scale, node loss -> smaller slice) re-flattens the
same logical arrays under the new (dp, tp) geometry — no resharding tool
needed.

Durability protocol (survives a kill at any point):
  1. write every leaf to  <dir>/step_N.tmp/arr_<k>.npy
  2. write manifest.json (tree structure, shapes, dtypes, sha256 per leaf)
  3. fsync files, atomically rename step_N.tmp -> step_N
  4. atomically update <dir>/LATEST to point at step_N

``save_async`` runs steps 1-4 on a background thread (double-buffered:
a save must finish before the next begins; the training loop never blocks
on I/O unless it laps the writer).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.model import transformer as T
from repro.model.params import is_pd
from repro.parallel.context import ParallelContext
from repro.train.trainer import TrainConfig, from_flat_global, to_flat_global

PyTree = Any


# ---------------------------------------------------------------------------
# Pack/unpack: train state <-> mesh-agnostic logical arrays
# ---------------------------------------------------------------------------

def _regular_structure(ms: T.ModelStructure) -> T.ModelStructure:
    """The non-FSDP twin of ``ms`` — logical checkpoints always use the
    regular (param-shaped) layout so they are mesh- AND mode-agnostic."""
    if not ms.fsdp:
        return ms
    return T.build_structure(ms.cfg, plan=ms.plan, tp=ms.tp)


def _seg_to_regular(flat_seg, seg, meta, ms: T.ModelStructure):
    """FSDP flat segment -> regular stacked segment tree (count, ...)."""
    from repro.parallel import fsdp as F
    groups = F.unpack_segment(flat_seg, meta, data=ms.fsdp_data, tp=ms.tp)
    if seg.count == 1:
        return groups[0]
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *groups)


def _seg_from_regular(reg_seg, seg, meta, ms: T.ModelStructure, dtype):
    from repro.parallel import fsdp as F
    groups = ([jax.tree.map(lambda v: v[i], reg_seg) for i in range(seg.count)]
              if seg.count > 1 else [reg_seg])
    return F.pack_segment(groups, meta, data=ms.fsdp_data, tp=ms.tp,
                          dtype=dtype)


def state_to_logical(state: Dict[str, Any], ms: T.ModelStructure,
                     pc: ParallelContext) -> Dict[str, Any]:
    """ZeRO train state -> {"master","m","v": param-shaped fp32, "step"}."""
    reg = _regular_structure(ms)
    tmpl = T.model_template(reg)
    leaves, treedef = jax.tree.flatten(tmpl, is_leaf=is_pd)
    pspecs = [pd.pspec for pd in leaves]
    shapes = [pd.shape for pd in leaves]
    metas = T.segment_metas(ms) if ms.fsdp else None

    def unpack(flat_tree):
        if ms.fsdp:
            flat_tree = dict(flat_tree)
            flat_tree["segments"] = [
                _seg_to_regular(fs, seg, meta, ms)
                for fs, seg, meta in zip(flat_tree["segments"], ms.segments,
                                         metas)]
        flats = treedef.flatten_up_to(flat_tree)
        out = []
        for f, s_, ps in zip(flats, shapes, pspecs):
            if f.shape == s_:  # already param-shaped (FSDP-unpacked)
                out.append(jnp.asarray(f, jnp.float32))
            else:
                out.append(from_flat_global(f, s_, ps, pc))
        return treedef.unflatten(out)

    return {
        "master": unpack(state["master"]),
        "m": unpack(state["m"]),
        "v": unpack(state["v"]),
        "step": state["step"],
    }


def logical_to_state(logical: Dict[str, Any], ms: T.ModelStructure,
                     pc: ParallelContext, tc: TrainConfig) -> Dict[str, Any]:
    """Inverse: re-flatten under the (possibly different) current mesh /
    FSDP mode."""
    reg = _regular_structure(ms)
    tmpl = T.model_template(reg)
    leaves, treedef = jax.tree.flatten(tmpl, is_leaf=is_pd)
    pspecs = [pd.pspec for pd in leaves]
    metas = T.segment_metas(ms) if ms.fsdp else None

    def pack(tree, dtype=jnp.float32):
        tree = dict(tree) if ms.fsdp else tree
        seg_override = None
        if ms.fsdp:
            seg_override = [
                _seg_from_regular(rs, seg, meta, ms, dtype)
                for rs, seg, meta in zip(tree["segments"], ms.segments, metas)]
        flats = treedef.flatten_up_to(tree)
        keyed = treedef.unflatten(
            [to_flat_global(x, ps, pc) for x, ps in zip(flats, pspecs)])
        if seg_override is not None:
            keyed["segments"] = seg_override
        return keyed

    master = pack(logical["master"])
    if ms.fsdp:
        params = dict(jax.tree.map(lambda x: x.astype(tc.param_dtype),
                                   logical["master"]))
        params["segments"] = [
            s.astype(tc.param_dtype) if hasattr(s, "astype") else
            jax.tree.map(lambda x: x.astype(tc.param_dtype), s)
            for s in master["segments"]]
    else:
        params = jax.tree.map(lambda x: x.astype(tc.param_dtype),
                              logical["master"])
    state = {
        "params": params,
        "master": master,
        "m": pack(logical["m"]),
        "v": pack(logical["v"]),
        "step": jnp.asarray(logical["step"], jnp.int32),
    }
    if tc.compress_pod:
        from repro.train.trainer import _err_init
        state["err"] = _err_init(ms, pc, tc)  # EF restarts at zero (lossless)
    return state


# ---------------------------------------------------------------------------
# Disk format
# ---------------------------------------------------------------------------

def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, logical: Dict[str, Any], step: int) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": int(step), "leaves": {}}
    for i, (key, leaf) in enumerate(_flatten_with_paths(logical)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"arr_{i:05d}.npy"
        path = os.path.join(tmp, fn)
        np.save(path, arr)
        with open(path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": digest,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like: Dict[str, Any], *,
            step: Optional[int] = None, verify: bool = True) -> Dict[str, Any]:
    """Load a logical checkpoint into the structure of ``like``."""
    step = latest_step(ckpt_dir) if step is None else step
    assert step is not None, f"no checkpoint in {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    keyed = _flatten_with_paths(like)
    treedef = jax.tree.structure(like)
    leaves = []
    for key, ref in keyed:
        meta = manifest["leaves"][key]
        path = os.path.join(d, meta["file"])
        if verify:
            with open(path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            assert digest == meta["sha256"], f"corrupt leaf {key} in {d}"
        arr = np.load(path)
        leaves.append(jnp.asarray(arr))
    return treedef.unflatten(leaves)


# ---------------------------------------------------------------------------
# Async writer
# ---------------------------------------------------------------------------

class AsyncCheckpointer:
    """Double-buffered background checkpoint writer with a bounded queue of
    one: a new save waits for the previous one to commit (backpressure
    instead of unbounded memory growth)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, logical: Dict[str, Any], step: int) -> None:
        self.wait()
        # device_get on the caller thread (arrays may be donated next step).
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), logical)

        def work():
            try:
                save(self.ckpt_dir, host, step)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
