"""AdamW + LR schedules (WSD per MiniCPM, cosine, constant).

The update operates on flat fp32 ZeRO shards (repro.parallel.zero); the
trainer owns flattening/gathering. Decoupled weight decay per Loshchilov &
Hutter — the paper's fine-tuning recipe (Table 2) uses AdamW with a linear
decay from 1e-4.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "wsd"  # wsd | cosine | linear | const
    warmup_steps: int = 100
    total_steps: int = 1000
    decay_frac: float = 0.1  # WSD: final fraction of steps spent decaying
    min_lr_frac: float = 0.0


def schedule_lr(opt: OptConfig, step) -> jax.Array:
    """LR at ``step`` (0-based, fp32). All branches are traceable."""
    s = jnp.asarray(step, jnp.float32)
    # (s+1)/warmup so step 0 trains at lr/warmup, not 0; warmup=0 disables.
    warm = jnp.minimum((s + 1.0) / max(opt.warmup_steps, 1), 1.0)
    total = float(opt.total_steps)
    lo = opt.min_lr_frac
    if opt.schedule == "const":
        frac = jnp.float32(1.0)
    elif opt.schedule == "linear":
        frac = jnp.maximum(lo, 1.0 - s / total)
    elif opt.schedule == "cosine":
        prog = jnp.clip(s / total, 0.0, 1.0)
        frac = lo + (1 - lo) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    elif opt.schedule == "wsd":
        # Warmup -> Stable -> Decay (MiniCPM): stable at lr, then linear
        # decay over the last decay_frac of training.
        decay_start = total * (1.0 - opt.decay_frac)
        prog = jnp.clip((s - decay_start) / jnp.maximum(total - decay_start, 1), 0, 1)
        frac = 1.0 - (1.0 - lo) * prog
    else:
        raise ValueError(opt.schedule)
    return opt.lr * warm * frac


def adamw_update(g, m, v, p, step, opt: OptConfig, *, lr, wd_mask=1.0):
    """One AdamW step on flat fp32 tensors. Returns (new_p, new_m, new_v)."""
    g = g.astype(jnp.float32)
    m = opt.beta1 * m + (1 - opt.beta1) * g
    v = opt.beta2 * v + (1 - opt.beta2) * jnp.square(g)
    t = jnp.asarray(step, jnp.float32) + 1.0
    mhat = m / (1 - opt.beta1 ** t)
    vhat = v / (1 - opt.beta2 ** t)
    upd = mhat / (jnp.sqrt(vhat) + opt.eps) + opt.weight_decay * wd_mask * p
    return p - lr * upd, m, v


def clip_by_global_norm(grads: PyTree, max_norm: float, *, pre_sum=None):
    """Clip a grad tree by global L2 norm. ``pre_sum``: already-reduced
    sum-of-squares (for cross-rank clipping, pass psum(local_sq))."""
    if pre_sum is None:
        pre_sum = global_sq_norm(grads)
    norm = jnp.sqrt(pre_sum)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def global_sq_norm(grads: PyTree) -> jax.Array:
    return sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
               for g in jax.tree.leaves(grads))
