"""Explicit-collective parallel context.

All model code takes a ``ParallelContext`` and calls its collective helpers;
when an axis is ``None`` (single-device tests / reference paths) every helper
degrades to the identity, so the exact same model code runs inside
``shard_map`` on a 512-way mesh and in a plain CPU unit test.

Axis roles:
  tp_axis  ("model") — Megatron tensor parallelism; LP halves syncs on it.
  dp_axes  (("pod","data")) — pure data parallelism; grads synced across them.
  pipe_axis ("pipe") — optional GPipe pipeline stage axis.

Sequence parallelism (``sp=True``) replaces each TP all-reduce with a
reduce-scatter along the sequence dimension at phase exit and an all-gather at
phase entry (same wire bytes as one all-reduce, but the residual stream and
the norms between phases run on 1/tp of the tokens).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class ParallelContext:
    tp_axis: Optional[str] = None
    tp_size: int = 1
    dp_axes: Tuple[str, ...] = ()
    dp_size: int = 1
    pod_size: int = 1           # leading "pod" factor of the dp axes (DCI)
    sp: bool = False            # sequence-parallel residual stream
    seq_axis: int = 1           # which array dim is "sequence" in activations

    # ------------------------------------------------------------------
    @property
    def tp(self) -> int:
        return self.tp_size

    def with_sp(self, sp: bool) -> "ParallelContext":
        return replace(self, sp=sp)

    def tp_index(self):
        if self.tp_axis is None:
            return jnp.int32(0)
        return lax.axis_index(self.tp_axis)

    # -- raw collectives over the TP axis ------------------------------
    def psum_tp(self, x):
        if self.tp_axis is None or self.tp_size == 1:
            return x
        return lax.psum(x, self.tp_axis)

    def pmax_tp(self, x):
        if self.tp_axis is None or self.tp_size == 1:
            return x
        return lax.pmax(x, self.tp_axis)

    def all_gather_tp(self, x, axis: int, *, tiled: bool = True):
        if self.tp_axis is None or self.tp_size == 1:
            return x
        return lax.all_gather(x, self.tp_axis, axis=axis, tiled=tiled)

    def psum_scatter_tp(self, x, axis: int):
        if self.tp_axis is None or self.tp_size == 1:
            return x
        return lax.psum_scatter(x, self.tp_axis, scatter_dimension=axis, tiled=True)

    def all_to_all_tp(self, x, split_axis: int, concat_axis: int):
        if self.tp_axis is None or self.tp_size == 1:
            return x
        return lax.all_to_all(
            x, self.tp_axis, split_axis=split_axis, concat_axis=concat_axis, tiled=True
        )

    # -- phase boundaries (the paper's sync points) ---------------------
    # A "phase" is one column-parallel -> local -> row-parallel TP region.
    # Standard transformer layer: 2 phases (attention, FFN) = 2 syncs.
    # LP pair: still 2 phases for TWO layers = the paper's halving.
    def phase_in(self, x, axis: Optional[int] = None):
        """Enter a TP phase: make the activation full-sequence."""
        if self.sp:
            return self.all_gather_tp(x, axis=self.seq_axis if axis is None else axis)
        return x

    def phase_out(self, x, axis: Optional[int] = None):
        """Exit a TP phase: combine row-parallel partial sums."""
        if self.sp:
            return self.psum_scatter_tp(x, axis=self.seq_axis if axis is None else axis)
        return self.psum_tp(x)

    def shard_seq(self, x):
        """Slice a replicated activation down to this rank's seq shard (used
        when entering an SP region, e.g. right after the embedding psum)."""
        if not self.sp or self.tp_axis is None or self.tp_size == 1:
            return x
        seq = x.shape[self.seq_axis]
        assert seq % self.tp_size == 0, (seq, self.tp_size)
        shard = seq // self.tp_size
        idx = lax.axis_index(self.tp_axis)
        return lax.dynamic_slice_in_dim(x, idx * shard, shard, axis=self.seq_axis)

    # -- data-parallel helpers ------------------------------------------
    def psum_dp(self, x):
        if not self.dp_axes or self.dp_size == 1:
            return x
        return lax.psum(x, self.dp_axes)

    def pmean_dp(self, x):
        if not self.dp_axes or self.dp_size == 1:
            return x
        return lax.pmean(x, self.dp_axes)

    def psum_scatter_dp(self, x, axis: int = 0):
        if not self.dp_axes or self.dp_size == 1:
            return x
        return lax.psum_scatter(x, self.dp_axes, scatter_dimension=axis, tiled=True)

    def all_gather_dp(self, x, axis: int = 0, *, tiled: bool = True):
        if not self.dp_axes or self.dp_size == 1:
            return x
        return lax.all_gather(x, self.dp_axes, axis=axis, tiled=tiled)

    def dp_index(self):
        if not self.dp_axes:
            return jnp.int32(0)
        idx = jnp.int32(0)
        for ax in self.dp_axes:
            idx = idx * lax.psum(1, ax) + lax.axis_index(ax)
        return idx


def make_context(mesh: jax.sharding.Mesh | None, *, sp: bool = False) -> ParallelContext:
    """Build a ParallelContext from a production mesh (see launch/mesh.py)."""
    if mesh is None:
        return ParallelContext()
    names = mesh.axis_names
    tp_axis = "model" if "model" in names else None
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    tp_size = mesh.shape["model"] if tp_axis else 1
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    pod_size = mesh.shape["pod"] if "pod" in names else 1
    return ParallelContext(
        tp_axis=tp_axis, tp_size=tp_size, dp_axes=dp_axes, dp_size=dp_size,
        pod_size=pod_size, sp=sp
    )
