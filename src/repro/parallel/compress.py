"""Int8 gradient compression with shared-scale quantization and error
feedback, for the cross-pod (DCI) data-parallel axis where wire bandwidth is
the scarcest resource at 1000+ node scale.

Scheme (per flat gradient chunk):
  1. scale = pmax(max|g|) / 127        -- ONE scalar psum-max, so every rank
                                          quantises on the same grid
  2. q = round(g / scale)  (int8)      -- cast to int32 for the reduction
  3. s = psum(q)                       -- <= 2^31 / 127 ranks, safe to 16M ranks
  4. g_hat = s * scale
  5. e <- g - dequant(q) * dp_size ... error feedback carries the local
     quantisation residual into the next step.

Wire bytes: 1 byte/grad element versus 4 (fp32) or 2 (bf16).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def compress_psum(
    g: jax.Array,
    axis_names,
    err: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """All-reduce ``g`` over ``axis_names`` in int8. Returns (sum, new_err)."""
    g32 = g.astype(jnp.float32)
    if err is not None:
        g32 = g32 + err
    amax = jnp.max(jnp.abs(g32))
    if axis_names:
        amax = lax.pmax(amax, axis_names)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127)
    deq_local = q * scale
    new_err = g32 - deq_local
    qsum = q.astype(jnp.int32)
    if axis_names:
        qsum = lax.psum(qsum, axis_names)
    return qsum.astype(jnp.float32) * scale, new_err


def compress_psum_tree(grads: PyTree, axis_names, errs: Optional[PyTree]) -> Tuple[PyTree, PyTree]:
    if errs is None:
        errs = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errs)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        s, ne = compress_psum(g, axis_names, e)
        out_g.append(s)
        out_e.append(ne)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)
