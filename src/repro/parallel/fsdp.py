"""FSDP / ZeRO-3 sharding of the layer-stack parameters.

For the largest assigned architectures (dbrx-132b: 16.5 GB of bf16 weights
per chip at TP=16) plain tensor parallelism cannot fit a v5e's 16 GB HBM.
FSDP stores each SEGMENT's parameters as flat shards over the intra-pod
``data`` axis and all-gathers ONE GROUP's weights inside the scan body, so
the full tensors are alive only while that group computes:

    peak = all flat shards (params/data) + one group's full tp-local tensors

Backward comes for free: jax AD of the in-scan all_gather emits a
reduce_scatter of the cotangent over the same axis — gradients arrive
already summed over ``data`` and sharded exactly like the parameters, which
is the ZeRO-3 gradient reduction with no extra trainer code.

Layout per segment leaf (GLOBAL view):
    (count, data, tp, chunk)   pspec P(None, "data", "model", None)
with chunk = ceil(prod(tp-local group-leaf shape) / data). The gather runs
over ``data`` only — never across the pod (DCI) axis; cross-pod the shards
are replicated and their gradients psum'd (optionally int8-compressed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.model.params import PD, is_pd
from repro.parallel.context import ParallelContext

PyTree = Any


def _local_shape(shape, pspec, tp: int):
    out = []
    for i, dim in enumerate(shape):
        ax = pspec[i] if i < len(pspec) else None
        names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        f = tp if "model" in names else 1
        assert dim % f == 0, (shape, pspec, tp)
        out.append(dim // f)
    return tuple(out)


def _sharded_dim(pspec) -> Optional[int]:
    for i, ax in enumerate(pspec):
        names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        if "model" in names:
            return i
    return None


@dataclass(frozen=True)
class SegMeta:
    """Static description of one FSDP segment's flat layout (one GROUP's
    template — the count axis is handled outside)."""

    treedef: Any
    global_shapes: Tuple[Tuple[int, ...], ...]
    local_shapes: Tuple[Tuple[int, ...], ...]   # tp-local, without count
    chunks: Tuple[int, ...]
    sharded_dims: Tuple[Optional[int], ...]     # which dim "model" splits
    wd_flags: Tuple[float, ...]
    count: int

    @property
    def tp_flags(self) -> Tuple[bool, ...]:
        return tuple(d is not None for d in self.sharded_dims)


def segment_meta(group_tmpl: PyTree, count: int, *, tp: int, data: int) -> SegMeta:
    leaves, treedef = jax.tree.flatten(group_tmpl, is_leaf=is_pd)
    gshapes, lshapes, chunks, sdims, wdf = [], [], [], [], []
    for pd in leaves:
        loc = _local_shape(pd.shape, pd.pspec, tp)
        n = 1
        for s in loc:
            n *= s
        gshapes.append(tuple(pd.shape))
        lshapes.append(loc)
        chunks.append(-(-n // data))
        sdims.append(_sharded_dim(pd.pspec))
        wdf.append(1.0 if len(pd.shape) >= 2 else 0.0)
    return SegMeta(treedef, tuple(gshapes), tuple(lshapes), tuple(chunks),
                   tuple(sdims), tuple(wdf), count)


def flat_segment_pds(meta: SegMeta, *, data: int, tp: int) -> PyTree:
    """PD tree describing the flat FSDP storage of one segment."""
    pds = [PD((meta.count, data, tp, c), P(None, "data", "model", None),
              init="zeros")
           for c in meta.chunks]
    return meta.treedef.unflatten(pds)


# ---------------------------------------------------------------------------
# Pack / unpack (GLOBAL arrays; init and mesh-agnostic checkpoints)
# ---------------------------------------------------------------------------

def _to_tp_rows(x, loc, sdim, tp):
    """GLOBAL tensor -> (tp, local_size) rows."""
    if sdim is None:
        return jnp.broadcast_to(x.reshape(1, -1), (tp, x.size))
    s = x.shape[sdim]
    xt = x.reshape(*x.shape[:sdim], tp, s // tp, *x.shape[sdim + 1:])
    return jnp.moveaxis(xt, sdim, 0).reshape(tp, -1)


def _from_tp_rows(rows, gshape, loc, sdim, tp):
    """(tp, local_size) rows -> GLOBAL tensor."""
    if sdim is None:
        return rows[0].reshape(loc)
    parts = rows.reshape(tp, *loc)
    out = jnp.moveaxis(parts, 0, sdim)
    return out.reshape(gshape)


def pack_segment(group_params: Sequence[PyTree], meta: SegMeta, *,
                 data: int, tp: int, dtype=jnp.float32) -> PyTree:
    """[count group trees of GLOBAL tensors] -> flat (count, data, tp, chunk)."""
    per_group = []
    for gp in group_params:
        leaves = meta.treedef.flatten_up_to(gp)
        flat = []
        for x, loc, chunk, sdim in zip(leaves, meta.local_shapes,
                                       meta.chunks, meta.sharded_dims):
            rows = _to_tp_rows(jnp.asarray(x), loc, sdim, tp)
            pad = data * chunk - rows.shape[1]
            if pad:
                rows = jnp.pad(rows, ((0, 0), (0, pad)))
            flat.append(rows.reshape(tp, data, chunk)
                        .transpose(1, 0, 2).astype(dtype))
        per_group.append(meta.treedef.unflatten(flat))
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *per_group)


def unpack_segment(flat: PyTree, meta: SegMeta, *, data: int, tp: int,
                   dtype=jnp.float32) -> List[PyTree]:
    """Inverse of ``pack_segment`` -> list of ``count`` GLOBAL group trees."""
    leaves = meta.treedef.flatten_up_to(flat)
    out = []
    for c in range(meta.count):
        gl = []
        for x, gshape, loc, sdim in zip(leaves, meta.global_shapes,
                                        meta.local_shapes, meta.sharded_dims):
            rows = x[c].transpose(1, 0, 2).reshape(tp, -1)
            n = 1
            for s in loc:
                n *= s
            gl.append(_from_tp_rows(rows[:, :n], gshape, loc, sdim, tp)
                      .astype(dtype))
        out.append(meta.treedef.unflatten(gl))
    return out


# ---------------------------------------------------------------------------
# The in-scan gather
# ---------------------------------------------------------------------------

def make_gather_fn(meta: SegMeta, pc: ParallelContext,
                   dtype=None) -> Callable[[PyTree], PyTree]:
    """Gather one group's flat shards (chunk,) -> full tp-local tensors.

    Input tree leaves: the scan-sliced, squeezed local shard (chunk,).
    AD of this all_gather is the ZeRO-3 reduce_scatter of the grads.
    """
    data_axis = "data" if "data" in pc.dp_axes else None

    def gather(flat_tree: PyTree) -> PyTree:
        leaves = meta.treedef.flatten_up_to(flat_tree)
        out = []
        for x, loc in zip(leaves, meta.local_shapes):
            full = (lax.all_gather(x, data_axis, axis=0, tiled=True)
                    if data_axis is not None else x)
            n = 1
            for s in loc:
                n *= s
            y = full[:n].reshape(loc)
            out.append(y if dtype is None else y.astype(dtype))
        return meta.treedef.unflatten(out)

    return gather


# ---------------------------------------------------------------------------
# int8 weight quantisation for FSDP serving (beyond-paper optimisation: the
# per-token weight gathers of FSDP decode halve their wire bytes, and the
# resident shards halve their HBM. Block-128 symmetric scales.)
# ---------------------------------------------------------------------------

QBLOCK = 128


def quantize_segment(flat: PyTree, *, block: int = QBLOCK):
    """bf16/fp32 flat segment -> {"q": int8 tree, "scale": fp32 tree}."""

    def blocks(x):
        c = x.shape[-1]
        pad = (-c) % block
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        xb = xp.reshape(*x.shape[:-1], -1, block).astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
                            / 127.0, 1e-12)
        return xb, scale, c

    def q_of(x):
        xb, scale, c = blocks(x)
        q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
        return q.reshape(*x.shape[:-1], -1)[..., :c]

    def s_of(x):
        _, scale, _ = blocks(x)
        return scale[..., 0]

    return {"q": jax.tree.map(q_of, flat),
            "scale": jax.tree.map(s_of, flat)}


def make_gather_fn_q(meta: SegMeta, pc: ParallelContext, dtype=jnp.bfloat16,
                     *, block: int = QBLOCK) -> Callable[[PyTree], PyTree]:
    """Gather int8 shards + scales -> dequantised tp-local tensors."""
    data_axis = "data" if "data" in pc.dp_axes else None

    def gather(tree: PyTree) -> PyTree:
        q_leaves = meta.treedef.flatten_up_to(tree["q"])
        s_leaves = meta.treedef.flatten_up_to(tree["scale"])
        out = []
        for q, sc, loc in zip(q_leaves, s_leaves, meta.local_shapes):
            if data_axis is not None:
                q = lax.all_gather(q, data_axis, axis=0, tiled=True)
                sc = lax.all_gather(sc, data_axis, axis=0, tiled=True)
            n = 1
            for s_ in loc:
                n *= s_
            pad = (-q.shape[0]) % block
            qb = jnp.pad(q, (0, pad)).reshape(-1, block).astype(jnp.float32)
            deq = (qb * sc[:qb.shape[0], None]).reshape(-1)[:n]
            out.append(deq.reshape(loc).astype(dtype))
        return meta.treedef.unflatten(out)

    return gather
