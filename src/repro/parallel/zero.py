"""ZeRO-style flat sharding of optimizer state (and optionally gradient
accumulators) over the data-parallel axes.

Every parameter tensor is flattened to 1D, padded to a multiple of the DP
world size, and viewed as ``(dp_size, chunk)``; rank ``i`` owns row ``i``.
Gradients are combined with a single ``psum_scatter`` (sum + shard in one
collective = half the wire bytes of all-reduce-then-slice), updates run on
the owned shard only, and fresh bf16 forward params are rebuilt with one
``all_gather``.

Scan-stacked layers mean each arch has O(10) large tensors, so the flat view
costs a handful of reshapes, not thousands.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.parallel.context import ParallelContext

PyTree = Any


def _pad_len(n: int, dp: int) -> int:
    return (-n) % dp


def flatten_leaf(x: jax.Array, dp: int) -> jax.Array:
    """Full tensor -> (dp, chunk) view (host-side shapes only, no comms)."""
    flat = x.reshape(-1)
    pad = _pad_len(flat.size, dp)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(dp, -1)


def unflatten_leaf(flat2d: jax.Array, shape, dtype) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    return flat2d.reshape(-1)[:n].reshape(shape).astype(dtype)


def shard_tree(tree: PyTree, pc: ParallelContext) -> PyTree:
    """Keep only this DP rank's flat shard of every leaf (no comms; used at
    init where every rank starts from identical replicated values)."""
    dp = pc.dp_size
    if dp == 1:
        return jax.tree.map(lambda x: flatten_leaf(x, 1)[0], tree)
    idx = pc.dp_index()

    def pick(x):
        return lax.dynamic_index_in_dim(flatten_leaf(x, dp), idx, axis=0, keepdims=False)

    return jax.tree.map(pick, tree)


def scatter_grads(grads: PyTree, pc: ParallelContext) -> PyTree:
    """Sum gradients across DP and return each rank's flat shard (ZeRO-2)."""
    dp = pc.dp_size

    def scat(g):
        flat2d = flatten_leaf(g.astype(jnp.float32), dp)
        if dp == 1:
            return flat2d[0]
        return pc.psum_scatter_dp(flat2d, axis=0)

    return jax.tree.map(scat, grads)


def gather_params(shards: PyTree, like: PyTree, pc: ParallelContext, dtype=jnp.bfloat16) -> PyTree:
    """Rebuild full (per-TP-shard) parameter tensors from flat DP shards."""

    def gat(shard, ref):
        full = pc.all_gather_dp(shard[None, :] if pc.dp_size > 1 else shard[None, :], axis=0)
        return unflatten_leaf(full, ref.shape, dtype)

    return jax.tree.map(gat, shards, like)
