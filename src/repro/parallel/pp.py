"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis.

Each pipeline rank holds the parameters of its contiguous stage of layers
(leading axis of the stacked layer params is sharded over ``pipe``).  The
schedule runs ``n_micro + n_stages - 1`` ticks; at every tick each stage
processes one microbatch-slot and the activations rotate one hop with a
single ``ppermute`` (neighbour-only ICI traffic — exactly what a 1000-node
TPU torus wants).

This is a feature module for the large-scale story: validated by
``tests/test_pipeline.py`` on an 8-device CPU sub-mesh; the default 40-cell
dry-run matrix uses DP x TP only.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def pipeline_apply(
    stage_fn: Callable[[PyTree, jax.Array], jax.Array],
    stage_params: PyTree,
    x_micro: jax.Array,  # (n_micro, micro_batch, ...) microbatched input
    *,
    axis: str,
    n_stages: int,
) -> jax.Array:
    """Run ``x_micro`` through ``n_stages`` pipeline stages.

    ``stage_fn(params, x) -> y`` is this rank's stage (already closed over
    the ParallelContext for any inner TP). Returns the final-stage outputs
    gathered back in microbatch order, shape == x_micro.shape.
    """
    n_micro = x_micro.shape[0]
    stage = lax.axis_index(axis)
    n_ticks = n_micro + n_stages - 1
    zero = jnp.zeros_like(x_micro[0])

    def tick(carry, t):
        buf, outs = carry  # buf: activation entering this stage at tick t
        # Stage 0 injects microbatch t (when in range); others use the buffer.
        inject = lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
        )
        x_in = jnp.where(stage == 0, inject, buf)
        y = stage_fn(stage_params, x_in)
        # Last stage records its result at slot t - (n_stages - 1).
        out_slot = t - (n_stages - 1)
        valid = jnp.logical_and(stage == n_stages - 1, out_slot >= 0)
        outs = lax.cond(
            valid,
            lambda o: lax.dynamic_update_index_in_dim(
                o, y.astype(o.dtype), jnp.maximum(out_slot, 0), axis=0
            ),
            lambda o: o,
            outs,
        )
        # Rotate activations one hop downstream.
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        buf = lax.ppermute(y, axis, perm)
        return (buf, outs), None

    outs0 = jnp.zeros_like(x_micro)
    (_, outs), _ = lax.scan(tick, (zero, outs0), jnp.arange(n_ticks))
    # Only the last stage holds real outputs; broadcast them to all stages so
    # the caller sees replicated results (one extra hop of traffic, but it
    # keeps the API mesh-agnostic).
    outs = lax.psum(jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
    return outs


def stage_slice(n_layers: int, n_stages: int, stage: int) -> tuple[int, int]:
    """Contiguous layer range [lo, hi) owned by ``stage`` (balanced split)."""
    base, rem = divmod(n_layers, n_stages)
    lo = stage * base + min(stage, rem)
    hi = lo + base + (1 if stage < rem else 0)
    return lo, hi
