from repro.parallel.context import ParallelContext, make_context  # noqa: F401
from repro.parallel import zero, compress, pp  # noqa: F401
