"""Deterministic, restart-safe, DP-sharded data pipeline.

The contract the fault-tolerance story needs: ``batch_at(step)`` is a pure
function of (seed, step), so a job restarted from checkpoint step N resumes
with EXACTLY the batch it would have seen — no iterator state to persist,
no skew between ranks (every rank derives its own shard of the global batch
from the same key).

Two sources:
  * SyntheticSource — repro.data.synthetic mixture (default; no files needed)
  * TokenFileSource — memmapped flat token file (uint16/uint32), sliced into
    seq_len windows with a per-epoch deterministic permutation
Both produce GLOBAL batches; under shard_map the dp in_spec slices rows.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import SynthConfig, lm_batch


@dataclass(frozen=True)
class DataConfig:
    seq_len: int = 256
    global_batch: int = 32
    seed: int = 0
    source: str = "synthetic"      # synthetic | file
    path: Optional[str] = None     # token file for source="file"


class SyntheticSource:
    def __init__(self, dc: DataConfig, sc: SynthConfig):
        self.dc, self.sc = dc, sc

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.dc.seed), step)
        return lm_batch(key, self.sc, self.dc.seq_len, self.dc.global_batch)


class TokenFileSource:
    """Flat binary token file -> deterministic shuffled windows."""

    def __init__(self, dc: DataConfig, dtype=np.uint16):
        assert dc.path and os.path.exists(dc.path), dc.path
        self.dc = dc
        self.data = np.memmap(dc.path, dtype=dtype, mode="r")
        self.n_windows = (len(self.data) - 1) // dc.seq_len
        assert self.n_windows >= dc.global_batch, "file too small"

    def _perm(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng(self.dc.seed + 7919 * epoch)
        return rng.permutation(self.n_windows)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        bpe = self.n_windows // self.dc.global_batch  # batches per epoch
        epoch, off = divmod(step, bpe)
        perm = self._perm(epoch)
        idx = perm[off * self.dc.global_batch:(off + 1) * self.dc.global_batch]
        rows = np.stack([self.data[i * self.dc.seq_len:
                                   i * self.dc.seq_len + self.dc.seq_len + 1]
                         for i in idx]).astype(np.int32)
        return {"tokens": jnp.asarray(rows[:, :-1]),
                "labels": jnp.asarray(rows[:, 1:])}


def make_source(dc: DataConfig, sc: Optional[SynthConfig] = None):
    if dc.source == "synthetic":
        return SyntheticSource(dc, sc or SynthConfig())
    if dc.source == "file":
        return TokenFileSource(dc)
    raise ValueError(dc.source)
