from repro.data.pipeline import DataConfig, SyntheticSource, TokenFileSource, make_source  # noqa: F401
from repro.data.synthetic import SynthConfig, eval_ppl_batch, icl_eval_batch, lm_batch  # noqa: F401
