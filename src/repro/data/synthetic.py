"""Structured synthetic corpus + ICL tasks.

No pretrained weights or external datasets exist offline, so the paper's
perplexity (RedPajama) and ICL (MMLU/ARC/...) measurements are reproduced
QUALITATIVELY on models trained in-container on this corpus. It is designed
so that (a) a ~100M model trains to far-below-uniform perplexity, and (b)
there are measurable in-context tasks whose accuracy degrades gracefully
under effective-depth interventions — the two properties the paper's
experiments need.

Mixture (per sequence, deterministic in the PRNG key):
  * trigram language — a fixed random trigram chain with Zipfian marginals
    (general "language competence"; perplexity metric)
  * copy spans — [COPY] pattern [SEP] pattern (induction circuitry)
  * k-shot ICL classification — k (x -> y) demonstrations of a per-sequence
    random class map followed by a query; answer-token accuracy is the
    Table-1 proxy metric
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax


@dataclass(frozen=True)
class SynthConfig:
    vocab_size: int = 512
    # reserved control tokens at the top of the vocab
    n_special: int = 8
    # trigram LM
    branch: int = 4          # candidate continuations per (a, b) context
    zipf: float = 1.2
    # ICL classification
    n_classes: int = 8
    n_features: int = 32

    @property
    def copy_tok(self) -> int:
        return self.vocab_size - 1

    @property
    def sep_tok(self) -> int:
        return self.vocab_size - 2

    @property
    def icl_tok(self) -> int:
        return self.vocab_size - 3

    @property
    def arrow_tok(self) -> int:
        return self.vocab_size - 4

    @property
    def base_vocab(self) -> int:
        return self.vocab_size - self.n_special


def _zipf_probs(n: int, alpha: float):
    r = jnp.arange(1, n + 1, dtype=jnp.float32)
    p = r ** (-alpha)
    return p / p.sum()


def _trigram_tables(key, sc: SynthConfig):
    """Deterministic trigram structure: for every (a%m, b%m) context, a set
    of ``branch`` allowed continuations with Zipfian weights. m keeps the
    table small (structure, not memorisation)."""
    m = min(sc.base_vocab, 64)
    k1, k2 = jax.random.split(key)
    nexts = jax.random.randint(k1, (m, m, sc.branch), 0, sc.base_vocab)
    w = _zipf_probs(sc.branch, sc.zipf)
    return m, nexts, w


def trigram_sequence(key, sc: SynthConfig, seq_len: int):
    """One trigram-language sequence [seq_len] (int32)."""
    m, nexts, w = _trigram_tables(jax.random.PRNGKey(17), sc)  # fixed language
    k0, k1 = jax.random.split(key)
    init = jax.random.randint(k0, (2,), 0, sc.base_vocab)

    def step(carry, k):
        a, b = carry
        cand = nexts[a % m, b % m]
        c = cand[jax.random.choice(k, sc.branch, p=w)]
        return (b, c), c

    keys = jax.random.split(k1, seq_len)
    (_, _), toks = lax.scan(step, (init[0], init[1]), keys)
    return toks.astype(jnp.int32)


def copy_sequence(key, sc: SynthConfig, seq_len: int):
    """[COPY] p_1..p_L [SEP] p_1..p_L ... tiled to seq_len."""
    L = (seq_len - 2) // 2
    pat = jax.random.randint(key, (L,), 0, sc.base_vocab)
    s = jnp.concatenate([jnp.array([sc.copy_tok]), pat,
                         jnp.array([sc.sep_tok]), pat])
    return jnp.pad(s, (0, seq_len - s.shape[0]),
                   constant_values=sc.sep_tok)[:seq_len].astype(jnp.int32)


def icl_sequence(key, sc: SynthConfig, seq_len: int, *, return_meta=False):
    """[ICL] x1 -> y1 . x2 -> y2 . ... xq -> yq, with a per-sequence random
    map features -> classes. Answer positions are where y tokens sit."""
    k_map, k_x = jax.random.split(key)
    fmap = jax.random.randint(k_map, (sc.n_features,), 0, sc.n_classes)
    n_pairs = (seq_len - 1) // 3
    xs = jax.random.randint(k_x, (n_pairs,), 0, sc.n_features)
    ys = fmap[xs]
    x_toks = xs.astype(jnp.int32)                      # features: low ids
    y_toks = (sc.base_vocab - sc.n_classes + ys).astype(jnp.int32)
    arrow = jnp.full((n_pairs,), sc.arrow_tok, jnp.int32)
    trip = jnp.stack([x_toks, arrow, y_toks], axis=1).reshape(-1)
    s = jnp.concatenate([jnp.array([sc.icl_tok], jnp.int32), trip])
    s = jnp.pad(s, (0, max(0, seq_len - s.shape[0])),
                constant_values=sc.sep_tok)[:seq_len]
    if not return_meta:
        return s
    # positions of the answer tokens (predict y given "x ->")
    ans_pos = 1 + 3 * jnp.arange(n_pairs) + 2
    return s, ans_pos, y_toks


@partial(jax.jit, static_argnames=("sc", "seq_len", "batch"))
def lm_batch(key, sc: SynthConfig, seq_len: int, batch: int) -> Dict[str, jax.Array]:
    """Mixture batch {"tokens","labels"} for LM training (labels shifted)."""
    keys = jax.random.split(key, batch)

    def one(k):
        kk, ks = jax.random.split(k)
        kind = jax.random.randint(ks, (), 0, 4)  # 0,1: trigram 2: copy 3: icl
        return lax.switch(
            jnp.clip(kind - 1, 0, 2),
            [lambda: trigram_sequence(kk, sc, seq_len + 1),
             lambda: copy_sequence(kk, sc, seq_len + 1),
             lambda: icl_sequence(kk, sc, seq_len + 1)],
        )

    toks = jax.vmap(one)(keys)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@partial(jax.jit, static_argnames=("sc", "seq_len", "batch"))
def eval_ppl_batch(key, sc: SynthConfig, seq_len: int, batch: int):
    """Pure trigram-language batch — the perplexity eval set (the analogue
    of the paper's RedPajama test split)."""
    keys = jax.random.split(key, batch)
    toks = jax.vmap(lambda k: trigram_sequence(k, sc, seq_len + 1))(keys)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@partial(jax.jit, static_argnames=("sc", "seq_len", "batch"))
def icl_eval_batch(key, sc: SynthConfig, seq_len: int, batch: int):
    """ICL accuracy batch: tokens + answer positions + answer ids."""
    keys = jax.random.split(key, batch)
    toks, pos, ys = jax.vmap(
        lambda k: icl_sequence(k, sc, seq_len, return_meta=True))(keys)
    return {"tokens": toks, "ans_pos": pos, "ans_tok": ys}
