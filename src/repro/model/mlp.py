"""Tensor-parallel MLP (gated SwiGLU/GeGLU or plain 2-matrix GeLU).

LP pairs: the paper concatenates both layers' up-projections along d_ff and
keeps separate low-rank down projections whose partial sums merge in the ONE
reduction. Here that is an einsum with a leading pair axis; the down
projection contracts over the pair axis too, so the psum that follows is the
single sync point for the FFN phase of two layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.model.params import PD

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def mlp_template(cfg, tp: int):
    D, F = cfg.d_model, cfg.d_ff
    assert F % tp == 0, (cfg.name, F, tp)
    t = {"w_up": PD((D, F), P(None, "model")), "w_down": PD((F, D), P("model", None))}
    if cfg.mlp_gated:
        t["w_gate"] = PD((D, F), P(None, "model"))
    if getattr(cfg, "mlp_bias", False):
        t["b_up"] = PD((F,), P("model"), init="zeros")
        t["b_down"] = PD((D,), P(), init="zeros")
    return t


def mlp_forward(p, xn, cfg, tp: int, *, pair: bool):
    """xn: [B,S,D] or [2,B,S,D] (pair, per-path normalised inputs).
    Returns the PARTIAL output [B,S,D]; caller runs phase_out (psum)."""
    act = _ACTS[cfg.mlp_act]
    if pair:
        up = jnp.einsum("pbsd,pdf->pbsf", xn, p["w_up"].astype(xn.dtype))
        if p.get("b_up") is not None:
            up = up + p["b_up"][:, None, None, :].astype(up.dtype)
        if cfg.mlp_gated:
            gate = jnp.einsum("pbsd,pdf->pbsf", xn, p["w_gate"].astype(xn.dtype))
            h = act(gate.astype(jnp.float32)).astype(up.dtype) * up
        else:
            h = act(up.astype(jnp.float32)).astype(up.dtype)
        # Down projection as two per-path gemms + one explicit add. The
        # einsum form ("pbsf,pfd->bsd") contracts (p, f) jointly and XLA's
        # split of that 2F-long reduction depends on the sequence length,
        # which breaks the suffix-prefill bit-identity contract
        # (repro.serve): a suffix row must reduce in exactly the grouping
        # the full-prompt forward used. Pinning the grouping to
        # per-path-then-add keeps each contraction at F (sequence-length-
        # invariant on CPU up to F ~ 512) without adding a sync — the psum
        # after this is still the phase's one reduction.
        wd = p["w_down"].astype(h.dtype)
        y = h[0] @ wd[0] + h[1] @ wd[1]
    else:
        up = xn @ p["w_up"].astype(xn.dtype)
        if p.get("b_up") is not None:
            up = up + p["b_up"].astype(up.dtype)
        if cfg.mlp_gated:
            gate = xn @ p["w_gate"].astype(xn.dtype)
            h = act(gate.astype(jnp.float32)).astype(up.dtype) * up
        else:
            h = act(up.astype(jnp.float32)).astype(up.dtype)
        y = h @ p["w_down"].astype(h.dtype)
    if p.get("b_down") is not None:
        bd = p["b_down"].astype(jnp.float32)
        if pair:
            bd = bd.sum(axis=0)  # both paths' biases enter the one reduction
        y = y + (bd / tp).astype(y.dtype)
    return y
