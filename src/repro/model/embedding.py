"""Vocab-parallel embedding, LM head, cross-entropy and sampling.

The vocabulary (up to 257k for paligemma) is sharded over the ``model``
axis. Lookup produces a TP-partial embedding (combined by the caller's
phase_out). The head computes LOCAL logits [B,S,V/tp] — never materialising
full-vocab logits — and the loss/sampling run vocab-parallel with O(B*S)
collectives (Megatron-style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.model.params import PD
from repro.parallel.context import ParallelContext


def vocab_pad(V: int, tp: int) -> int:
    return -(-V // tp) * tp


def embed_template(cfg, tp: int):
    Vp = vocab_pad(cfg.vocab_size, tp)
    t = {"tok": PD((Vp, cfg.d_model), P("model", None), fan_in=cfg.d_model)}
    if cfg.pos_embed == "learned":
        t["pos"] = PD((cfg.max_position, cfg.d_model), P(), fan_in=cfg.d_model)
    if not cfg.tie_embeddings:
        t["head"] = PD((cfg.d_model, Vp), P(None, "model"))
    return t


def embed_lookup(p, ids, pc: ParallelContext):
    """ids: [B,S] global token ids -> TP-partial [B,S,D]."""
    tok = p["tok"]
    v_local = tok.shape[0]
    base = pc.tp_index() * v_local
    lid = ids - base
    ok = (lid >= 0) & (lid < v_local)
    emb = tok[jnp.clip(lid, 0, v_local - 1)]
    return jnp.where(ok[..., None], emb, 0).astype(jnp.bfloat16 if tok.dtype == jnp.bfloat16 else tok.dtype)


def add_positions(p, x, positions):
    if "pos" not in p:
        return x
    return x + p["pos"][positions].astype(x.dtype)


def local_logits(p, x, cfg, pc: ParallelContext):
    """x: [B,S,D] full -> local logits [B,S,V/tp] (fp32, pad masked)."""
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    v_local = logits.shape[-1]
    base = pc.tp_index() * v_local
    col = base + jnp.arange(v_local)
    logits = jnp.where(col < cfg.vocab_size, logits, -1e30)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def vocab_parallel_xent(logits, labels, pc: ParallelContext, *, mask=None):
    """Mean token cross-entropy from LOCAL logits [B,S,Vl] + global labels."""
    v_local = logits.shape[-1]
    base = pc.tp_index() * v_local
    # Max shift is for numerical stability only — the lse gradient is
    # invariant to it, and pmax has no VJP, so detach it.
    m = pc.pmax_tp(lax.stop_gradient(logits).max(-1))
    lse = m + jnp.log(pc.psum_tp(jnp.exp(logits - m[..., None]).sum(-1)))
    lid = labels - base
    ok = (lid >= 0) & (lid < v_local)
    tgt = jnp.take_along_axis(logits, jnp.clip(lid, 0, v_local - 1)[..., None], axis=-1)[..., 0]
    tgt = pc.psum_tp(jnp.where(ok, tgt, 0.0))
    nll = lse - tgt
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def vocab_parallel_argmax(logits, pc: ParallelContext):
    """Greedy next token from LOCAL logits [B,Vl] (deterministic tie-break:
    smallest global id among the maximisers)."""
    v_local = logits.shape[-1]
    base = pc.tp_index() * v_local
    val = logits.max(-1)
    idx = base + logits.argmax(-1).astype(jnp.int32)
    gmax = pc.pmax_tp(val)
    cand = jnp.where(val >= gmax, idx, jnp.int32(2**30))
    return -pc.pmax_tp(-cand)  # global min over candidates


def vocab_parallel_sample(logits, key, temperature, pc: ParallelContext):
    """Gumbel-max sampling over the sharded vocabulary: each rank draws
    independent gumbels for ITS columns (key folded with tp rank), then the
    global argmax is exact sampling from softmax(logits/T)."""
    rk = jax.random.fold_in(key, pc.tp_index())
    g = jax.random.gumbel(rk, logits.shape, jnp.float32)
    return vocab_parallel_argmax(logits / jnp.maximum(temperature, 1e-6) + g, pc)
