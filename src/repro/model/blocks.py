"""Layer and LP-pair application.

A ``Group`` is the unit the stack scans over: either one layer or an LP pair
of two consecutive layers. The pair path implements the paper's Fig. 2b
computational-graph rewrite:

    a = x + A_k(LN1_k x) + A_{k+1}(LN1_{k+1} x)     # ONE phase_out
    y = a + F_k(LN2_k a) + F_{k+1}(LN2_{k+1} a)     # ONE phase_out

(for mamba/rec mixers the generalised residual-pair form). Pair params are
the two layers' params stacked on a leading axis — the retraining-free merge
of repro.core.lp is exactly that stacking.

Decode fast path
----------------
Decode (seq=1) is where the paper's speedup lives, and it is latency-bound:
per-layer kernel launches and cache reads dominate, not FLOPs. A pair whose
two halves share one mixer kind therefore stores its KV/state caches
STACKED-CONTIGUOUS on a leading pair axis — ``k``/``v``: [2, B, L, Hkv, hd]
(bare names; per-layer fallback entries keep indexed names ``k0``/``k1``) —
and ``apply_group_decode`` runs the whole pair as ONE call into
``attention.decode_attn_standard(pair=True)`` (or the seq-sharded variant):
one stacked QKV projection, one cache write per tensor, one attention
core / Pallas launch (repro.kernels.decode_attention.decode_attention_pair)
and one merged output projection per phase. Heterogeneous pairs (llama4
chunked+global: different ring lengths) keep the per-half loop. Cross
-attention and mamba/rec pairs use the same stacked storage and a single
stacked application.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, LayerSpec
from repro.model import attention as A
from repro.model import mlp as M
from repro.model import moe as MOE
from repro.model import rglru as RG
from repro.model import ssm as SSM
from repro.model.norms import apply_norm, dual_norm
from repro.model.params import PD
from repro.parallel.context import ParallelContext


@dataclass(frozen=True)
class Group:
    pair: bool
    specs: Tuple[LayerSpec, ...]      # 1 or 2 entries
    layer_ids: Tuple[int, ...]

    @property
    def signature(self):
        return (self.pair, self.specs)


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def _norm_tmpl(cfg):
    t = {"scale": PD((cfg.d_model,), P(),
                     init="zeros" if cfg.norm_plus_one else "ones")}
    if cfg.norm_kind == "layernorm":
        t["bias"] = PD((cfg.d_model,), P(), init="zeros")
    return t


def layer_template(cfg: ArchConfig, spec: LayerSpec, tp: int):
    t: Dict[str, Any] = {}
    if spec.mixer.startswith("attn"):
        t["ln1"] = _norm_tmpl(cfg)
        t["attn"] = A.attn_template(cfg, tp)
    elif spec.mixer == "rec":
        t["ln1"] = _norm_tmpl(cfg)
        t["rec"] = RG.rglru_template(cfg, tp)
    elif spec.mixer == "mamba":
        t["ln1"] = _norm_tmpl(cfg)
        t["mamba"] = SSM.ssm_template(cfg, tp)
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        t["lnx"] = _norm_tmpl(cfg)
        t["xattn"] = A.attn_template(cfg, tp, cross=True)
    if spec.ffn == "mlp":
        t["ln2"] = _norm_tmpl(cfg)
        t["mlp"] = M.mlp_template(cfg, tp)
    elif spec.ffn == "moe":
        t["ln2"] = _norm_tmpl(cfg)
        t["moe"] = MOE.moe_template(cfg, tp)
    return t


# ---------------------------------------------------------------------------
# Phase runners
# ---------------------------------------------------------------------------

def _norm_inputs(gp, key, x, cfg, group: Group):
    """Per-path normalised inputs: [B,S,D] (single) or [2,B,S,D] (pair)."""
    if group.pair:
        a, b = dual_norm(x, jax.tree.map(lambda v: v[0], gp[key]),
                         jax.tree.map(lambda v: v[1], gp[key]), cfg)
        return jnp.stack([a, b])
    return apply_norm(x, gp[key], cfg)


def _mixer_kinds(group: Group):
    return tuple(s.mixer for s in group.specs)


def pair_cache_stacked(group: Group) -> bool:
    """True when the group's decode caches use the stacked-contiguous pair
    layout ([2, ...], bare key names) and the fused pair decode path. Pairs
    with heterogeneous mixer kinds (llama4 chunked+global) fall back to
    per-layer entries: their ring lengths/slots differ."""
    return group.pair and len(set(_mixer_kinds(group))) == 1


def attention_phase_full(gp, xn, cfg, dims, pc, *, group: Group, positions,
                         prefix_len=0, cross_kv=None, attn_impl="auto",
                         ctx_kv=None, q0=0):
    """Full-sequence attention (train/prefill). Returns (partial_out, kv_list)
    with one (k, v) in stored layout per layer in the group.

    ``cross_kv`` (whisper decoder): precomputed encoder k/v in FOLDED layout
    [B,T,P*hkv,hd]; q comes from xn, keys are never roped (attn_bidir).

    ``ctx_kv`` (suffix prefill — repro.serve prefix sharing): this group's
    cached CONTEXT keys/values for absolute positions [0, q0), in stored
    layout ({"k"/"v"}: [2,B,Tc,hkv,hd] stacked pair, {"k0"/"v0"}:
    [B,Tc,hkv,hd] single layer; keys already roped when cached). They are
    prepended to the suffix's freshly projected kv so every suffix row
    reduces over exactly ``q0 + S`` keys — the same reduction shape the
    full-prompt forward gives that row, which is what keeps a prefix-hit
    prefill BIT-IDENTICAL to the cold one. ``q0`` is the absolute position
    of the first query row (0 for a full forward).
    """
    kinds = _mixer_kinds(group)
    cross = cross_kv is not None
    p = gp["xattn"] if cross else gp["attn"]
    homogeneous = len(set(kinds)) == 1 or cross
    B = xn.shape[1] if group.pair else xn.shape[0]
    S = xn.shape[2] if group.pair else xn.shape[1]
    nP = 2 if group.pair else 1
    Hk, g = A.core_layout(dims)

    if homogeneous:
        kind = "attn_bidir" if cross else kinds[0]
        q = A.project_q(p, xn, cfg, dims, positions=positions, kind=kind,
                        pair=group.pair)
        if cross:
            k, v = cross_kv
        else:
            k, v = A.project_kv(p, xn, cfg, dims, positions=positions,
                                kind=kind, pair=group.pair)
        ks, vs = _sel_pairwise(k, v, dims, pc, pair=group.pair)
        if ctx_kv is not None:
            cks, cvs = _fold_ctx_kv(ctx_kv, dims, pc, group=group)
            ks = jnp.concatenate([cks.astype(ks.dtype), ks], axis=1)
            vs = jnp.concatenate([cvs.astype(vs.dtype), vs], axis=1)
            if getattr(q0, "ndim", 0) > 0:
                # Per-row ctx lengths (bucketed radix-suffix rows): row i's
                # REAL context is its first q0[i] positions of the
                # Tc-padded ctx block; the rest is garbage-page filler.
                # Rearrange each row's key axis to [real ctx | suffix |
                # junk] so every real key sits at its absolute position —
                # junk lands at kpos >= q0[i] + S, PAST the row's last
                # query (q0[i] + S - 1), where the ordinary causal mask
                # kills it. With the pinned-kv-tile chunked core the junk
                # columns are then bit-transparent exactly like bucket
                # padding (finite masked lanes contribute exact zeros), so
                # a ctx row reduces identically to the cold full-prompt
                # program and a ctx-less row (q0[i] = 0, all-junk tail) is
                # bit-identical to the plain bucket program.
                assert attn_impl.startswith("chunked:"), (
                    "per-row ctx lengths require the pinned-tile chunked "
                    f"attention impl, got {attn_impl!r}")
                Tc, Tt = cks.shape[1], ks.shape[1]
                j = jnp.arange(Tt)[None, :]
                c = q0[:, None]
                idx = jnp.where(j < c, j,
                                jnp.where(j < c + S, Tc + (j - c), j - S))
                ks = jnp.take_along_axis(ks, idx[:, :, None, None], axis=1)
                vs = jnp.take_along_axis(vs, idx[:, :, None, None], axis=1)
            # Materialise the concatenated kv: otherwise XLA splits the
            # value contraction through the concat (p@[v_ctx;v_sfx] ->
            # p1@v_ctx + p2@v_sfx), regrouping the float accumulation and
            # breaking bit-identity with the cold full-prompt forward.
            ks, vs = lax.optimization_barrier((ks, vs))
        qh = q.reshape(B, S, nP * Hk, g, dims.hd)
        o = A.attention_core(qh, ks, vs, kind=kind, window=cfg.window,
                             chunk=cfg.chunk, prefix_len=prefix_len,
                             q0=q0, impl=attn_impl)
        o = o.reshape(B, S, nP * dims.hq, dims.hd)
        out = A.output_proj(p, o, dims, pair=group.pair)
        return out, _split_kv(k, v, dims, pair=group.pair)

    if ctx_kv is not None:
        raise NotImplementedError(
            "suffix prefill supports homogeneous attention groups only")
    # Heterogeneous pair kinds (llama4 chunked+global): per-half cores, still
    # merged output projection + ONE phase_out.
    os, kvs = [], []
    for i, kind in enumerate(kinds):
        ph = jax.tree.map(lambda w: w[i], p)
        qi, ki, vi = A.project_qkv(ph, xn[i], cfg, dims, pc,
                                   positions=positions, kind=kind, pair=False)
        ksi, vsi = _sel_pairwise(ki, vi, dims, pc, pair=False)
        oi = A.attention_core(qi.reshape(B, S, Hk, g, dims.hd), ksi, vsi,
                              kind=kind, window=cfg.window, chunk=cfg.chunk,
                              prefix_len=prefix_len, impl=attn_impl)
        os.append(oi.reshape(B, S, dims.hq, dims.hd))
        kvs.append((ki, vi))
    o = jnp.concatenate(os, axis=2)
    out = A.output_proj(p, o, dims, pair=True)
    return out, kvs


def _sel_pairwise(k, v, dims, pc, *, pair: bool):
    """Rank-local kv selection, preserving the pair-as-doubled-heads layout."""
    if not pair:
        return A.select_local_kv(k, dims, pc), A.select_local_kv(v, dims, pc)
    B, S = k.shape[0], k.shape[1]
    k2 = k.reshape(B, S, 2, dims.hkv, dims.hd)
    v2 = v.reshape(B, S, 2, dims.hkv, dims.hd)
    if not dims.kv_sharded and dims.tp > 1:
        if dims.per_head:
            idx = A.rank_head_kv_map(dims, pc)
            k2 = jnp.take(k2, idx, axis=3)
            v2 = jnp.take(v2, idx, axis=3)
        else:
            base = pc.tp_index() * dims.hq
            kv_idx = jnp.clip(base // dims.group, 0, dims.hkv - 1)
            k2 = lax.dynamic_slice_in_dim(k2, kv_idx, 1, axis=3)
            v2 = lax.dynamic_slice_in_dim(v2, kv_idx, 1, axis=3)
    ks = k2.reshape(B, S, 2 * k2.shape[3], dims.hd)
    vs = v2.reshape(B, S, 2 * v2.shape[3], dims.hd)
    return ks, vs


def _fold_ctx_kv(ctx_kv, dims, pc, *, group: Group):
    """Cached context kv (stored layout) -> the folded [B,Tc,P*Hk,hd] layout
    ``_sel_pairwise`` produces for fresh projections, so a suffix forward can
    concatenate context before suffix keys along the length axis. Keys in the
    cache are already roped; the pair fold is pair-major, matching
    ``_sel_pairwise``'s [B,S,2,hkv,...] reshape.

    Per-rank branch (tp > 1): a kv-SHARDED pool's ``gather_ctx`` hands each
    rank its LOCAL head shard inside shard_map, so the fold is the identity
    on the head axis; a REPLICATED pool (n_kv < tp) hands every rank all
    stored heads, and the rank in-gathers its own head(s) here — the same
    selection the paged decode kernel performs in-kernel via
    ``paged_head_map``. Either way the folded head count must equal
    ``core_layout``'s per-rank count — audited at trace time so a
    mis-sharded ctx tree fails loudly instead of reducing at a different
    shape than the cold full-prompt program (bit-identity is the
    contract)."""
    Hk_eff, _ = A.core_layout(dims)
    if pair_cache_stacked(group):
        ck, cv = ctx_kv["k"], ctx_kv["v"]              # [2,B,Tc,hkv,hd]
        if dims.kv_sharded or dims.tp == 1:
            ks, vs = ck, cv                            # already rank-local
        else:
            ks = A.select_local_kv_pair(ck, dims, pc)  # in-gather this rank
            vs = A.select_local_kv_pair(cv, dims, pc)
        assert ks.shape[3] == Hk_eff, (
            f"ctx kv folds to {ks.shape[3]} heads per pair half but the "
            f"attention core reduces over {Hk_eff}: the gathered ctx tree "
            "does not match this rank's kv layout")
        B, Tc, Hk = ks.shape[1], ks.shape[2], ks.shape[3]
        ks = jnp.moveaxis(ks, 0, 2).reshape(B, Tc, 2 * Hk, dims.hd)
        vs = jnp.moveaxis(vs, 0, 2).reshape(B, Tc, 2 * Hk, dims.hd)
        return ks, vs
    assert not group.pair, "heterogeneous pairs have no stored ctx layout"
    if dims.kv_sharded or dims.tp == 1:
        ks, vs = ctx_kv["k0"], ctx_kv["v0"]            # [B,Tc,hkv,hd]
    else:
        ks = A.select_local_kv(ctx_kv["k0"], dims, pc)
        vs = A.select_local_kv(ctx_kv["v0"], dims, pc)
    assert ks.shape[2] == Hk_eff, (
        f"ctx kv folds to {ks.shape[2]} heads but the attention core "
        f"reduces over {Hk_eff}: the gathered ctx tree does not match "
        "this rank's kv layout")
    return ks, vs


def _split_kv(k, v, dims, *, pair: bool):
    if not pair:
        return [(k, v)]
    B, S = k.shape[0], k.shape[1]
    k2 = k.reshape(B, S, 2, dims.hkv, dims.hd)
    v2 = v.reshape(B, S, 2, dims.hkv, dims.hd)
    return [(k2[:, :, 0], v2[:, :, 0]), (k2[:, :, 1], v2[:, :, 1])]


def ffn_phase(gp, xn, cfg, pc, *, group: Group):
    """Returns (partial_out, aux)."""
    ffn = group.specs[0].ffn
    if ffn == "mlp":
        return M.mlp_forward(gp["mlp"], xn, cfg, pc.tp_size, pair=group.pair), 0.0
    return MOE.moe_forward(gp["moe"], xn, cfg, pc, pair=group.pair)


# ---------------------------------------------------------------------------
# KV-cache construction
# ---------------------------------------------------------------------------

def ring_len(cfg, mixer: str, max_len: int) -> int:
    if mixer == "attn_local" and cfg.window:
        return min(cfg.window, max_len)
    if mixer == "attn_chunked" and cfg.chunk:
        return min(cfg.chunk, max_len)
    return max_len


def seq_sharded_kind(cfg, dims, mixer: str, kv_mode: str) -> bool:
    """Sequence-shard the cache over the model axis? Only worthwhile for
    full-length causal caches with replicated kv heads."""
    return (kv_mode == "seq" and mixer in ("attn", "attn_global")
            and not dims.kv_sharded and dims.tp > 1)


def fill_cache(k, L: int, *, mixer, cfg, seq_shard: bool, pc, dims):
    """Place prefill keys/values [B,S,hkv,hd] into a decode cache."""
    B, S, H, hd = k.shape
    if mixer == "attn_local" and cfg.window and S >= (W := ring_len(cfg, mixer, L)):
        last = k[:, S - W:]
        return jnp.roll(last, (S - W) % W, axis=1)
    if mixer == "attn_chunked" and cfg.chunk:
        C = ring_len(cfg, mixer, L)
        cstart = (S // C) * C if S % C else S  # S%C==0 -> empty fresh chunk
        ring = jnp.zeros((B, C, H, hd), k.dtype)
        n = S - cstart
        if n:
            ring = lax.dynamic_update_slice_in_dim(ring, k[:, cstart:], 0, axis=1)
        return ring
    Ls = ring_len(cfg, mixer, L)
    pad = jnp.zeros((B, Ls, H, hd), k.dtype)
    kp = lax.dynamic_update_slice_in_dim(pad, k[:, :min(S, Ls)], 0, axis=1)
    if seq_shard:
        L_loc = Ls // dims.tp
        return lax.dynamic_slice_in_dim(kp, pc.tp_index() * L_loc, L_loc, axis=1)
    return kp


def group_cache_meta(cfg, group: Group, dims, *, batch: int, max_len: int,
                     kv_mode: str, enc_len: int = 0, dtype=jnp.bfloat16):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for one group's decode
    cache. Batch axis sharding is added by the caller. Shapes are LOCAL in
    the head/seq dims the model axis shards (shard_map local view) — the
    caller converts to global via pspec rules; here we return GLOBAL shapes
    with their pspecs.

    Homogeneous pairs store STACKED-CONTIGUOUS caches: one entry per tensor
    under a bare name ("k", "v", "xk", "xv", "conv", "h") with a leading
    pair axis of 2, so the fused decode path reads/writes one tensor per
    phase. Per-layer entries keep indexed names ("k0", "k1", ...) — the
    trailing digit is what downstream pspec augmentation keys on."""
    spec_tree, pspec_tree = {}, {}
    stacked = pair_cache_stacked(group)

    def put(name, i, shp, ps, dt):
        if stacked:
            if name not in spec_tree:  # identical for both halves: emit once
                spec_tree[name] = jax.ShapeDtypeStruct((2, *shp), dt)
                pspec_tree[name] = P(None, *ps)
        else:
            spec_tree[f"{name}{i}"] = jax.ShapeDtypeStruct(shp, dt)
            pspec_tree[f"{name}{i}"] = P(*ps)

    for i, spec in enumerate(group.specs):
        m = spec.mixer
        if m.startswith("attn"):
            L = ring_len(cfg, m, max_len)
            shp = (batch, L, dims.hkv_global, dims.hd)
            if seq_sharded_kind(cfg, dims, m, kv_mode):
                ps = (None, "model", None, None)
            elif dims.kv_sharded:
                ps = (None, None, "model", None)
            else:
                ps = (None, None, None, None)
            put("k", i, shp, ps, dtype)
            put("v", i, shp, ps, dtype)
            if spec.cross_attn:
                xshp = (batch, enc_len, dims.hkv_global, dims.hd)
                xps = (None, None, "model", None) if dims.kv_sharded \
                    else (None, None, None, None)
                put("xk", i, xshp, xps, dtype)
                put("xv", i, xshp, xps, dtype)
        elif m == "mamba":
            di = cfg.d_inner
            put("conv", i, (batch, cfg.ssm_conv - 1, di),
                (None, None, "model"), dtype)
            put("h", i, (batch, di, cfg.ssm_state),
                (None, "model", None), jnp.float32)
        elif m == "rec":
            W = cfg.lru_width
            put("conv", i, (batch, cfg.rec_conv - 1, W),
                (None, None, "model"), dtype)
            put("h", i, (batch, W, 1),
                (None, "model", None), jnp.float32)
    return spec_tree, pspec_tree


# ---------------------------------------------------------------------------
# Full-sequence group application (train / prefill)
# ---------------------------------------------------------------------------

def apply_group_full(gp, x, *, cfg, group: Group, dims, pc: ParallelContext,
                     positions, prefix_len=0, enc_out=None, attn_impl="auto",
                     emit_cache=False, max_len=0, kv_mode="heads",
                     scan_impl="chunked", ctx_kv=None, q0=0):
    """One group over the full sequence.

    x: [B,S_local,D] (S_local = S/tp under SP). Returns (x, aux, cache_dict).

    ``ctx_kv``/``q0`` (suffix prefill): cached kv for positions [0, q0) in
    stored layout; the sequence being processed starts at absolute position
    ``q0``. Attention-only (recurrent state cannot resume from kv), and the
    emitted cache covers ONLY the suffix (length ``max_len``, local position
    0 == absolute ``q0``) — the caller owns placing it after the context.
    """
    aux = jnp.float32(0.0)
    cache: Dict[str, Any] = {}
    mixer = group.specs[0].mixer
    nP = 2 if group.pair else 1
    gather_axis = 2 if group.pair else 1

    # ---- phase 1: temporal mixing -------------------------------------
    # Gather-first: under SP the residual is re-gathered BEFORE the norms,
    # so an LP pair's two per-path norms read ONE gathered tensor — half
    # the phase-entry wire bytes of gathering the stacked [2,...] inputs
    # (EXPERIMENTS.md §Perf iteration 2).
    xg = pc.phase_in(x)
    xn = _norm_inputs(gp, "ln1", xg, cfg, group)
    if ctx_kv is not None and not mixer.startswith("attn"):
        raise NotImplementedError(
            "suffix prefill requires attention mixers (recurrent state "
            "cannot resume from cached kv)")
    if mixer.startswith("attn"):
        out, kvs = attention_phase_full(gp, xn, cfg, dims, pc, group=group,
                                        positions=positions,
                                        prefix_len=prefix_len,
                                        attn_impl=attn_impl,
                                        ctx_kv=ctx_kv, q0=q0)
        if emit_cache:
            fks, fvs = [], []
            for i, (k, v) in enumerate(kvs):
                m = group.specs[i].mixer
                ss = seq_sharded_kind(cfg, dims, m, kv_mode)
                fk = fill_cache(k, max_len, mixer=m, cfg=cfg,
                                seq_shard=ss, pc=pc, dims=dims)
                fv = fill_cache(v, max_len, mixer=m, cfg=cfg,
                                seq_shard=ss, pc=pc, dims=dims)
                if pair_cache_stacked(group):
                    fks.append(fk)
                    fvs.append(fv)
                else:
                    cache[f"k{i}"], cache[f"v{i}"] = fk, fv
            if fks:  # stacked-contiguous pair layout for the fused decode
                cache["k"] = jnp.stack(fks)
                cache["v"] = jnp.stack(fvs)
    else:
        xn_p = xn if group.pair else xn[None]
        key = "mamba" if mixer == "mamba" else "rec"
        mp = gp[key] if group.pair else jax.tree.map(lambda w: w[None], gp[key])
        if mixer == "mamba":
            out, state = SSM.ssm_mix(mp, xn_p, cfg, pc, impl=scan_impl)
        else:
            out, state = RG.rglru_mix(mp, xn_p, cfg, pc, impl=scan_impl)
        if emit_cache:
            conv, h = state
            if pair_cache_stacked(group):  # already stacked [2, ...]
                cache["conv"], cache["h"] = conv, h
            else:
                for i in range(nP):
                    cache[f"conv{i}"], cache[f"h{i}"] = conv[i], h[i]
    x = x + pc.phase_out(out).astype(x.dtype)

    # ---- cross-attention phase (whisper decoder) ----------------------
    if group.specs[0].cross_attn and enc_out is not None:
        xnx = _norm_inputs(gp, "lnx", pc.phase_in(x), cfg, group)
        enc_in = jnp.stack([enc_out] * 2) if group.pair else enc_out
        xk, xv = A.project_kv(gp["xattn"], enc_in, cfg, dims,
                              positions=None, kind="attn_bidir", pair=group.pair)
        out, _ = attention_phase_full(gp, xnx, cfg, dims, pc, group=group,
                                      positions=positions, cross_kv=(xk, xv),
                                      attn_impl=attn_impl)
        if emit_cache:
            halves = _split_kv(xk, xv, dims, pair=group.pair)
            if pair_cache_stacked(group):
                cache["xk"] = jnp.stack([ki for ki, _ in halves])
                cache["xv"] = jnp.stack([vi for _, vi in halves])
            else:
                for i, (ki, vi) in enumerate(halves):
                    cache[f"xk{i}"], cache[f"xv{i}"] = ki, vi
        x = x + pc.phase_out(out).astype(x.dtype)

    # ---- phase 2: FFN ---------------------------------------------------
    if group.specs[0].ffn is not None:
        xn2 = _norm_inputs(gp, "ln2", pc.phase_in(x), cfg, group)
        out, a = ffn_phase(gp, xn2, cfg, pc, group=group)
        aux = aux + a
        x = x + pc.phase_out(out).astype(x.dtype)
    return x, aux, cache


# ---------------------------------------------------------------------------
# Single-token decode group application
# ---------------------------------------------------------------------------

def apply_group_decode(gp, x, cache, t, *, cfg, group: Group, dims,
                       pc: ParallelContext, kv_mode="heads",
                       cache_layout="ring", block_tables=None):
    """One group for one new token. x: [B,1,D] (replicated over model; no SP
    at decode). Returns (x, new_cache).

    Stacked pairs (pair_cache_stacked) take the FUSED fast path: the whole
    pair is one decode_attn_*(pair=True) call over the stacked [2, ...]
    cache — one QKV projection, one cache read/write, one attention kernel
    launch and one psum per phase, instead of the per-half loop's two of
    each. Heterogeneous pairs and single layers use the per-half loop.

    cache_layout="paged" (continuous batching): ``t`` is a [B] vector of
    per-slot positions, attention k/v entries are page pools indirected
    through ``block_tables`` [B, n_pg], and state entries stay slot-indexed
    with B == n_slots. The fused pair path is preserved — one
    decode_attn_paged(pair=True) call per stacked pair. Under tp > 1 the
    pool shards kv heads over the model axis exactly like the ring cache
    (replicated when n_kv < tp, with in-kernel head selection); a
    seq-sharded page pool has no block-table analogue, so kv_mode="seq"
    is rejected rather than silently ignored.
    """
    new_cache: Dict[str, Any] = {}
    mixer = group.specs[0].mixer
    nP = 2 if group.pair else 1
    paged = cache_layout == "paged"
    if paged and kv_mode != "heads":
        raise NotImplementedError(
            f"paged decode supports kv_mode='heads' only (got {kv_mode!r}): "
            "pages shard kv heads over the model axis, not the sequence")
    fused = pair_cache_stacked(group)
    if fused:  # tolerate caches emitted under the per-layer layout
        fused = ("k" if mixer.startswith("attn") else "conv") in cache
    if paged and group.pair and mixer.startswith("attn") and not fused:
        raise NotImplementedError(
            "paged decode requires the stacked pair cache layout "
            "(heterogeneous attention pairs are not pageable)")

    xn = _norm_inputs(gp, "ln1", x, cfg, group)
    if mixer.startswith("attn"):
        if paged and fused:
            out, nk, nv = A.decode_attn_paged(
                gp["attn"], xn, cache["k"], cache["v"], t, block_tables,
                cfg, dims, pc, kind=mixer, pair=True)
            new_cache["k"], new_cache["v"] = nk, nv
        elif paged:
            o, nk, nv = A.decode_attn_paged(
                gp["attn"], xn, cache["k0"], cache["v0"], t, block_tables,
                cfg, dims, pc, kind=mixer, pair=False)
            out = o
            new_cache["k0"], new_cache["v0"] = nk, nv
        elif fused:
            decode_fn = (A.decode_attn_seq_sharded
                         if seq_sharded_kind(cfg, dims, mixer, kv_mode)
                         else A.decode_attn_standard)
            out, nk, nv = decode_fn(
                gp["attn"], xn, cache["k"], cache["v"], t, cfg, dims, pc,
                kind=mixer, pair=True, window=cfg.window, chunk=cfg.chunk)
            new_cache["k"], new_cache["v"] = nk, nv
        else:
            outs = []
            for i, spec in enumerate(group.specs):
                ph = jax.tree.map(lambda w: w[i], gp["attn"]) if group.pair else gp["attn"]
                xi = xn[i] if group.pair else xn
                kd = spec.mixer
                if seq_sharded_kind(cfg, dims, kd, kv_mode):
                    o, nk, nv = A.decode_attn_seq_sharded(
                        ph, xi, cache[f"k{i}"], cache[f"v{i}"], t, cfg, dims, pc,
                        kind=kd, pair=False, window=cfg.window, chunk=cfg.chunk)
                else:
                    o, nk, nv = A.decode_attn_standard(
                        ph, xi, cache[f"k{i}"], cache[f"v{i}"], t, cfg, dims, pc,
                        kind=kd, pair=False, window=cfg.window, chunk=cfg.chunk)
                outs.append(o)
                new_cache[f"k{i}"], new_cache[f"v{i}"] = nk, nv
            out = sum(outs)
    else:
        xn_p = xn if group.pair else xn[None]
        key = "mamba" if mixer == "mamba" else "rec"
        mp = gp[key] if group.pair else jax.tree.map(lambda w: w[None], gp[key])
        if fused:  # stacked state: no per-step gather/scatter of the halves
            conv, h = cache["conv"], cache["h"]
        else:
            conv = jnp.stack([cache[f"conv{i}"] for i in range(nP)], axis=0)
            h = jnp.stack([cache[f"h{i}"] for i in range(nP)], axis=0)
        if mixer == "mamba":
            out, (nconv, nh) = SSM.ssm_mix(mp, xn_p, cfg, pc, state=(conv, h))
        else:
            out, (nconv, nh) = RG.rglru_mix(mp, xn_p, cfg, pc, state=(conv, h))
        if fused:
            new_cache["conv"], new_cache["h"] = nconv, nh
        else:
            for i in range(nP):
                new_cache[f"conv{i}"], new_cache[f"h{i}"] = nconv[i], nh[i]
    x = x + pc.psum_tp(out).astype(x.dtype)

    if group.specs[0].cross_attn and ("xk" in cache or "xk0" in cache):
        xnx = _norm_inputs(gp, "lnx", x, cfg, group)
        Hk, g = A.core_layout(dims)
        if "xk" in cache:
            # Fused pair cross-attention: one stacked q projection, one core
            # call with the pair folded into the head axis, one merged
            # output projection -> the psum below is the phase's ONE sync.
            q = A.project_q(gp["xattn"], xnx, cfg, dims, positions=None,
                            kind="attn_bidir", pair=True)   # [B,1,2*hq,hd]
            B = q.shape[0]
            ks = A.select_local_kv_pair(cache["xk"], dims, pc)  # [2,B,T,Hk,hd]
            vs = A.select_local_kv_pair(cache["xv"], dims, pc)
            T = ks.shape[2]
            ksf = jnp.moveaxis(ks, 0, 2).reshape(B, T, 2 * Hk, dims.hd)
            vsf = jnp.moveaxis(vs, 0, 2).reshape(B, T, 2 * Hk, dims.hd)
            o = A.attention_core(q.reshape(B, 1, 2 * Hk, g, dims.hd),
                                 ksf, vsf, kind="attn_bidir", impl="dense")
            o = o.reshape(B, 1, 2 * dims.hq, dims.hd)
            out = A.output_proj(gp["xattn"], o, dims, pair=True)
            new_cache["xk"], new_cache["xv"] = cache["xk"], cache["xv"]
        else:
            outs = []
            for i in range(nP):
                ph = jax.tree.map(lambda w: w[i], gp["xattn"]) if group.pair else gp["xattn"]
                xi = xnx[i] if group.pair else xnx
                q = A.project_q(ph, xi, cfg, dims, positions=None,
                                kind="attn_bidir", pair=False)
                ks = A.select_local_kv(cache[f"xk{i}"], dims, pc)
                vs = A.select_local_kv(cache[f"xv{i}"], dims, pc)
                B = q.shape[0]
                o = A.attention_core(q.reshape(B, 1, Hk, g, dims.hd), ks, vs,
                                     kind="attn_bidir", impl="dense")
                o = o.reshape(B, 1, dims.hq, dims.hd)
                outs.append(A.output_proj(ph, o, dims, pair=False))
                new_cache[f"xk{i}"], new_cache[f"xv{i}"] = cache[f"xk{i}"], cache[f"xv{i}"]
            out = sum(outs)
        x = x + pc.psum_tp(out).astype(x.dtype)

    if group.specs[0].ffn is not None:
        xn2 = _norm_inputs(gp, "ln2", x, cfg, group)
        out, _ = ffn_phase(gp, xn2, cfg, pc, group=group)
        x = x + pc.psum_tp(out).astype(x.dtype)
    return x, new_cache
