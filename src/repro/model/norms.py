"""Normalisation layers (fp32 statistics, bf16-safe).

``dual_norm`` is the LP-specific fused form: an LP pair needs BOTH layers'
norms of the SAME input tensor; computing them together shares the variance
reduction and (on TPU, via the Pallas kernel in repro.kernels.dual_rmsnorm)
reads ``x`` from HBM once instead of twice.
"""
from __future__ import annotations

import jax.numpy as jnp


def _stats_rms(x32):
    return jnp.mean(jnp.square(x32), axis=-1, keepdims=True)


def rmsnorm(x, scale, *, eps: float = 1e-6, plus_one: bool = False):
    x32 = x.astype(jnp.float32)
    inv = jnp.reciprocal(jnp.sqrt(_stats_rms(x32) + eps))
    s = (1.0 + scale.astype(jnp.float32)) if plus_one else scale.astype(jnp.float32)
    return (x32 * inv * s).astype(x.dtype)


def layernorm(x, scale, bias, *, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    xc = x32 - mu
    inv = jnp.reciprocal(jnp.sqrt(jnp.mean(jnp.square(xc), axis=-1, keepdims=True) + eps))
    return (xc * inv * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, cfg):
    """Dispatch on the architecture's norm kind. ``p`` is {"scale"[, "bias"]}."""
    if cfg.norm_kind == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"], plus_one=cfg.norm_plus_one)


_DUAL_IMPL = "xla"


def set_dual_impl(impl: str) -> None:
    """'xla' (default) or 'pallas' (repro.kernels.dual_rmsnorm fusion)."""
    global _DUAL_IMPL
    assert impl in ("xla", "pallas"), impl
    _DUAL_IMPL = impl


def dual_norm(x, p_a, p_b, cfg):
    """Both LP-pair norms of the same input; shares the fp32 statistics."""
    if _DUAL_IMPL == "pallas" and cfg.norm_kind == "rmsnorm":
        from repro.kernels import ops as KOPS
        return KOPS.dual_rmsnorm(x, p_a["scale"], p_b["scale"],
                                 plus_one=cfg.norm_plus_one)
    x32 = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        xc = x32 - mu
        inv = jnp.reciprocal(jnp.sqrt(jnp.mean(jnp.square(xc), axis=-1, keepdims=True) + 1e-5))
        xn = xc * inv
        ya = xn * p_a["scale"].astype(jnp.float32) + p_a["bias"].astype(jnp.float32)
        yb = xn * p_b["scale"].astype(jnp.float32) + p_b["bias"].astype(jnp.float32)
    else:
        inv = jnp.reciprocal(jnp.sqrt(_stats_rms(x32) + 1e-6))
        xn = x32 * inv
        sa = p_a["scale"].astype(jnp.float32)
        sb = p_b["scale"].astype(jnp.float32)
        if cfg.norm_plus_one:
            sa, sb = 1.0 + sa, 1.0 + sb
        ya, yb = xn * sa, xn * sb
    return ya.astype(x.dtype), yb.astype(x.dtype)


def init_norm(cfg, d: int):
    p = {"scale": jnp.zeros((d,), jnp.float32) if cfg.norm_plus_one else jnp.ones((d,), jnp.float32)}
    if cfg.norm_kind == "layernorm":
        p["scale"] = jnp.ones((d,), jnp.float32)
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p
