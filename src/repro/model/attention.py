"""Tensor-parallel multi-head attention for every assigned mixer kind.

TP conventions (per-rank shapes inside shard_map):
  * Q heads are padded to a multiple of tp (zero wo rows => padded heads are
    inert) and sharded over the ``model`` axis: hq = hq_global // tp.
  * KV heads are sharded when n_kv >= tp (minicpm, whisper) and replicated
    otherwise. In every replicated case of the assigned pool each rank's q
    heads map to exactly ONE kv head (group % hq == 0), so the rank selects
    its kv head dynamically and runs a grouped (g = hq) attention core —
    no KV expansion is ever materialised.

LP pairs reuse this module with a leading pair axis on the weights: one
einsum projects both layers' Q/K/V ("the stacked matmul" of the paper's
Fig. 5), the head axis simply doubles, and the pair's output projection is a
single contraction that also sums the two paths — the psum that follows is
the paper's ONE sync point for the attention phase of two layers.

On the decode path the pair's KV caches are stacked-contiguous
([2, B, L, Hkv, hd] — repro.model.blocks.group_cache_meta), and
``decode_attn_standard`` / ``decode_attn_seq_sharded`` with ``pair=True``
run both layers as one wide unit: one stacked projection, one cache write,
one attention core (or one ``decode_attention_pair`` Pallas launch when
``set_decode_impl("pallas")``), one merged output projection.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.model.params import PD
from repro.model.rope import apply_rope
from repro.parallel.context import ParallelContext

NEG_INF = -1e30

_DECODE_IMPL = "xla"


def set_decode_impl(impl: str) -> None:
    """'xla' (default) or 'pallas' (repro.kernels.decode_attention)."""
    global _DECODE_IMPL
    assert impl in ("xla", "pallas"), impl
    _DECODE_IMPL = impl


def get_decode_impl() -> str:
    """Current decode implementation (for save/restore around benchmarks)."""
    return _DECODE_IMPL


# ---------------------------------------------------------------------------
# Static dimension bookkeeping
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AttnDims:
    tp: int
    hq_global: int      # padded global q heads
    hq: int             # local q heads
    kv_sharded: bool
    hkv_global: int     # stored global kv heads (padded when sharded)
    hkv: int            # local kv heads held by each rank
    group: int          # ORIGINAL q-heads per kv-head (GQA group)
    hd: int
    per_head: bool = False  # rank q-heads span kv groups -> per-head kv gather


def attn_dims(cfg, tp: int) -> AttnDims:
    if cfg.n_heads == 0:  # attention-free arch (falcon-mamba)
        return AttnDims(tp, 0, 0, False, 0, 0, 1, cfg.head_dim or 1)
    hq_global = -(-cfg.n_heads // tp) * tp
    hq = hq_global // tp
    kv_sharded = cfg.n_kv_heads >= tp
    per_head = False
    if kv_sharded:
        hkv_global = -(-cfg.n_kv_heads // tp) * tp
        hkv = hkv_global // tp
        assert hq % hkv == 0, (hq, hkv)
    else:
        hkv_global = cfg.n_kv_heads
        hkv = cfg.n_kv_heads
    group = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    if not kv_sharded and tp > 1 and group % hq != 0:
        # Rank q-heads span GQA groups (llama4: 40 q heads padded to 48 over
        # 16 ranks, group=5, hq=3). Each rank gathers ITS q-heads' kv heads
        # (hq x hd per rank — negligible) and runs a g=1 grouped core.
        per_head = True
    return AttnDims(tp, hq_global, hq, kv_sharded, hkv_global, hkv, group,
                    cfg.head_dim, per_head)


def attn_template(cfg, tp: int, *, cross: bool = False):
    d = attn_dims(cfg, tp)
    D = cfg.d_model
    kv_spec = P(None, "model") if d.kv_sharded else P()
    t = {
        "wq": PD((D, d.hq_global * d.hd), P(None, "model")),
        "wk": PD((D, d.hkv_global * d.hd), kv_spec),
        "wv": PD((D, d.hkv_global * d.hd), kv_spec),
        "wo": PD((d.hq_global * d.hd, D), P("model", None)),
    }
    if getattr(cfg, "attn_bias", False):
        kv_bspec = P("model") if d.kv_sharded else P()
        t["bq"] = PD((d.hq_global * d.hd,), P("model"), init="zeros")
        t["bk"] = PD((d.hkv_global * d.hd,), kv_bspec, init="zeros")
        t["bv"] = PD((d.hkv_global * d.hd,), kv_bspec, init="zeros")
        t["bo"] = PD((D,), P(), init="zeros")
    return t


# ---------------------------------------------------------------------------
# Masking
# ---------------------------------------------------------------------------

def tile_mask(kind: str, qpos, kpos, *, window=0, chunk=0, prefix_len=0):
    """Boolean allowed-mask for absolute q positions x k positions.

    ``qpos`` may be [qb] (one offset for the whole batch) or [B, qb]
    (per-row offsets — the bucketed radix-suffix path, where every row
    starts at its own ctx length); the mask is [qb, kb] or [B, qb, kb]
    respectively."""
    q = qpos[..., None]
    k = kpos[None, :]
    if kind == "attn_bidir":
        return jnp.ones(qpos.shape + kpos.shape, bool)
    causal = k <= q
    if kind in ("attn", "attn_global"):
        if prefix_len:
            return causal | (k < prefix_len)
        return causal
    if kind == "attn_local":
        return causal & (q - k < window)
    if kind == "attn_chunked":
        return causal & (q // chunk == k // chunk)
    raise ValueError(kind)


def _uses_rope(cfg, kind: str) -> bool:
    return cfg.pos_embed == "rope" and kind not in ("attn_global", "attn_bidir")


# ---------------------------------------------------------------------------
# Attention cores (grouped layout: q [B,S,Hk,g,hd], kv [B,T,Hk,hd])
# ---------------------------------------------------------------------------

def _dense_core(q, k, v, mask):
    """Materialised-scores reference core (small S*T only)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bsngh,btnh->bngst", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale + jnp.where(mask, 0.0, NEG_INF)[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,btnh->bsngh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def _chunked_core(q, k, v, *, kind, window, chunk, prefix_len, q0, k0,
                  qb: int, kb: int, fixed_kb: bool = False):
    """Online-softmax (flash-style) core: O(S*block) memory, scan over q and
    kv tiles. ``q0``/``k0`` are the absolute offsets of q and k position 0.
    This is the XLA path; the Pallas kernel implements the same schedule on
    TPU (repro.kernels.flash_attention). Ragged S/T are padded to tile
    multiples; padded kv columns are masked via ``k_limit``.

    ``fixed_kb`` pins the kv tile width at ``kb`` even when T < kb (pad up
    instead of clamping down). With a pinned tile, the reduction grouping
    of every q row is a pure function of its own key horizon: a fully
    masked tile contributes ``corr = exp(m - m) = 1`` and ``p = 0``, so
    ``l = l * 1 + 0`` and ``acc = acc * 1 + 0`` are bitwise no-ops, and a
    partially masked tile reduces over the same ``kb`` lanes whatever the
    total padded length is. That makes right-padding the key axis BIT-
    TRANSPARENT for rows below the true length — the property the serve
    engine's bucketed prefill leans on (masked pad lanes carry finite
    values, so ``0 * v`` is exactly 0).

    ``q0`` may be an [B]-shaped array of PER-ROW offsets (the bucketed
    radix-suffix path: row i's queries start at its own ctx length); the
    mask then resolves per row while the tile schedule — and with
    ``fixed_kb`` the reduction grouping — stays row-independent."""
    B, S0, Hk, g, hd = q.shape
    T0 = k.shape[1]
    qb = min(qb, S0)
    if not fixed_kb:
        kb = min(kb, T0)
    pad_q = (-S0) % qb
    pad_k = (-T0) % kb
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    S, T = S0 + pad_q, T0 + pad_k
    k_limit = k0 + T0
    scale = hd ** -0.5
    nq, nk = S // qb, T // kb

    qt = q.reshape(B, nq, qb, Hk, g, hd).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,Hk,g,qb,hd]
    kt = k.reshape(B, nk, kb, Hk, hd).transpose(1, 0, 3, 2, 4)        # [nk,B,Hk,kb,hd]
    vt = v.reshape(B, nk, kb, Hk, hd).transpose(1, 0, 3, 2, 4)

    per_row = getattr(q0, "ndim", 0) > 0   # q0 is [B]: per-row offsets

    def q_step(_, qi_and_tile):
        qi, qtile = qi_and_tile
        base = q0[:, None] if per_row else q0
        qpos = base + qi * qb + jnp.arange(qb)   # [qb] or [B,qb]

        def kv_step(carry, ki_and_tiles):
            m, l, acc = carry
            ki, ktile, vtile = ki_and_tiles
            kpos = k0 + ki * kb + jnp.arange(kb)
            msk = tile_mask(kind, qpos, kpos, window=window, chunk=chunk,
                            prefix_len=prefix_len)  # [qb,kb] or [B,qb,kb]
            msk = msk & (kpos < k_limit)[None, :]   # kv padding columns
            s = jnp.einsum("bngqh,bnkh->bngqk", qtile.astype(jnp.float32),
                           ktile.astype(jnp.float32)) * scale
            bias = jnp.where(msk, 0.0, NEG_INF)
            s = s + (bias[:, None, None, :, :] if per_row
                     else bias[None, None, None, :, :])
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bngqk,bnkh->bngqh", p, vtile.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hk, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, g, qb), jnp.float32)
        a0 = jnp.zeros((B, Hk, g, qb, hd), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (jnp.arange(nk), kt, vt))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, tiles = lax.scan(q_step, None, (jnp.arange(nq), qt))  # [nq,B,Hk,g,qb,hd]
    out = tiles.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, Hk, g, hd)
    return out[:, :S0] if pad_q else out


_PALLAS_KIND = {"attn": "causal", "attn_global": "causal",
                "attn_local": "window", "attn_chunked": "chunk",
                "attn_bidir": "bidir"}


def attention_core(q, k, v, *, kind, window=0, chunk=0, prefix_len=0,
                   q0=0, k0=0, impl="auto", qb=512, kb=1024):
    B, S, Hk, g, hd = q.shape
    T = k.shape[1]
    if getattr(q0, "ndim", 0) > 0 and not impl.startswith("chunked:"):
        # Per-row offsets are only wired through the pinned-tile chunked
        # core (the serve prefill impl); the dense/pallas paths would
        # silently build a single shared mask from the wrong-rank qpos.
        raise NotImplementedError(
            f"per-row q0 requires a pinned chunked impl ('chunked:<kb>'), "
            f"got impl={impl!r}")
    if impl.startswith("chunked:"):
        # Pinned kv tile width ("chunked:16" -> kb=16, never clamped to T):
        # the serve prefill path uses this so bucket-padded and exact-length
        # forwards reduce with identical per-tile grouping (see
        # _chunked_core fixed_kb).
        kb = int(impl.split(":", 1)[1])
        return _chunked_core(q, k, v, kind=kind, window=window, chunk=chunk,
                             prefix_len=prefix_len, q0=q0, k0=k0, qb=qb,
                             kb=kb, fixed_kb=True)
    if impl == "auto":
        impl = "dense" if S * T <= 2048 * 2048 else "chunked"
    if impl == "pallas":
        # GQA-folded flash kernel: rows of one kv head = [position, group].
        from repro.kernels import ops as KOPS
        qf = q.transpose(0, 2, 1, 3, 4).reshape(B * Hk, S * g, hd)
        kf = jnp.moveaxis(k, 2, 1).reshape(B * Hk, T, hd)
        vf = jnp.moveaxis(v, 2, 1).reshape(B * Hk, T, hd)
        o = KOPS.flash_attention(qf, kf, vf, kind=_PALLAS_KIND[kind],
                                 window=window, chunk=chunk,
                                 prefix_len=prefix_len, q0=q0, k0=k0,
                                 q_group=g)
        return o.reshape(B, Hk, S, g, hd).transpose(0, 2, 1, 3, 4)
    if impl == "dense":
        qpos = q0 + jnp.arange(S)
        kpos = k0 + jnp.arange(T)
        mask = tile_mask(kind, qpos, kpos, window=window, chunk=chunk,
                         prefix_len=prefix_len)[None]
        mask = jnp.broadcast_to(mask, (B, S, T))
        return _dense_core(q, k, v, mask)
    return _chunked_core(q, k, v, kind=kind, window=window, chunk=chunk,
                         prefix_len=prefix_len, q0=q0, k0=k0, qb=qb, kb=kb)


# ---------------------------------------------------------------------------
# Projections (single layer and LP pair) + rank-local KV selection
# ---------------------------------------------------------------------------

def _proj(x, w, b, tp):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def _proj_pair(xs, w, b):
    """xs: [2,B,S,D] (per-path normalised inputs); w: [2,D,C] -> [2,B,S,C].
    One batched matmul for both paths == the paper's stacked projection."""
    y = jnp.einsum("pbsd,pdc->pbsc", xs, w.astype(xs.dtype))
    if b is not None:
        y = y + b[:, None, None, :].astype(y.dtype)
    return y


def rank_head_kv_map(dims: AttnDims, pc: ParallelContext):
    """[hq] kv-head index for each of this rank's q heads (per-head mode).
    Padded q heads clip to the last kv head (their wo rows are zero)."""
    base = pc.tp_index() * dims.hq
    return jnp.clip((base + jnp.arange(dims.hq)) // dims.group,
                    0, dims.hkv - 1)


def select_local_kv(kv, dims: AttnDims, pc: ParallelContext, *,
                    head_axis: int = 2):
    """kv as stored ([B,T,hkv,hd], head axis 2). Returns the rank-local
    selection for the grouped core: hkv heads when sharded; 1 (this rank's
    kv head) when replicated and the rank's q block lives in one GQA group;
    hq per-head gathered otherwise."""
    if dims.kv_sharded or dims.tp == 1:
        return kv
    if dims.per_head:
        return jnp.take(kv, rank_head_kv_map(dims, pc), axis=head_axis)
    base = pc.tp_index() * dims.hq
    kv_idx = jnp.clip(base // dims.group, 0, dims.hkv - 1)
    return lax.dynamic_slice_in_dim(kv, kv_idx, 1, axis=head_axis)


def select_local_kv_pair(kv, dims: AttnDims, pc: ParallelContext):
    """Stacked-pair variant: kv [2,B,T,hkv,hd] -> [2,B,T,Hk_eff,hd]. The
    same selection on head axis 3 so the pair stays one contiguous tensor
    for the fused decode kernel."""
    return select_local_kv(kv, dims, pc, head_axis=3)


def paged_head_map(dims: AttnDims, pc: ParallelContext):
    """Local-head -> STORED-head map for the paged decode kernels, or None
    when the identity applies (tp == 1, or kv heads sharded so each rank's
    pool shard already holds exactly its heads).

    This is ``select_local_kv`` expressed as an index map instead of a
    gather: the paged pool keeps all stored kv heads replicated across
    ranks, and the kernel's BlockSpec index map streams only the head(s)
    this rank's q rows need (repro.kernels.decode_attention._launch_paged),
    so replicated-kv TP never materialises a per-rank kv selection on the
    Pallas path.
    """
    if dims.tp == 1 or dims.kv_sharded:
        return None
    if dims.per_head:
        return rank_head_kv_map(dims, pc)            # [hq], g = 1
    base = pc.tp_index() * dims.hq
    kv_idx = jnp.clip(base // dims.group, 0, dims.hkv - 1)
    return kv_idx[None]                              # [1], g = hq


def core_layout(dims: AttnDims) -> Tuple[int, int]:
    """(Hk_eff, g) of the grouped core for one layer's local heads."""
    if dims.tp == 1 or dims.kv_sharded:
        assert dims.hq % dims.hkv == 0, (dims.hq, dims.hkv)
        return dims.hkv, dims.hq // dims.hkv
    if dims.per_head:
        return dims.hq, 1  # per-head gathered kv
    return 1, dims.hq  # replicated kv: one rank = one kv head, g = hq


def project_q(p, xn, cfg, dims: AttnDims, *, positions, kind, pair: bool):
    """q in folded layout [B,S,P*hq,hd] (pair-interleaved by... pair-MAJOR? No:
    pair axis folds as [2, hq] per position -> heads [2*hq], layer-a first)."""
    bq = p.get("bq")
    if pair:
        B, S = xn.shape[1], xn.shape[2]
        q = _proj_pair(xn, p["wq"], bq)
        q = q.transpose(1, 2, 0, 3).reshape(B, S, 2 * dims.hq, dims.hd)
    else:
        B, S = xn.shape[0], xn.shape[1]
        q = _proj(xn, p["wq"], bq, dims.tp).reshape(B, S, dims.hq, dims.hd)
    if _uses_rope(cfg, kind):
        q = apply_rope(q, positions, cfg.rope_theta)
    return q


def project_kv(p, xn, cfg, dims: AttnDims, *, positions, kind, pair: bool):
    """k, v in stored layout [B,S,P*hkv,hd] (pair folded into the head axis).
    ``xn`` is the self-attention input, or the (raw) encoder output for
    cross-attention (kind='attn_bidir' -> no rope on keys)."""
    bk = p.get("bk"); bv = p.get("bv")
    if pair:
        B, S = xn.shape[1], xn.shape[2]
        k = _proj_pair(xn, p["wk"], bk).transpose(1, 2, 0, 3).reshape(B, S, 2 * dims.hkv, dims.hd)
        v = _proj_pair(xn, p["wv"], bv).transpose(1, 2, 0, 3).reshape(B, S, 2 * dims.hkv, dims.hd)
    else:
        B, S = xn.shape[0], xn.shape[1]
        k = _proj(xn, p["wk"], bk, dims.tp).reshape(B, S, dims.hkv, dims.hd)
        v = _proj(xn, p["wv"], bv, dims.tp).reshape(B, S, dims.hkv, dims.hd)
    if _uses_rope(cfg, kind):
        k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def project_qkv(p, xn, cfg, dims: AttnDims, pc, *, positions, kind, pair: bool):
    """Self-attention q/k/v from the same normalised input."""
    q = project_q(p, xn, cfg, dims, positions=positions, kind=kind, pair=pair)
    k, v = project_kv(p, xn, cfg, dims, positions=positions, kind=kind, pair=pair)
    return q, k, v


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------

def cache_slot(kind: str, t, *, window=0, chunk=0):
    """Ring-buffer slot + local validity horizon for a decode step ``t``.

    Returns (slot_index, t_local) where entries with arange(L) <= t_local are
    valid. For plain causal caches slot == t; window/chunked kinds reuse a
    ring of size window/chunk.
    """
    if kind == "attn_local" and window:
        return t % window, jnp.minimum(t, window - 1)
    if kind == "attn_chunked" and chunk:
        return t % chunk, t % chunk
    return t, t


def decode_attn_standard(p, xn, cache_k, cache_v, t, cfg, dims: AttnDims, pc,
                         *, kind, pair: bool, window=0, chunk=0):
    """Decode with head-local caches. Returns (partial_out, new_k, new_v).

    pair=False: xn [B,1,D], cache_[kv] [B, L, hkv_stored, hd].
    pair=True (fused LP pair): xn [2,B,1,D] (both per-path norms of the same
    residual), cache_[kv] [2, B, L, hkv_stored, hd] STACKED-CONTIGUOUS.
    Both layers run as one wide unit: ONE stacked QKV projection einsum,
    ONE ring-slot write per cache tensor, ONE attention core / kernel
    launch over the leading pair axis, ONE merged output projection — the
    caller's psum after this is the pair's single attention-phase sync.

    hkv_stored == n_kv (replicated) or hkv (sharded).
    """
    B = xn.shape[1] if pair else xn.shape[0]
    pos = jnp.asarray(t)[None] if jnp.ndim(t) == 0 else t
    q, k, v = project_qkv(p, xn, cfg, dims, pc, positions=pos, kind=kind, pair=pair)
    slot, t_local = cache_slot(kind, t, window=window, chunk=chunk)
    Hk, g = core_layout(dims)
    scale = dims.hd ** -0.5

    if pair:
        hkv_st = cache_k.shape[3]
        L = cache_k.shape[2]
        # New-token kv arrives pair-folded [B,1,2*hkv,hd]; unfold to the
        # stacked layout and write BOTH layers' slots in one update.
        k2 = k.reshape(B, 1, 2, hkv_st, dims.hd).transpose(2, 0, 1, 3, 4)
        v2 = v.reshape(B, 1, 2, hkv_st, dims.hd).transpose(2, 0, 1, 3, 4)
        cache_k = lax.dynamic_update_slice_in_dim(
            cache_k, k2.astype(cache_k.dtype), slot, axis=2)
        cache_v = lax.dynamic_update_slice_in_dim(
            cache_v, v2.astype(cache_v.dtype), slot, axis=2)
        ks = select_local_kv_pair(cache_k, dims, pc)   # [2,B,L,Hk,hd]
        vs = select_local_kv_pair(cache_v, dims, pc)
        qh = q.reshape(B, 2, Hk, g, dims.hd)           # pair-major heads, S=1
        if _DECODE_IMPL == "pallas":
            from repro.kernels import ops as KOPS
            qp = qh.transpose(1, 0, 2, 3, 4)           # [2,B,Hk,g,hd]
            o = KOPS.decode_attention_pair(qp, ks, vs, t_local).astype(xn.dtype)
            o = o.transpose(1, 0, 2, 3, 4).reshape(B, 1, 2 * dims.hq, dims.hd)
            return output_proj(p, o, dims, pair=True), cache_k, cache_v
        s = jnp.einsum("bpngh,pbtnh->bpngt", qh.astype(jnp.float32),
                       ks.astype(jnp.float32)) * scale
        valid = (jnp.arange(L) <= t_local)[None, None, None, None, :]
        s = jnp.where(valid, s, NEG_INF)
        pweights = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bpngt,pbtnh->bpngh", pweights, vs.astype(jnp.float32))
        o = o.astype(xn.dtype).reshape(B, 1, 2 * dims.hq, dims.hd)
        return output_proj(p, o, dims, pair=True), cache_k, cache_v

    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)
    L = cache_k.shape[1]
    ks = select_local_kv(cache_k, dims, pc)
    vs = select_local_kv(cache_v, dims, pc)
    qh = q.reshape(B, 1, Hk, g, dims.hd)
    if _DECODE_IMPL == "pallas":
        from repro.kernels import ops as KOPS
        o = KOPS.decode_attention(qh[:, 0], ks, vs, t_local).astype(xn.dtype)
        o = o.reshape(B, 1, dims.hq, dims.hd)
        return output_proj(p, o, dims, pair=False), cache_k, cache_v
    s = jnp.einsum("bsngh,btnh->bngst", qh.astype(jnp.float32), ks.astype(jnp.float32)) * scale
    valid = (jnp.arange(L) <= t_local)[None, None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    pweights = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,btnh->bsngh", pweights, vs.astype(jnp.float32))
    o = o.astype(xn.dtype).reshape(B, 1, dims.hq, dims.hd)
    return output_proj(p, o, dims, pair=False), cache_k, cache_v


def decode_attn_paged(p, xn, k_pages, v_pages, t, block_tables, cfg,
                      dims: AttnDims, pc, *, kind, pair: bool):
    """Decode against the PAGED cache pool (continuous batching).

    pair=False: xn [B,1,D], k/v_pages [n_pages, ps, hkv_stored, hd].
    pair=True (fused LP pair): xn [2,B,1,D], k/v_pages [2, n_pages, ps,
    hkv_stored, hd] stacked-contiguous — both halves occupy the SAME page
    indices of their own half, so one block table serves the pair and the
    pair still costs ONE projection, ONE scatter per cache tensor, ONE
    attention launch and ONE merged output projection.

    t: [B] int32 per-slot absolute positions (every slot decodes at its own
    stream position); block_tables: [B, n_pg] int32 page indirection, with
    unused entries (and idle slots' whole rows) pointing at the reserved
    garbage page 0 — their writes are harmless and their reads mask out.
    Only plain causal kinds page (slot == t); window/chunk rings are
    rejected upstream (serve.paged_cache.validate_paged_support).

    TP: kv-sharded pools hold this rank's heads (identity head map);
    replicated-kv ranks select their kv head(s) in-kernel through
    ``paged_head_map`` on the Pallas path and ``select_local_kv`` on the
    XLA gather path — both run under tp > 1.

    Returns (partial_out, new_k_pages, new_v_pages).
    """
    B = xn.shape[1] if pair else xn.shape[0]
    q, k, v = project_qkv(p, xn, cfg, dims, pc, positions=t[:, None],
                          kind=kind, pair=pair)
    page_ax = 1 if pair else 0
    ps = k_pages.shape[page_ax + 1]
    # Indirection: position t lives at (bt[b, t // ps], t % ps).
    page_of = jnp.take_along_axis(block_tables, (t // ps)[:, None],
                                  axis=1)[:, 0]
    off = t % ps
    Hk, g = core_layout(dims)
    scale = dims.hd ** -0.5

    if pair:
        hkv_st = k_pages.shape[3]
        # New-token kv arrives pair-folded [B,1,2*hkv,hd]; unfold and write
        # both halves' (page, offset) in ONE scatter per cache tensor.
        k2 = k.reshape(B, 2, hkv_st, dims.hd).transpose(1, 0, 2, 3)
        v2 = v.reshape(B, 2, hkv_st, dims.hd).transpose(1, 0, 2, 3)
        k_pages = k_pages.at[:, page_of, off].set(k2.astype(k_pages.dtype))
        v_pages = v_pages.at[:, page_of, off].set(v2.astype(v_pages.dtype))
        qh = q.reshape(B, 2, Hk, g, dims.hd)           # pair-major heads, S=1
        if _DECODE_IMPL == "pallas":
            from repro.kernels import ops as KOPS
            qp = qh.transpose(1, 0, 2, 3, 4)           # [2,B,Hk,g,hd]
            o = KOPS.decode_attention_pair_paged(
                qp, k_pages, v_pages, block_tables, t,
                paged_head_map(dims, pc)).astype(xn.dtype)
            o = o.transpose(1, 0, 2, 3, 4).reshape(B, 1, 2 * dims.hq, dims.hd)
            return output_proj(p, o, dims, pair=True), k_pages, v_pages
        # XLA path: gather the slots' pages back into per-request sequences
        # ([2, B, L, hkv, hd], L = n_pg * ps) and run the ring core math.
        kg = jnp.take(k_pages, block_tables, axis=1)
        vg = jnp.take(v_pages, block_tables, axis=1)
        L = kg.shape[2] * ps
        ks = select_local_kv_pair(kg.reshape(2, B, L, hkv_st, dims.hd), dims, pc)
        vs = select_local_kv_pair(vg.reshape(2, B, L, hkv_st, dims.hd), dims, pc)
        s = jnp.einsum("bpngh,pbtnh->bpngt", qh.astype(jnp.float32),
                       ks.astype(jnp.float32)) * scale
        valid = jnp.arange(L)[None, :] <= t[:, None]   # per-slot horizon
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        pweights = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bpngt,pbtnh->bpngh", pweights, vs.astype(jnp.float32))
        o = o.astype(xn.dtype).reshape(B, 1, 2 * dims.hq, dims.hd)
        return output_proj(p, o, dims, pair=True), k_pages, v_pages

    hkv_st = k_pages.shape[2]
    k_pages = k_pages.at[page_of, off].set(k[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[page_of, off].set(v[:, 0].astype(v_pages.dtype))
    qh = q.reshape(B, 1, Hk, g, dims.hd)
    if _DECODE_IMPL == "pallas":
        from repro.kernels import ops as KOPS
        o = KOPS.decode_attention_paged(
            qh[:, 0], k_pages, v_pages, block_tables, t,
            paged_head_map(dims, pc)).astype(xn.dtype)
        o = o.reshape(B, 1, dims.hq, dims.hd)
        return output_proj(p, o, dims, pair=False), k_pages, v_pages
    kg = jnp.take(k_pages, block_tables, axis=0)
    vg = jnp.take(v_pages, block_tables, axis=0)
    L = kg.shape[1] * ps
    ks = select_local_kv(kg.reshape(B, L, hkv_st, dims.hd), dims, pc)
    vs = select_local_kv(vg.reshape(B, L, hkv_st, dims.hd), dims, pc)
    s = jnp.einsum("bsngh,btnh->bngst", qh.astype(jnp.float32),
                   ks.astype(jnp.float32)) * scale
    valid = jnp.arange(L)[None, :] <= t[:, None]
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    pweights = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngst,btnh->bsngh", pweights, vs.astype(jnp.float32))
    o = o.astype(xn.dtype).reshape(B, 1, dims.hq, dims.hd)
    return output_proj(p, o, dims, pair=False), k_pages, v_pages


def decode_attn_seq_sharded(p, xn, cache_k, cache_v, t, cfg, dims: AttnDims, pc,
                            *, kind, pair: bool, window=0, chunk=0):
    """Decode with the KV cache sharded along SEQUENCE over the model axis
    (for kv_heads < tp: avoids tp-fold cache replication, multiplies the
    aggregate HBM bandwidth of the cache read by tp).

    cache_[kv]: [B, L/tp, n_kv, hd] per rank; pair=True uses the stacked
    layout [2, B, L/tp, n_kv, hd] and runs BOTH layers through one gathered
    attention evaluation. Combines partial softmax stats across ranks with
    one pmax + ONE packed psum per phase regardless of pair width.
    """
    nP = 2 if pair else 1
    B = xn.shape[1] if pair else xn.shape[0]
    pos = jnp.asarray(t)[None] if jnp.ndim(t) == 0 else t
    q, k, v = project_qkv(p, xn, cfg, dims, pc, positions=pos, kind=kind, pair=pair)
    # q: [B,1,nP*hq,hd] local -> gather all q heads.
    qg = pc.all_gather_tp(q, axis=2)  # [B,1,tp*nP*hq,hd] rank-major
    tp = dims.tp
    if pair:
        qg = qg.reshape(B, 1, tp, 2, dims.hq, dims.hd).transpose(0, 1, 3, 2, 4, 5)
        qg = qg.reshape(B, 1, 2, tp * dims.hq, dims.hd)
    else:
        qg = qg.reshape(B, 1, 1, tp * dims.hq, dims.hd)

    # Cache update: only the owner rank of slot ``t`` writes.
    slot, t_local = cache_slot(kind, t, window=window, chunk=chunk)
    seq_ax = 2 if pair else 1
    L_loc = cache_k.shape[seq_ax]
    n_kv = cache_k.shape[seq_ax + 1]
    rank = pc.tp_index()
    local_slot = slot - rank * L_loc
    in_range = (local_slot >= 0) & (local_slot < L_loc)
    idx = jnp.clip(local_slot, 0, L_loc - 1)
    if pair:  # unfold the pair-folded new token to the stacked layout
        kn = k.reshape(B, 1, 2, n_kv, dims.hd).transpose(2, 0, 1, 3, 4)
        vn = v.reshape(B, 1, 2, n_kv, dims.hd).transpose(2, 0, 1, 3, 4)
    else:
        kn, vn = k, v
    old_k = lax.dynamic_slice_in_dim(cache_k, idx, 1, axis=seq_ax)
    old_v = lax.dynamic_slice_in_dim(cache_v, idx, 1, axis=seq_ax)
    new_k = jnp.where(in_range, kn.astype(cache_k.dtype), old_k)
    new_v = jnp.where(in_range, vn.astype(cache_v.dtype), old_v)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, new_k, idx, axis=seq_ax)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, new_v, idx, axis=seq_ax)

    Hq_all = tp * dims.hq          # == padded global q heads
    ks = cache_k if pair else cache_k[None]   # [nP,B,L_loc,n_kv,hd]
    vs = cache_v if pair else cache_v[None]
    if dims.per_head or Hq_all != dims.group * n_kv:
        # Expand kv per q head with the TRUE head->kv map (padded q heads
        # clip; their wo rows are zero). The uniform grouped reshape below
        # is only valid when padding did not inflate the global head count
        # (Hq_all == group * n_kv); otherwise head i's kv is i // group
        # clipped, not i // (Hq_all // n_kv).
        hmap = jnp.clip(jnp.arange(Hq_all) // dims.group, 0, n_kv - 1)
        ks = jnp.take(ks, hmap, axis=3)
        vs = jnp.take(vs, hmap, axis=3)
        n_kv_eff, g = Hq_all, 1
    else:
        n_kv_eff, g = n_kv, Hq_all // max(n_kv, 1)
    qh = qg.reshape(B, 1, nP, n_kv_eff, g, dims.hd)

    scale = dims.hd ** -0.5
    s = jnp.einsum("bspngh,pbtnh->bpngst", qh.astype(jnp.float32), ks.astype(jnp.float32)) * scale
    s = s[..., 0, :]  # squeeze q-position -> [B,P,n,g,L_loc]
    gpos = rank * L_loc + jnp.arange(L_loc)
    valid = gpos <= t_local
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    m_g = pc.pmax_tp(m)
    pexp = jnp.exp(s - m_g[..., None])
    l = pexp.sum(axis=-1)
    acc = jnp.einsum("bpngt,pbtnh->bpngh", pexp, vs.astype(jnp.float32))
    # ONE stacked psum for (l, acc).
    packed = jnp.concatenate([acc, l[..., None]], axis=-1)
    packed = pc.psum_tp(packed)
    acc, l = packed[..., :-1], packed[..., -1]
    o_all = acc / jnp.maximum(l, 1e-30)[..., None]  # [B,P,n_eff,g,hd]
    o_all = o_all.reshape(B, nP, Hq_all, dims.hd)
    # Slice back this rank's q heads.
    o_loc = lax.dynamic_slice_in_dim(o_all, rank * dims.hq, dims.hq, axis=2)
    o = o_loc.reshape(B, nP * dims.hq, dims.hd)[:, None]  # pair-major [B,1,nP*hq,hd]
    return output_proj(p, o, dims, pair=pair), cache_k, cache_v


def output_proj(p, o, dims: AttnDims, *, pair: bool):
    """o: [B,S,P*hq,hd] -> partial [B,S,D] (caller runs phase_out)."""
    B, S = o.shape[0], o.shape[1]
    if pair:
        # Pair output projection as two per-path gemms + one explicit add.
        # The einsum form ("pbsc,pcd->bsd") contracts (p, c) jointly and
        # XLA's split of that reduction can depend on the sequence length,
        # which breaks the suffix-prefill bit-identity contract
        # (repro.serve). Per-path-then-add pins the grouping; the psum
        # after this is still the pair's one attention-phase sync.
        o2 = o.reshape(B, S, 2, dims.hq * dims.hd)
        wo = p["wo"].astype(o.dtype)
        y = o2[:, :, 0] @ wo[0] + o2[:, :, 1] @ wo[1]
    else:
        y = o.reshape(B, S, dims.hq * dims.hd) @ p["wo"].astype(o.dtype)
    if p.get("bo") is not None:
        bo = p["bo"].astype(jnp.float32)
        if pair:
            bo = bo.sum(axis=0)  # both paths' biases enter the one reduction
        y = y + (bo / dims.tp).astype(y.dtype)
    return y
