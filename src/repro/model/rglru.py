"""RG-LRU recurrent block (RecurrentGemma / Griffin) with tensor parallelism.

Structure: gate branch (linear -> GeLU) * recurrent branch (linear -> causal
conv -> RG-LRU), then a row-parallel output projection. The LRU width is
sharded over ``model``; the per-channel recurrence is rank-local, so a block
costs exactly ONE sync (the phase-exit psum) and an LP pair of two recurrent
blocks still costs one.

The input/recurrence gates use per-channel (diagonal) weights — a documented
simplification of Griffin's block-diagonal heads (DESIGN.md §deviations).
Shares the chunked/sequential scan machinery with the Mamba mixer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.model.params import PD
from repro.model.ssm import _causal_conv, _scan_chunked, _scan_seq
from repro.parallel.context import ParallelContext

_C_RGLRU = 8.0


def rglru_template(cfg, tp: int):
    D, W, K = cfg.d_model, cfg.lru_width, cfg.rec_conv
    assert W % tp == 0
    return {
        "w_gate": PD((D, W), P(None, "model")),
        "w_rec": PD((D, W), P(None, "model")),
        "conv_w": PD((K, W), P(None, "model"), fan_in=K),
        "conv_b": PD((W,), P("model"), init="zeros"),
        "lam": PD((W,), P("model"), init="ones"),    # softplus(lam) ~ decay rate
        "wa": PD((W,), P("model"), init="zeros"),
        "ba": PD((W,), P("model"), init="zeros"),
        "wx": PD((W,), P("model"), init="zeros"),
        "bx": PD((W,), P("model"), init="zeros"),
        "w_out": PD((W, D), P("model", None)),
    }


def rglru_mix(p, xn, cfg, pc: ParallelContext, *, impl="chunked", chunk=256,
              state=None):
    """xn: [P,B,S,D]. Returns (partial [B,S,D], (conv_state, h))."""
    Pp, B, S, D = xn.shape
    K = cfg.rec_conv

    gate = jax.nn.gelu(
        jnp.einsum("pbsd,pdw->pbsw", xn, p["w_gate"].astype(xn.dtype)).astype(jnp.float32))
    xr = jnp.einsum("pbsd,pdw->pbsw", xn, p["w_rec"].astype(xn.dtype))

    if state is not None:
        conv_prev, h_prev = state
        xcat = jnp.concatenate([conv_prev.astype(xr.dtype), xr], axis=2)
        new_conv = xcat[:, :, -(K - 1):, :]
        xc = _causal_conv(xcat, p["conv_w"], p["conv_b"])[:, :, -S:, :]
    else:
        xc = _causal_conv(xr, p["conv_w"], p["conv_b"])
        new_conv = xr[:, :, -(K - 1):, :] if S >= K - 1 else jnp.pad(
            xr, ((0, 0), (0, 0), (K - 1 - S, 0), (0, 0)))
        W = xr.shape[-1]
        h_prev = jnp.zeros((Pp, B, W, 1), jnp.float32)

    x32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 * p["wa"][:, None, None, :] + p["ba"][:, None, None, :])
    i = jax.nn.sigmoid(x32 * p["wx"][:, None, None, :] + p["bx"][:, None, None, :])
    log_a = -_C_RGLRU * jax.nn.softplus(p["lam"])[:, None, None, :] * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)

    # Per-channel recurrence == the N=1 case of the SSM scan.
    a4, b4 = a[..., None], b[..., None]
    if state is not None or impl == "seq":
        y, hT = _scan_seq(a4, b4, h_prev)
    elif impl == "pallas":
        from repro.kernels import ops as KOPS
        Pp_, B_, S_, C_ = a.shape
        y2, h2 = KOPS.ssm_scan(a4.reshape(Pp_ * B_, S_, C_, 1),
                               b4.reshape(Pp_ * B_, S_, C_, 1),
                               h_prev.reshape(Pp_ * B_, C_, 1))
        y = y2.reshape(Pp_, B_, S_, C_, 1)
        hT = h2.reshape(Pp_, B_, C_, 1)
    else:
        y, hT = _scan_chunked(a4, b4, h_prev, chunk)
    y = y[..., 0] * gate

    out = jnp.einsum("pbsw,pwd->bsd", y.astype(xn.dtype), p["w_out"].astype(xn.dtype))
    return out, (new_conv, hT)
