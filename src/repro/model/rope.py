"""Rotary position embeddings (fp32 rotation, llama convention)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float):
    exps = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exps)  # (head_dim/2,)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
