"""Parameter templates: one source of truth for shape, sharding and init.

Model modules describe their parameters as trees of ``PD`` descriptors
(GLOBAL shapes + PartitionSpec). From a template we derive:
  * initialised arrays            (init_tree)
  * PartitionSpec tree            (pspec_tree)    -> shard_map in_specs
  * abstract ShapeDtypeStructs    (abstract_tree) -> dry-run lowering
so the three can never drift apart.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class PD:
    """Descriptor of one GLOBAL parameter tensor."""

    shape: Tuple[int, ...]
    pspec: P = P()
    init: str = "normal"  # normal | zeros | ones
    fan_in: Optional[int] = None  # None -> last-but-one dim (or last)
    dtype: Any = None  # None -> use the build dtype


def is_pd(x) -> bool:
    return isinstance(x, PD)


def _leaves(tmpl):
    return jax.tree.flatten(tmpl, is_leaf=is_pd)


def init_tree(tmpl, key, dtype=jnp.float32):
    leaves, treedef = _leaves(tmpl)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for pd, k in zip(leaves, keys):
        dt = pd.dtype or dtype
        if pd.init == "zeros":
            out.append(jnp.zeros(pd.shape, dt))
        elif pd.init == "ones":
            out.append(jnp.ones(pd.shape, dt))
        else:
            fan = pd.fan_in
            if fan is None:
                fan = pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]
            std = fan ** -0.5
            out.append((jax.random.normal(k, pd.shape, jnp.float32) * std).astype(dt))
    return treedef.unflatten(out)


def pspec_tree(tmpl):
    leaves, treedef = _leaves(tmpl)
    return treedef.unflatten([pd.pspec for pd in leaves])


def abstract_tree(tmpl, dtype=jnp.bfloat16):
    leaves, treedef = _leaves(tmpl)
    return treedef.unflatten(
        [jax.ShapeDtypeStruct(pd.shape, pd.dtype or dtype) for pd in leaves]
    )


# -- structural helpers ------------------------------------------------------

def stack_tmpl(tmpl, n: int):
    """Template for ``n`` stacked copies (scan segments / LP pairs): prepend a
    replicated leading axis to every descriptor."""

    def bump(pd: PD) -> PD:
        return PD(
            shape=(n, *pd.shape),
            pspec=P(None, *pd.pspec),
            init=pd.init,
            fan_in=pd.fan_in if pd.fan_in is not None else (pd.shape[-2] if len(pd.shape) >= 2 else pd.shape[-1]),
            dtype=pd.dtype,
        )

    return jax.tree.map(bump, tmpl, is_leaf=is_pd)


def stack_trees(trees):
    """Stack a list of identical-structure param trees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def unstack_tree(tree, n: int):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]
