"""Mixture-of-Experts FFN with expert parallelism over the ``model`` axis.

Design (replicated-activation EP): inside a TP region the tokens are already
replicated across the model axis, so expert dispatch needs NO all_to_all —
each rank gathers the tokens routed to ITS experts (capacity-bounded,
sort-free cumsum dispatch), runs them through its local experts, scatters the
weighted results back, and the ordinary phase-exit psum both completes the
combine and merges with the attention residual. For an LP pair the two
layers' expert sets form one virtual 2E-expert dispatch and the pair still
costs ONE reduction — the paper's sync-halving carries over to MoE.

Aux outputs (load-balance loss) follow Switch/GShard: mean(frac_tokens *
frac_router_prob) * E.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.model.params import PD
from repro.parallel.context import ParallelContext

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def moe_template(cfg, tp: int):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe_experts
    assert E % tp == 0, (cfg.name, E, tp)
    t = {
        "router": PD((D, E), P(), fan_in=D),
        "w_up": PD((E, D, F), P("model", None, None)),
        "w_down": PD((E, F, D), P("model", None, None)),
    }
    if cfg.mlp_gated:
        t["w_gate"] = PD((E, D, F), P("model", None, None))
    if cfg.moe_shared_expert:
        t["shared"] = {
            "w_up": PD((D, F), P(None, "model")),
            "w_down": PD((F, D), P("model", None)),
        }
        if cfg.mlp_gated:
            t["shared"]["w_gate"] = PD((D, F), P(None, "model"))
    return t


def capacity(n_tokens: int, cfg) -> int:
    c = int(math.ceil(n_tokens * cfg.moe_top_k * cfg.moe_capacity_factor / cfg.moe_experts))
    return max(8, -(-c // 8) * 8)  # pad to an MXU-friendly multiple


def _route(router_logits, cfg):
    """Top-k routing. Returns (expert_idx [T,k], weight [T,k], aux_loss)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)  # [T,E]
    w, idx = lax.top_k(probs, cfg.moe_top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss.
    E = cfg.moe_experts
    hot = jax.nn.one_hot(idx[:, 0], E)  # primary assignment
    frac_tokens = hot.mean(0)
    frac_probs = probs.mean(0)
    aux = (frac_tokens * frac_probs).sum() * E
    return idx, w.astype(jnp.float32), aux


def moe_forward(p, xn, cfg, pc: ParallelContext, *, pair: bool):
    """xn: [B,S,D] or [2,B,S,D]. Returns (partial_out [B,S,D], aux_loss).

    Partial: every rank contributes only its local experts' outputs (plus its
    shard of the shared expert); phase_out completes the combine.
    """
    if pair:
        out_a, aux_a = _moe_single(jax.tree.map(lambda x: x[0], p), xn[0], cfg, pc)
        out_b, aux_b = _moe_single(jax.tree.map(lambda x: x[1], p), xn[1], cfg, pc)
        return out_a + out_b, 0.5 * (aux_a + aux_b)
    return _moe_single(p, xn, cfg, pc)


def _moe_single(p, xn, cfg, pc: ParallelContext):
    B, S, D = xn.shape
    T = B * S
    E = cfg.moe_experts
    tp = pc.tp_size
    e_local = E // tp
    C = capacity(T, cfg)
    x = xn.reshape(T, D)

    idx, w, aux = _route(x @ p["router"].astype(x.dtype), cfg)  # [T,k]

    k = cfg.moe_top_k
    slot_expert = idx.reshape(-1)                     # [T*k]
    slot_weight = w.reshape(-1)
    slot_token = jnp.repeat(jnp.arange(T), k)

    # Position of each slot inside its expert's buffer (cumsum dispatch).
    onehot = jax.nn.one_hot(slot_expert, E, dtype=jnp.int32)        # [T*k,E]
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)[jnp.arange(T * k), slot_expert]
    keep = pos_in_expert < C

    # This rank owns experts [lo, lo + e_local).
    lo = pc.tp_index() * e_local
    local_e = slot_expert - lo
    mine = keep & (local_e >= 0) & (local_e < e_local)
    # Dropped/foreign slots write to a trash row via clamped indices + drop mode.
    le = jnp.where(mine, local_e, 0)
    pe = jnp.where(mine, pos_in_expert, C)  # C == out of range -> dropped

    # Chunked dispatch: the [T*k, D] gather is materialised CHUNK slots at a
    # time (32k-token prefill would otherwise stage multi-GB temporaries —
    # EXPERIMENTS.md §Perf iteration 3).
    n_slots = T * k
    CHUNK = 16384
    buf = jnp.zeros((e_local, C + 1, D), x.dtype)
    if n_slots <= CHUNK:
        buf = buf.at[le, pe].add(jnp.where(mine[:, None], x[slot_token], 0))
    else:
        pad = (-n_slots) % CHUNK
        le_c = jnp.pad(le, (0, pad)).reshape(-1, CHUNK)
        pe_c = jnp.pad(pe, (0, pad), constant_values=C).reshape(-1, CHUNK)
        st_c = jnp.pad(slot_token, (0, pad)).reshape(-1, CHUNK)
        mi_c = jnp.pad(mine, (0, pad)).reshape(-1, CHUNK)

        def disp(b, args):
            lec, pec, stc, mic = args
            return b.at[lec, pec].add(
                jnp.where(mic[:, None], x[stc], 0)), None

        buf, _ = lax.scan(disp, buf, (le_c, pe_c, st_c, mi_c))
    buf = buf[:, :C]

    act = _ACTS[cfg.mlp_act]
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    if cfg.mlp_gated:
        gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
        h = act(gate.astype(jnp.float32)).astype(up.dtype) * up
    else:
        h = act(up.astype(jnp.float32)).astype(up.dtype)
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(h.dtype))  # [e_local,C,D]

    # Combine: weighted scatter back to tokens (partial across ranks),
    # chunked like the dispatch.
    out = jnp.zeros((T, D), x.dtype)
    if n_slots <= CHUNK:
        gathered = eout[le, jnp.where(mine, pe, 0)]                 # [T*k,D]
        contrib = jnp.where(mine[:, None],
                            gathered * slot_weight[:, None].astype(gathered.dtype), 0)
        out = out.at[slot_token].add(contrib)
    else:
        pad = (-n_slots) % CHUNK
        w_c = jnp.pad(slot_weight, (0, pad)).reshape(-1, CHUNK)

        def comb(o, args):
            lec, pec, stc, mic, wc = args
            g = eout[lec, jnp.where(mic, pec, 0)]
            c = jnp.where(mic[:, None], g * wc[:, None].astype(g.dtype), 0)
            return o.at[stc].add(c), None

        out, _ = lax.scan(comb, out, (le_c, pe_c, st_c, mi_c, w_c))

    if cfg.moe_shared_expert:
        sp = p["shared"]
        sup = x @ sp["w_up"].astype(x.dtype)
        if cfg.mlp_gated:
            sg = x @ sp["w_gate"].astype(x.dtype)
            sh = act(sg.astype(jnp.float32)).astype(sup.dtype) * sup
        else:
            sh = act(sup.astype(jnp.float32)).astype(sup.dtype)
        out = out + sh @ sp["w_down"].astype(sh.dtype)  # TP-partial as usual

    return out.reshape(B, S, D), aux
