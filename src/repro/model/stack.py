"""Stack assembly: layers -> LP groups -> scan segments.

The layer list (with its LP pairing plan) is compressed into SEGMENTS of
identical group signature; each segment's params are stacked on a leading
axis and applied with ONE lax.scan, so HLO size (and compile time) is flat
in depth — granite's 88 layers lower as 2-3 scans.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig, LayerSpec
from repro.model import blocks as B
from repro.model.params import init_tree, pspec_tree, abstract_tree, stack_tmpl
from repro.parallel.context import ParallelContext


@dataclass(frozen=True)
class Segment:
    group: B.Group          # representative (layer_ids of the first group)
    count: int


# Dry-run knob: lax.scan hides its trip count from XLA cost analysis, so the
# roofline lowering unrolls the segment scans (exact FLOP/byte/collective
# accounting) while production keeps the compact scan form.
_SCAN_UNROLL = False


def set_scan_unroll(flag: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = bool(flag)


def template_compatible(cfg, a: LayerSpec, b: LayerSpec) -> bool:
    """Two specs can LP-pair iff their param templates are structurally equal."""
    ta = jax.tree.structure(B.layer_template(cfg, a, 1))
    tb = jax.tree.structure(B.layer_template(cfg, b, 1))
    return ta == tb and a.cross_attn == b.cross_attn and a.ffn == b.ffn


def make_groups(cfg: ArchConfig, lp_pairs: Sequence[Tuple[int, int]],
                specs: Optional[Sequence[LayerSpec]] = None) -> List[B.Group]:
    """Build the group list from an LP pairing plan (validated)."""
    specs = list(specs if specs is not None else cfg.layer_specs())
    n = len(specs)
    paired = {}
    seen = set()
    for (i, j) in lp_pairs:
        assert j == i + 1, f"LP pairs must be consecutive layers, got {(i, j)}"
        assert 0 <= i and j < n, (i, j, n)
        assert i not in seen and j not in seen, f"overlapping LP pairs at {(i, j)}"
        assert template_compatible(cfg, specs[i], specs[j]), (
            f"layers {i},{j} of {cfg.name} have incompatible templates")
        seen.update((i, j))
        paired[i] = j
    groups: List[B.Group] = []
    i = 0
    while i < n:
        if i in paired:
            groups.append(B.Group(True, (specs[i], specs[i + 1]), (i, i + 1)))
            i += 2
        else:
            groups.append(B.Group(False, (specs[i],), (i,)))
            i += 1
    return groups


def make_segments(groups: Sequence[B.Group]) -> List[Segment]:
    segs: List[Segment] = []
    for g in groups:
        if segs and segs[-1].group.signature == g.signature:
            segs[-1] = Segment(segs[-1].group, segs[-1].count + 1)
        else:
            segs.append(Segment(g, 1))
    return segs


def group_template(cfg, group: B.Group, tp: int):
    t = B.layer_template(cfg, group.specs[0], tp)
    return stack_tmpl(t, 2) if group.pair else t


def segment_template(cfg, seg: Segment, tp: int):
    gt = group_template(cfg, seg.group, tp)
    return stack_tmpl(gt, seg.count) if seg.count > 1 else gt


def stack_template(cfg, segments: Sequence[Segment], tp: int):
    return [segment_template(cfg, s, tp) for s in segments]


# ---------------------------------------------------------------------------
# Application
# ---------------------------------------------------------------------------

def apply_stack_full(seg_params, x, segments, *, cfg, dims, pc, positions,
                     prefix_len=0, enc_out=None, attn_impl="auto",
                     emit_cache=False, max_len=0, kv_mode="heads",
                     remat=False, scan_impl="chunked", gather_fns=None,
                     ctx=None, q0=0):
    """Run all segments over the full sequence.

    ``gather_fns`` (FSDP): one fn per segment mapping the scan-sliced flat
    shard tree -> full tp-local group params (repro.parallel.fsdp); its AD
    is the ZeRO-3 gradient reduce_scatter. Under remat the backward pass
    re-gathers instead of saving the full weights.

    ``ctx``/``q0`` (suffix prefill): cached context kv for absolute
    positions [0, q0) — one count-stacked tree per segment, same structure
    as the emitted caches but with the length axis trimmed to the context.
    The trees ride each segment's scan as xs alongside the params, so every
    group attends over its OWN layer's context.

    Returns (x, aux, caches) where caches is a list (one stacked tree per
    segment) when emit_cache else None.
    """
    caches = [] if emit_cache else None
    aux = jnp.float32(0.0)
    gather_fns = gather_fns or [None] * len(segments)
    ctx = ctx if ctx is not None else [None] * len(segments)
    for sp, seg, gather, ctx_seg in zip(seg_params, segments, gather_fns, ctx):
        def body(x, gp, ctx_g=None, _seg=seg, _gather=gather):
            if _gather is not None:
                gp = _gather(gp)
            return B.apply_group_full(
                gp, x, cfg=cfg, group=_seg.group, dims=dims, pc=pc,
                positions=positions, prefix_len=prefix_len, enc_out=enc_out,
                attn_impl=attn_impl, emit_cache=emit_cache, max_len=max_len,
                kv_mode=kv_mode, scan_impl=scan_impl, ctx_kv=ctx_g, q0=q0)

        if remat:
            body = jax.checkpoint(body)
        if seg.count == 1:
            sp1 = jax.tree.map(lambda v: v[0], sp) if gather is not None else sp
            ctx1 = (jax.tree.map(lambda v: v[0], ctx_seg)
                    if ctx_seg is not None else None)
            x, a, c = body(x, sp1, ctx1)
            aux = aux + a
            if emit_cache:
                caches.append(jax.tree.map(lambda v: v[None], c))
        elif ctx_seg is not None:
            def scan_body_ctx(carry, gp_ctx):
                x, aux = carry
                x, a, c = body(x, gp_ctx[0], gp_ctx[1])
                return (x, aux + a), c

            (x, aux), cs = lax.scan(scan_body_ctx, (x, aux), (sp, ctx_seg),
                                    unroll=seg.count if _SCAN_UNROLL else 1)
            if emit_cache:
                caches.append(cs)
        else:
            def scan_body(carry, gp):
                x, aux = carry
                x, a, c = body(x, gp)
                return (x, aux + a), c

            (x, aux), cs = lax.scan(scan_body, (x, aux), sp,
                                    unroll=seg.count if _SCAN_UNROLL else 1)
            if emit_cache:
                caches.append(cs)
    return x, aux, caches


def apply_stack_decode(seg_params, x, caches, t, segments, *, cfg, dims, pc,
                       kv_mode="heads", gather_fns=None, cache_layout="ring",
                       block_tables=None):
    """One decode step through all segments. caches: list of stacked trees.

    cache_layout="paged": attention cache entries are page pools indirected
    through ``block_tables`` and ``t`` is the per-slot position vector; the
    scan-over-count machinery is layout-agnostic (the pool rides in the
    carry exactly like the ring cache, so XLA still aliases the buffers).
    That layout-agnosticism is what makes the sharded paged engine free
    here: under shard_map each rank scans its LOCAL pool shard (kv heads
    cut over the model axis) with the same block tables, so the carry
    aliasing — decode holds ONE pool copy per rank — survives tp > 1.
    """
    new_caches = []
    gather_fns = gather_fns or [None] * len(segments)
    for sp, cache, seg, gather in zip(seg_params, caches, segments, gather_fns):
        def body(x, gp_and_cache, _seg=seg, _gather=gather):
            gp, c = gp_and_cache
            if _gather is not None:
                gp = _gather(gp)
            return B.apply_group_decode(gp, x, c, t, cfg=cfg, group=_seg.group,
                                        dims=dims, pc=pc, kv_mode=kv_mode,
                                        cache_layout=cache_layout,
                                        block_tables=block_tables)

        if seg.count == 1:
            c0 = jax.tree.map(lambda v: v[0], cache)
            sp1 = jax.tree.map(lambda v: v[0], sp) if gather is not None else sp
            x, nc = body(x, (sp1, c0))
            new_caches.append(jax.tree.map(lambda v: v[None], nc))
        else:
            # The stacked cache rides in the scan CARRY (updated in place by
            # dynamic_update_index) rather than as xs->ys, so XLA aliases
            # the buffers: decode holds ONE copy of the KV cache, not two.
            def scan_body(carry, gp_i):
                x, cache_all = carry
                gp, i = gp_i
                c = jax.tree.map(
                    lambda v: lax.dynamic_index_in_dim(v, i, 0, keepdims=False),
                    cache_all)
                x, nc = body(x, (gp, c))
                cache_all = jax.tree.map(
                    lambda v, n: lax.dynamic_update_index_in_dim(
                        v, n.astype(v.dtype), i, 0),
                    cache_all, nc)
                return (x, cache_all), None

            (x, ncs), _ = lax.scan(scan_body, (x, cache),
                                   (sp, jnp.arange(seg.count)),
                                   unroll=seg.count if _SCAN_UNROLL else 1)
            new_caches.append(ncs)
    return x, new_caches


def stack_cache_meta(cfg, segments, dims, *, batch, max_len, kv_mode,
                     enc_len=0, dtype=jnp.bfloat16):
    """(abstract, pspec) cache trees per segment, stacked to [count, ...]."""
    abss, pss = [], []
    for seg in segments:
        a, p = B.group_cache_meta(cfg, seg.group, dims, batch=batch,
                                  max_len=max_len, kv_mode=kv_mode,
                                  enc_len=enc_len, dtype=dtype)
        from jax.sharding import PartitionSpec as P
        a = jax.tree.map(lambda s: jax.ShapeDtypeStruct((seg.count, *s.shape), s.dtype), a)
        p = {k: P(None, *p[k]) for k in p}
        abss.append(a)
        pss.append(p)
    return abss, pss
