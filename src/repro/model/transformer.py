"""Full model assembly: embedding -> (encoder) -> LP-grouped stack -> head.

One ``ModelStructure`` describes everything static (config, LP plan, TP
degree, scan segments); the functional entry points are:

  loss_fn        — token cross-entropy for train_step
  forward_full   — logits over a full sequence (train fwd / prefill)
  prefill        — forward_full + KV/state cache emission
  decode_step    — one new token against the cache (serve_step)

All functions run identically on a single CPU device (pc=ParallelContext())
and inside shard_map over a 512-chip mesh — collectives degrade to identity
when the axis is absent (repro.parallel.context).

Family handling:
  encdec (whisper)  — encoder consumes precomputed frame embeddings (the
                      conv frontend is a stub per the assignment); the
                      decoder cross-attends to the encoder output.
  vlm (paligemma)   — precomputed SigLIP patch embeddings are prepended to
                      the token embeddings as a bidirectional prefix
                      (prefix-LM mask via cfg.prefix_len).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, LayerSpec
from repro.core.lp import EMPTY_PLAN, LPPlan
from repro.model import attention as A
from repro.model import blocks as B
from repro.model import embedding as E
from repro.model import stack as ST
from repro.model.norms import apply_norm
from repro.model.params import PD, abstract_tree, init_tree, pspec_tree
from repro.parallel.context import ParallelContext

PyTree = Any


# ---------------------------------------------------------------------------
# Structure
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelStructure:
    cfg: ArchConfig
    plan: LPPlan
    tp: int
    segments: Tuple[ST.Segment, ...]
    enc_segments: Tuple[ST.Segment, ...] = ()
    fsdp: bool = False        # ZeRO-3 flat segment params over "data"
    fsdp_data: int = 1        # size of the FSDP (intra-pod data) axis
    quant: bool = False       # int8 FSDP weight shards (serving only)

    @property
    def dims(self) -> A.AttnDims:
        return A.attn_dims(self.cfg, self.tp)

    @property
    def effective_depth(self) -> int:
        return self.plan.effective_depth(self.cfg.n_layers)


def build_structure(cfg: ArchConfig, *, plan: Optional[LPPlan] = None,
                    tp: int = 1, fsdp: bool = False,
                    fsdp_data: int = 1, quant: bool = False) -> ModelStructure:
    plan = plan or EMPTY_PLAN
    groups = ST.make_groups(cfg, plan.pairs)
    segments = tuple(ST.make_segments(groups))
    enc_segments: Tuple[ST.Segment, ...] = ()
    if cfg.enc_layers:
        enc_spec = LayerSpec(mixer="attn_bidir", ffn="mlp")
        enc_groups = ST.make_groups(cfg, (), specs=[enc_spec] * cfg.enc_layers)
        enc_segments = tuple(ST.make_segments(enc_groups))
    if quant:
        assert fsdp, "int8 weight shards require FSDP layout"
    return ModelStructure(cfg, plan, tp, segments, enc_segments, fsdp,
                          fsdp_data, quant)


def segment_metas(ms: ModelStructure):
    """FSDP flat-layout metadata per decoder segment."""
    from repro.parallel import fsdp as F
    return [F.segment_meta(ST.group_template(ms.cfg, seg.group, ms.tp),
                           seg.count, tp=ms.tp, data=ms.fsdp_data)
            for seg in ms.segments]


def model_template(ms: ModelStructure) -> Dict[str, Any]:
    cfg, tp = ms.cfg, ms.tp
    if ms.fsdp:
        from repro.parallel import fsdp as F
        seg_tmpl = [F.flat_segment_pds(meta, data=ms.fsdp_data, tp=tp)
                    for meta in segment_metas(ms)]
        if ms.quant:
            from repro.model.params import PD as _PD
            def q_pds(tree):
                qt = jax.tree.map(lambda pd: _PD(pd.shape, pd.pspec,
                                                 init="zeros",
                                                 dtype=jnp.int8), tree)
                st = jax.tree.map(lambda pd: _PD(
                    (*pd.shape[:-1], -(-pd.shape[-1] // F.QBLOCK)),
                    pd.pspec, init="zeros", dtype=jnp.float32), tree)
                return {"q": qt, "scale": st}
            seg_tmpl = [q_pds(t) for t in seg_tmpl]
    else:
        seg_tmpl = ST.stack_template(cfg, ms.segments, tp)
    t: Dict[str, Any] = {
        "embed": E.embed_template(cfg, tp),
        "segments": seg_tmpl,
        "final_norm": B._norm_tmpl(cfg),
    }
    if ms.enc_segments:
        t["enc_segments"] = ST.stack_template(cfg, ms.enc_segments, tp)
        t["enc_norm"] = B._norm_tmpl(cfg)
    return t


def init_params(ms: ModelStructure, key, dtype=jnp.float32) -> PyTree:
    if not ms.fsdp:
        return init_tree(model_template(ms), key, dtype)
    # FSDP: init the REGULAR template (correct fan-in scaling), then pack.
    from repro.parallel import fsdp as F
    reg = build_structure(ms.cfg, plan=ms.plan, tp=ms.tp)
    params = init_tree(model_template(reg), key, dtype)
    metas = segment_metas(ms)
    packed = []
    for sp, seg, meta in zip(params["segments"], ms.segments, metas):
        groups = ([jax.tree.map(lambda v: v[i], sp) for i in range(seg.count)]
                  if seg.count > 1 else [sp])
        flat = F.pack_segment(groups, meta, data=ms.fsdp_data,
                              tp=ms.tp, dtype=dtype)
        packed.append(F.quantize_segment(flat) if ms.quant else flat)
    params["segments"] = packed
    return params


def stack_params_and_gathers(params, ms: ModelStructure, pc: ParallelContext):
    """(segment param trees, gather_fns) for the stack apply. FSDP leaves
    arrive as the rank-local (count, 1, 1, chunk) view -> (count, chunk)."""
    if not ms.fsdp:
        return params["segments"], None
    from repro.parallel import fsdp as F
    metas = segment_metas(ms)
    segs = [jax.tree.map(lambda v: v.reshape(v.shape[0], v.shape[-1]), sp)
            for sp in params["segments"]]
    if ms.quant:
        gathers = [F.make_gather_fn_q(meta, pc) for meta in metas]
    else:
        gathers = [F.make_gather_fn(meta, pc) for meta in metas]
    return segs, gathers


def param_pspecs(ms: ModelStructure) -> PyTree:
    return pspec_tree(model_template(ms))


def abstract_params(ms: ModelStructure, dtype=jnp.bfloat16) -> PyTree:
    return abstract_tree(model_template(ms), dtype)


def param_count(ms: ModelStructure) -> int:
    leaves = jax.tree.leaves(abstract_params(ms))
    return sum(int(jnp.prod(jnp.array(l.shape))) for l in leaves)


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _embed(params, tokens, cfg, pc: ParallelContext, *, positions):
    """Token ids -> full [B,S,D] residual stream (one psum, vocab-parallel)."""
    x = E.embed_lookup(params["embed"], tokens, pc)
    x = pc.psum_tp(x)
    x = E.add_positions(params["embed"], x, positions)
    if cfg.norm_plus_one:  # gemma-style sqrt(D) embedding scale
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _head(params, x, cfg, pc: ParallelContext):
    """Final norm + column-parallel LM head -> LOCAL logits [..., V/tp]."""
    x = apply_norm(x, params["final_norm"], cfg)
    return E.local_logits(params["embed"], x, cfg, pc)


def _encoder(params, frames, ms: ModelStructure, pc: ParallelContext,
             *, attn_impl="auto"):
    """Whisper encoder on precomputed frame embeddings [B,T,D] (stub
    frontend). Runs without SP so the output is full-sequence on every rank
    (cross-attention projects K/V from it)."""
    enc_pc = pc.with_sp(False)
    pos = jnp.arange(frames.shape[1])[None, :]
    h, _, _ = ST.apply_stack_full(params["enc_segments"], frames,
                                  ms.enc_segments, cfg=ms.cfg, dims=ms.dims,
                                  pc=enc_pc, positions=pos, attn_impl=attn_impl)
    return apply_norm(h, params["enc_norm"], ms.cfg)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward_full(params, tokens, *, ms: ModelStructure, pc: ParallelContext,
                 prefix_embed=None, enc_frames=None, emit_cache=False,
                 max_len=0, kv_mode="heads", remat=False, attn_impl="auto",
                 scan_impl="chunked", cache_dtype=jnp.bfloat16,
                 ctx_kv=None, start=0):
    """tokens: [B, S_text] -> (local_logits [B, S_total, V/tp], aux, caches).

    prefix_embed (vlm): [B, P, D] patch embeddings prepended to the stream.
    enc_frames (encdec): [B, T, D] frame embeddings for the encoder.

    ctx_kv/start (suffix prefill — repro.serve prefix sharing): process
    ``tokens`` as the SUFFIX of a stream whose first ``start`` positions
    have cached kv in ``ctx_kv`` (one count-stacked tree per segment, the
    layer layout of the emitted caches). Every suffix row attends over
    exactly ``start + S`` keys — the reduction shape the full-prompt
    forward gives the same row, which keeps suffix prefill bit-identical
    to cold prefill. ``start`` may be a [B] array of PER-ROW context
    lengths (bucketed radix-hit prefill: each row's suffix begins at its
    own ctx length; requires the pinned-tile chunked ``attn_impl``).
    Attention-only; the emitted cache covers only the
    suffix (length ``max_len``, local 0 == absolute ``start``).
    """
    cfg = ms.cfg
    Bt, S_text = tokens.shape
    prefix_len = cfg.prefix_len if prefix_embed is not None else 0
    if ctx_kv is not None:
        assert prefix_len == 0 and enc_frames is None, \
            "suffix prefill does not compose with prefix-LM/encoder inputs"
    S = S_text + prefix_len
    if getattr(start, "ndim", 0) > 0:
        # Per-row suffix offsets (bucketed radix-hit prefill): row i's
        # suffix begins at its own ctx length. A bare broadcast would
        # mis-align [B] against the length axis, so shape it explicitly.
        positions = start[:, None] + jnp.arange(S)[None, :]
    else:
        positions = start + jnp.arange(S)[None, :]

    x = _embed(params, tokens, cfg, pc,
               positions=positions[:, prefix_len:])
    if prefix_embed is not None:
        x = jnp.concatenate([prefix_embed.astype(x.dtype), x], axis=1)

    enc_out = None
    if enc_frames is not None:
        enc_out = _encoder(params, enc_frames, ms, pc, attn_impl=attn_impl)

    x = pc.shard_seq(x)
    seg_params, gather_fns = stack_params_and_gathers(params, ms, pc)
    x, aux, caches = ST.apply_stack_full(
        seg_params, x, ms.segments, cfg=cfg, dims=ms.dims, pc=pc,
        positions=positions, prefix_len=prefix_len, enc_out=enc_out,
        attn_impl=attn_impl, emit_cache=emit_cache,
        max_len=max_len or S, kv_mode=kv_mode, remat=remat,
        scan_impl=scan_impl, gather_fns=gather_fns, ctx=ctx_kv, q0=start)
    x = pc.phase_in(x)  # SP: re-gather the sequence before the LM head
    logits = _head(params, x, cfg, pc)
    return logits, aux, caches


def loss_fn(params, batch, *, ms: ModelStructure, pc: ParallelContext,
            remat=False, attn_impl="auto", scan_impl="chunked",
            aux_weight=1e-2):
    """Mean next-token cross-entropy (+ MoE load-balance aux).

    batch: {"tokens": [B,S], "labels": [B,S]} plus optional "prefix"/"frames".
    labels < 0 are masked out. Loss is averaged over the DP axes by the
    caller's pmean on gradients (each rank computes its local-batch mean).
    """
    logits, aux, _ = forward_full(
        params, batch["tokens"], ms=ms, pc=pc,
        prefix_embed=batch.get("prefix"), enc_frames=batch.get("frames"),
        remat=remat, attn_impl=attn_impl, scan_impl=scan_impl)
    labels = batch["labels"]
    prefix_len = ms.cfg.prefix_len if batch.get("prefix") is not None else 0
    if prefix_len:
        logits = logits[:, prefix_len:]
    mask = (labels >= 0).astype(jnp.float32)
    xent = E.vocab_parallel_xent(logits, jnp.maximum(labels, 0), pc, mask=mask)
    return xent + aux_weight * aux, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill + decode (serving)
# ---------------------------------------------------------------------------

def cache_meta(ms: ModelStructure, *, batch: int, max_len: int,
               kv_mode="heads", dtype=jnp.bfloat16):
    """(abstract, pspec) trees for the decode cache (per segment)."""
    return ST.stack_cache_meta(ms.cfg, ms.segments, ms.dims, batch=batch,
                               max_len=max_len, kv_mode=kv_mode,
                               enc_len=ms.cfg.enc_seq if ms.enc_segments else 0,
                               dtype=dtype)


def cache_batch_axis(entry_name: str) -> int:
    """Axis of the BATCH dim in a count-stacked cache entry [count, ...].

    Stacked pair entries (bare names "k", "xv", "conv", ... — see
    blocks.group_cache_meta) carry a leading pair axis of 2, so batch sits
    at axis 2; per-layer entries ("k0", "conv1", ...) keep it at axis 1.
    """
    return 1 if entry_name[-1].isdigit() else 2


def prefill(params, tokens, *, ms: ModelStructure, pc: ParallelContext,
            max_len: int, prefix_embed=None, enc_frames=None,
            kv_mode="heads", attn_impl="auto", cache_dtype=jnp.bfloat16):
    """Returns (last-position local logits [B, V/tp], caches)."""
    logits, _, caches = forward_full(
        params, tokens, ms=ms, pc=pc, prefix_embed=prefix_embed,
        enc_frames=enc_frames, emit_cache=True, max_len=max_len,
        kv_mode=kv_mode, attn_impl=attn_impl, cache_dtype=cache_dtype)
    caches = jax.tree.map(lambda c: c.astype(cache_dtype)
                          if c.dtype in (jnp.float32, jnp.bfloat16) else c,
                          caches)
    return logits[:, -1], caches


def decode_step(params, tok, caches, t, *, ms: ModelStructure,
                pc: ParallelContext, kv_mode="heads", cache_layout="ring",
                block_tables=None):
    """One decode step. tok: [B] int32 ids; t: scalar absolute position of
    ``tok`` in the stream. Returns (local logits [B, V/tp], new caches).

    cache_layout="paged" (continuous batching — repro.serve): ``t`` is a
    [B] int32 VECTOR of per-slot positions, ``caches`` is the paged pool
    tree (serve.paged_cache) and ``block_tables`` [B, n_pg] carries the
    slot -> page indirection. The ring path is untouched. The same body
    runs inside shard_map on a tp > 1 mesh: tok/t/block_tables arrive
    replicated (host-side scheduling is tp-agnostic) and only the pool's
    kv-head axis is sharded (serve.engine.make_sharded_serve_step).
    """
    cfg = ms.cfg
    dpc = pc.with_sp(False)  # decode never uses sequence parallelism
    if cache_layout == "paged":
        assert block_tables is not None
        t = jnp.asarray(t, jnp.int32)
        assert t.ndim == 1, f"paged decode takes per-slot positions, got {t.shape}"
        pos = t[:, None]          # per-slot positions for embed/rope
    else:
        pos = jnp.full((tok.shape[0], 1), t, jnp.int32)
    x = _embed(params, tok[:, None], cfg, dpc, positions=pos)
    seg_params, gather_fns = stack_params_and_gathers(params, ms, dpc)
    x, new_caches = ST.apply_stack_decode(
        seg_params, x, caches, t, ms.segments, cfg=cfg, dims=ms.dims,
        pc=dpc, kv_mode=kv_mode, gather_fns=gather_fns,
        cache_layout=cache_layout, block_tables=block_tables)
    logits = _head(params, x, cfg, dpc)
    return logits[:, 0], new_caches
