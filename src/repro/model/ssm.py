"""Mamba-1 selective SSM mixer (falcon-mamba) with tensor parallelism.

TP: d_inner is sharded over ``model`` (in_proj column-split, depthwise conv
and the per-channel selective scan are local, out_proj row-split). The only
mid-block sync is the tiny x_proj psum ([B,S,dt_rank+2N]); for an LP pair
both paths' x_proj partials are stacked and psum'd ONCE, and the pair's
out_proj partials sum into the single phase-exit reduction — the paper's
halving applies to attention-free layers too.

Internally everything carries a leading path axis P (1 = single layer,
2 = LP pair) so single and pair share one code path.

Scan impls: "seq" (lax.scan oracle), "chunked" (intra-chunk associative scan,
sequential across chunks — the XLA stand-in for the Pallas kernel in
repro.kernels.ssm_scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.model.params import PD
from repro.parallel.context import ParallelContext


def ssm_template(cfg, tp: int):
    D = cfg.d_model
    di = cfg.d_inner
    assert di % tp == 0
    R, N, K = cfg.dt_rank, cfg.ssm_state, cfg.ssm_conv
    return {
        "w_in": PD((D, 2 * di), P(None, "model")),          # [x; z]
        "conv_w": PD((K, di), P(None, "model"), init="normal", fan_in=K),
        "conv_b": PD((di,), P("model"), init="zeros"),
        "w_x": PD((di, R + 2 * N), P("model", None)),        # row-parallel
        "w_dt": PD((R, di), P(None, "model")),
        "dt_bias": PD((di,), P("model"), init="zeros"),
        "A_log": PD((di, N), P("model", None), init="zeros"),
        "D": PD((di,), P("model"), init="ones"),
        "w_out": PD((di, D), P("model", None)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: [P,B,S,C]; w: [P,K,C]."""
    K = w.shape[1]
    out = b[:, None, None, :].astype(jnp.float32)
    for j in range(K):
        shift = K - 1 - j
        xs = jnp.pad(x, ((0, 0), (0, 0), (shift, 0), (0, 0)))[:, :, : x.shape[2], :]
        out = out + xs.astype(jnp.float32) * w[:, j][:, None, None, :].astype(jnp.float32)
    return out.astype(x.dtype)


def _scan_seq(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t over axis 2. a,b: [P,B,S,C,N]."""

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    aT = jnp.moveaxis(a, 2, 0)
    bT = jnp.moveaxis(b, 2, 0)
    hT, ys = lax.scan(step, h0, (aT, bT))
    return jnp.moveaxis(ys, 0, 2), hT


def _scan_chunked(a, b, h0, chunk: int):
    S = a.shape[2]
    if S <= chunk:
        cum = lax.associative_scan(_compose, (a, b), axis=2)
        y = cum[1] + cum[0] * h0[:, :, None]
        return y, y[:, :, -1]
    assert S % chunk == 0
    nc = S // chunk
    ar = jnp.moveaxis(a.reshape(a.shape[0], a.shape[1], nc, chunk, *a.shape[3:]), 2, 0)
    br = jnp.moveaxis(b.reshape(b.shape[0], b.shape[1], nc, chunk, *b.shape[3:]), 2, 0)

    def step(h, ab):
        ac, bc = ab  # [P,B,chunk,C,N]
        cum = lax.associative_scan(_compose, (ac, bc), axis=2)
        y = cum[1] + cum[0] * h[:, :, None]
        return y[:, :, -1], y

    hT, ys = lax.scan(step, h0, (ar, br))  # ys: [nc,P,B,chunk,C,N]
    y = jnp.moveaxis(ys, 0, 2).reshape(a.shape)
    return y, hT


def _compose(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def ssm_mix(p, xn, cfg, pc: ParallelContext, *, impl="chunked", chunk=256,
            state=None):
    """xn: [P,B,S,D] per-path normalised inputs. Returns (partial [B,S,D],
    new_state) where state = (conv_state [P,B,K-1,di], h [P,B,di,N]).
    When ``state`` is given, runs in stateful (decode) mode."""
    Pp, B, S, D = xn.shape
    N, K = cfg.ssm_state, cfg.ssm_conv
    w_in = p["w_in"]
    di = w_in.shape[-1] // 2

    xz = jnp.einsum("pbsd,pde->pbse", xn, w_in.astype(xn.dtype))
    xin, z = xz[..., :di], xz[..., di:]

    if state is not None:
        conv_prev, h_prev = state
        xcat = jnp.concatenate([conv_prev.astype(xin.dtype), xin], axis=2)
        new_conv = xcat[:, :, -(K - 1):, :]
        xc = _causal_conv(xcat, p["conv_w"], p["conv_b"])[:, :, -S:, :]
    else:
        xc = _causal_conv(xin, p["conv_w"], p["conv_b"])
        new_conv = xin[:, :, -(K - 1):, :] if S >= K - 1 else jnp.pad(
            xin, ((0, 0), (0, 0), (K - 1 - S, 0), (0, 0)))
        h_prev = jnp.zeros((Pp, B, di, N), jnp.float32)
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(xc.dtype)

    # x_proj: row-parallel -> ONE stacked psum for all paths.
    bcd_part = jnp.einsum("pbsc,pce->pbse", xc, p["w_x"].astype(xc.dtype))
    bcd = pc.psum_tp(bcd_part.astype(jnp.float32))
    R = cfg.dt_rank
    dt_raw, Bt, Ct = bcd[..., :R], bcd[..., R:R + N], bcd[..., R + N:]

    dt = jax.nn.softplus(
        jnp.einsum("pbsr,prc->pbsc", dt_raw, p["w_dt"].astype(jnp.float32))
        + p["dt_bias"][:, None, None, :].astype(jnp.float32))          # [P,B,S,di]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                        # [P,di,N]
    a = jnp.exp(dt[..., None] * A[:, None, None])                       # [P,B,S,di,N]
    b = (dt * xc.astype(jnp.float32))[..., None] * Bt[..., None, :]     # [P,B,S,di,N]

    if state is not None or impl == "seq":
        y, hT = _scan_seq(a, b, h_prev)
    elif impl == "pallas":
        from repro.kernels import ops as KOPS
        Pp_, B_, S_, C_, N_ = a.shape
        y2, h2 = KOPS.ssm_scan(a.reshape(Pp_ * B_, S_, C_, N_),
                               b.reshape(Pp_ * B_, S_, C_, N_),
                               h_prev.reshape(Pp_ * B_, C_, N_))
        y = y2.reshape(Pp_, B_, S_, C_, N_)
        hT = h2.reshape(Pp_, B_, C_, N_)
    else:
        y, hT = _scan_chunked(a, b, h_prev, chunk)

    yout = (y * Ct[..., None, :]).sum(-1) + p["D"][:, None, None, :].astype(jnp.float32) * xc.astype(jnp.float32)
    yout = yout * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("pbsc,pcd->bsd", yout.astype(xn.dtype), p["w_out"].astype(xn.dtype))
    return out, (new_conv, hT)
