"""Serve telemetry: span legality, histograms, exporters, determinism.

The registry is pure host bookkeeping, so observing a run may never change
it (on/off bit-identity), and because every record is step-denominated the
whole event stream of a seeded chaos run must replay byte-identically once
wall-clock annotations are stripped. Spans are driven by the ENGINE through
a validating state machine — an illegal transition is engine corruption and
raises, it is never recorded.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.model import transformer as T
from repro.serve import (FINISHED, FaultPlan, Histogram, PagedEngine,
                         PagedServeConfig, RequestSpan, SpanStateError,
                         Telemetry, dumps_trace, strip_wall, validate_trace)
from repro.serve.telemetry import (ADMITTED, DECODE, PREEMPTED, PREFILL,
                                   QUEUED, SPAN_TERMINAL, SUBMITTED)

from _helpers import tiny

KEY = jax.random.PRNGKey(0)


def _build(n_layers=2):
    cfg = tiny(n_layers=n_layers)
    ms = T.build_structure(cfg, tp=1)
    return cfg, ms, T.init_params(ms, KEY)


def _psv(**kw):
    base = dict(n_slots=2, page_size=8, n_pages=9, max_len=32,
                cache_dtype=jnp.float32)
    base.update(kw)
    return PagedServeConfig(**base)


def _prompt(i, length, vocab):
    return np.asarray(jax.random.randint(jax.random.fold_in(KEY, i),
                                         (length,), 0, vocab))


# ---------------------------------------------------------------------------
# Span state machine
# ---------------------------------------------------------------------------

def test_span_legal_lifecycle_with_preemption_cycle():
    s = RequestSpan(rid=7)
    s.transition(SUBMITTED, 0, prompt_len=8)
    s.transition(QUEUED, 0)
    s.transition(ADMITTED, 1, slot=0, cohort="main")
    s.transition(PREFILL, 1, kind="full", hit_tokens=0, tokens=8)
    s.transition(DECODE, 1)
    s.transition(PREEMPTED, 3, slot=0)
    s.transition(QUEUED, 3)
    s.transition(ADMITTED, 5, slot=1, cohort="main")
    s.transition(DECODE, 5)
    s.transition(FINISHED, 9, n_out=4)
    assert s.state == FINISHED and s.state in SPAN_TERMINAL
    assert s.submit_step == 0 and s.terminal_step == 9
    assert s.cohort == "main"
    assert [e.step for e in s.events_of(ADMITTED)] == [1, 5]
    assert s.events_of(PREFILL)[0].attrs["kind"] == "full"


def test_span_rejects_decode_before_admission():
    s = RequestSpan(rid=1)
    s.transition(SUBMITTED, 0)
    s.transition(QUEUED, 0)
    with pytest.raises(SpanStateError, match="queued -> decode"):
        s.transition(DECODE, 1)


def test_span_terminal_states_are_absorbing():
    s = RequestSpan(rid=2)
    for state, step in ((SUBMITTED, 0), (QUEUED, 0), (ADMITTED, 1),
                        (DECODE, 1), (FINISHED, 4)):
        s.transition(state, step)
    with pytest.raises(SpanStateError, match="finished ->"):
        s.transition(QUEUED, 5)


def test_span_must_open_with_submitted_and_requeue_after_preempt():
    with pytest.raises(SpanStateError, match="must open"):
        RequestSpan(rid=3).transition(QUEUED, 0)
    s = RequestSpan(rid=4)
    for state, step in ((SUBMITTED, 0), (QUEUED, 0), (ADMITTED, 1),
                        (DECODE, 1), (PREEMPTED, 2)):
        s.transition(state, step)
    with pytest.raises(SpanStateError, match="preempted -> admitted"):
        s.transition(ADMITTED, 3)       # must pass through QUEUED first


# ---------------------------------------------------------------------------
# Histogram: Prometheus le (upper-inclusive) bucket semantics
# ---------------------------------------------------------------------------

def test_histogram_bucket_edges_are_upper_inclusive():
    h = Histogram(edges=(1, 2, 4, 8))
    for v in (0, 1):            # both <= 1 -> first bucket
        h.observe(v)
    h.observe(2)                # == edge -> bucket of that edge
    h.observe(3)                # 2 < v <= 4
    h.observe(8)                # == last finite edge
    h.observe(9)                # overflow -> +Inf bucket
    assert h.counts == [2, 1, 1, 1, 1]
    assert sum(h.counts) == h.count == 6
    assert h.sum == 23.0
    d = h.as_dict()
    assert d["edges"] == [1, 2, 4, 8] and len(d["counts"]) == 5


def test_histogram_percentile_reports_bucket_upper_edge():
    h = Histogram(edges=(1, 2, 4, 8))
    for v in (1, 1, 2, 4, 100):
        h.observe(v)
    assert h.percentile(50) == 2.0
    assert h.percentile(100) == 8.0     # overflow reports last finite edge
    assert Histogram(edges=(1,)).percentile(50) == 0.0   # empty


# ---------------------------------------------------------------------------
# Registry behavior
# ---------------------------------------------------------------------------

def test_disabled_telemetry_keeps_scalars_drops_growing_channels():
    tel = Telemetry(enabled=False)
    tel.inc("decoded", 3)
    tel.compile_event("main", "decode", 2)
    tel.fault(4, "nan_logits", rid=1, slot=0)
    tel.observe("e2e_steps", 5)
    tel.gauge("queue_depth", 1, 2)
    tel.span_event(0, SUBMITTED, 0)
    tel.mark_step(1)
    # Scalars live (the engine's stats deltas and chaos gates read them)…
    assert tel.counters["decoded"] == 3
    assert tel.compiles == {("main", "decode", 2): 1}
    assert tel.fault_counts == {"nan_logits": 1} and len(tel.fault_log) == 1
    assert tel.hists["e2e_steps"].count == 1
    assert tel.gauge_last["queue_depth"] == 2
    # …growing channels dropped.
    assert not tel.spans and not tel.gauge_series and not tel.step_wall


def test_reset_zeros_in_place_keeping_key_sets():
    tel = Telemetry()
    tel.seed_counters(["decoded", "finished"])
    tel.inc("decoded", 5)
    tel.fault(1, "nan_logits")
    tel.span_event(0, SUBMITTED, 0)
    tel.gauge("queue_depth", 0, 1)
    tel.reset()
    assert tel.counters == {"decoded": 0, "finished": 0}
    assert tel.fault_counts == {"nan_logits": 0} and not tel.fault_log
    assert not tel.spans and not tel.gauge_series and not tel.hists


# ---------------------------------------------------------------------------
# Engine-driven telemetry
# ---------------------------------------------------------------------------

def test_engine_spans_snapshot_and_trace():
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv())
    rids = [eng.add_request(_prompt(i, 8, cfg.vocab_size), 4)
            for i in range(3)]        # 3 requests, 2 slots -> staggered
    eng.drain()
    for rid in rids:
        span = eng.telemetry.span(rid)
        assert span.state == FINISHED
        assert span.first_token_step >= span.events_of(ADMITTED)[0].step
        assert span.events_of(PREFILL)[0].attrs["kind"] == "full"
    snap = eng.metrics_snapshot()
    assert snap["requests"] == {"finished": 3}
    assert snap["counters"]["submitted"] == snap["counters"]["finished"] == 3
    assert snap["counters"]["decoded"] == 9          # 3 x (4 - 1 prefill tok)
    assert snap["histograms"]["e2e_steps"]["count"] == 3
    assert "serve_finished_total 3" in eng.metrics_text()
    trace = json.loads(dumps_trace(eng.telemetry, n_slots=2))
    validate_trace(trace)
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"queue_depth", "pages_live"} <= names
    cats = {e.get("cat") for e in trace["traceEvents"]}
    assert {"slot", "request", "lifecycle"} <= cats


def test_compile_counter_pins_prefill_compiles_to_bucket_ladder():
    # Bucketed prefill bounds compile count by the LADDER length, not by
    # distinct arrival lengths: lengths (8, 16, 8, 16, 8) land on rungs 8
    # and 16 of the auto ladder (8, 16, 32), each compiled exactly once
    # at the program's fixed row count. (Pre-bucketing this test pinned
    # one "prefill_full" program per distinct length.)
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv())
    assert eng._buckets == (8, 16, 32)
    for i, L in enumerate((8, 16, 8, 16, 8)):   # two DISTINCT lengths
        eng.add_request(_prompt(i, L, cfg.vocab_size), 2)
    eng.drain()
    prefills = {k: n for k, n in eng.telemetry.compiles.items()
                if k[1] == "prefill_bucket"}
    assert set(prefills) == {("main", "prefill_bucket", (8, 2)),
                             ("main", "prefill_bucket", (16, 2))}
    assert all(n == 1 for n in prefills.values())
    assert len(prefills) <= len(eng._buckets)
    assert not any(k[1] == "prefill_full"
                   for k in eng.telemetry.compiles)
    assert eng.telemetry.compiles[("main", "decode", 2)] == 1


def test_telemetry_on_off_runs_are_bit_identical():
    cfg, ms, params = _build()
    prompts = [(_prompt(i, 8, cfg.vocab_size), 4) for i in range(3)]
    engines = [PagedEngine(params, ms, _psv(telemetry=on))
               for on in (True, False)]
    for eng in engines:
        for p, n in prompts:
            eng.add_request(p, n)
        eng.drain()
    on, off = engines
    assert sorted(on.results) == sorted(off.results)
    for rid in on.results:
        assert (on.results[rid] == off.results[rid]).all(), rid
    assert dict(on.counters) == dict(off.counters)
    assert on.telemetry.compiles == off.telemetry.compiles
    assert on.telemetry.spans and not off.telemetry.spans


def test_same_seed_chaos_traces_are_byte_identical():
    cfg, ms, params = _build()
    prompts = [(_prompt(i, 8, cfg.vocab_size), 4) for i in range(4)]

    def soak():
        eng = PagedEngine(params, ms, _psv(),
                          fault_plan=FaultPlan(0, n_steps=12, per_kind=1))
        for p, n in prompts:
            eng.add_request(p, n)
        while eng.sched.n_queued or eng.sched.n_running:
            eng.step()
            assert eng.step_count < 100
        return eng

    a, b = soak(), soak()
    assert a.fault_log == b.fault_log and a.fault_log
    ta = dumps_trace(a.telemetry, n_slots=2, wall=False)
    assert ta == dumps_trace(b.telemetry, n_slots=2, wall=False)
    # wall fields exist with wall=True and strip_wall removes every one.
    doc = json.loads(dumps_trace(a.telemetry, n_slots=2, wall=True))

    def has_wall(o):
        if isinstance(o, dict):
            return any(k.startswith("wall") or has_wall(v)
                       for k, v in o.items())
        return isinstance(o, list) and any(has_wall(v) for v in o)

    assert has_wall(doc) and not has_wall(strip_wall(doc))
