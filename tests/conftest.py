import os
import sys

# Tests see ONE device (the dry-run sets its own 512-device flag in a
# separate process). Subprocess-based multi-device tests set XLA_FLAGS
# explicitly in their child environment.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
