"""Request lifecycle hardening: typed terminal states, cancel(), deadlines,
drain semantics, and actionable configuration/submit validation.

Every per-request failure path must land the request in a TYPED terminal
state (failed / cancelled / expired) carrying a ServeError, release its
slot and pages within one step, and leave every cohabiting request's
output bit-identical to an undisturbed run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (CANCELLED, EXPIRED, FINISHED, QUEUED,
                         DeadlineExceededError, InvalidRequestError,
                         PagedEngine, PagedServeConfig, PagePool, Scheduler,
                         ServeConfig, ServeError, generate)

from _helpers import tiny

PC = ParallelContext()
KEY = jax.random.PRNGKey(0)


def _build(n_layers=2, plan=None):
    cfg = tiny(n_layers=n_layers)
    ms = T.build_structure(cfg, plan=plan, tp=1)
    return cfg, ms, T.init_params(ms, KEY)


def _psv(**kw):
    base = dict(n_slots=2, page_size=8, n_pages=9, max_len=32,
                cache_dtype=jnp.float32)
    base.update(kw)
    return PagedServeConfig(**base)


def _one_shot(params, ms, prompt, n_new):
    sv = ServeConfig(max_len=32, temperature=0.0, cache_dtype=jnp.float32)
    return np.asarray(generate(params, jnp.asarray(prompt)[None], n_new,
                               ms=ms, pc=PC, sv=sv)[0])


# ---------------------------------------------------------------------------
# Constructor validation: every geometry mistake is an actionable ValueError
# ---------------------------------------------------------------------------

def test_init_rejects_unaligned_max_len():
    _, ms, params = _build()
    with pytest.raises(ValueError, match="not a multiple of"):
        PagedEngine(params, ms, _psv(max_len=20, page_size=8))


def test_init_rejects_empty_slot_count():
    _, ms, params = _build()
    with pytest.raises(ValueError, match="n_slots=0 must be >= 1"):
        PagedEngine(params, ms, _psv(n_slots=0))


def test_init_rejects_negative_max_queue():
    _, ms, params = _build()
    with pytest.raises(ValueError, match="max_queue=-1 must be >= 0"):
        PagedEngine(params, ms, _psv(max_queue=-1))


def test_init_rejects_degrade_slots_without_degrade_delta():
    _, ms, params = _build()
    with pytest.raises(ValueError, match="without degrade_delta"):
        PagedEngine(params, ms, _psv(degrade_slots=1))


@pytest.mark.parametrize("slots", [0, 2])
def test_init_rejects_degrade_slots_out_of_range(slots):
    # The degraded cohort must leave >= 1 main slot and hold >= 1 slot.
    _, ms, params = _build()
    with pytest.raises(ValueError, match="1 <= degrade_slots < n_slots"):
        PagedEngine(params, ms,
                    _psv(degrade_delta=True, degrade_slots=slots))


def test_init_rejects_degrade_plan_no_deeper_than_base():
    # Base already maximally paired: the "degraded" cohort would run the
    # SAME depth — a config bug, not a capacity knob.
    cfg = tiny(n_layers=2)
    _, ms, params = _build(plan=plan_range(cfg, 0, 2))
    with pytest.raises(ValueError, match="degraded plan pairs"):
        PagedEngine(params, ms,
                    _psv(n_slots=3, degrade_delta=True, degrade_slots=1))


# ---------------------------------------------------------------------------
# Submit validation: malformed work fails AT THE BOUNDARY, typed, pre-queue
# ---------------------------------------------------------------------------

def _sched(n_slots=2, n_pages=9):
    return Scheduler(n_slots=n_slots, pool=PagePool(n_pages), page_size=8,
                     max_len=32)


def test_submit_rejects_empty_prompt():
    s = _sched()
    with pytest.raises(InvalidRequestError, match="empty prompt"):
        s.submit(np.zeros(0, np.int32), 4)
    assert s.n_queued == 0


def test_submit_rejects_non_integer_prompt():
    s = _sched()
    with pytest.raises(InvalidRequestError, match="not an integer type"):
        s.submit(np.zeros(4, np.float32), 4)
    assert s.n_queued == 0


def test_submit_rejects_non_positive_max_new():
    s = _sched()
    with pytest.raises(InvalidRequestError, match="max_new=0 must be >= 1"):
        s.submit(np.zeros(4, np.int32), 0)
    assert s.n_queued == 0


def test_submit_rejects_over_length_request():
    s = _sched()
    with pytest.raises(InvalidRequestError, match="positions > max_len"):
        s.submit(np.zeros(30, np.int32), 4)
    assert s.n_queued == 0


def test_submit_rejects_request_larger_than_pool():
    # 17 positions -> 3 pages > the 2-page pool: could never be admitted.
    s = _sched(n_pages=3)
    with pytest.raises(InvalidRequestError, match="pool capacity"):
        s.submit(np.zeros(10, np.int32), 7)
    assert s.n_queued == 0


def test_submit_errors_are_value_errors():
    # Back-compat: callers that caught ValueError keep working.
    s = _sched()
    with pytest.raises(ValueError):
        s.submit(np.zeros(0, np.int32), 4)
    assert issubclass(InvalidRequestError, ServeError)


def test_add_request_rejects_out_of_vocab_tokens():
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv())
    bad = np.array([0, cfg.vocab_size + 7], np.int32)
    with pytest.raises(InvalidRequestError, match="outside \\[0,"):
        eng.add_request(bad, 4)
    assert eng.sched.n_queued == 0


# ---------------------------------------------------------------------------
# Terminal transitions: cancel / expire release everything within one step
# ---------------------------------------------------------------------------

def test_cancel_queued_and_running():
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv())
    key = jax.random.PRNGKey(7)
    pr = [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (8,),
                                        0, cfg.vocab_size)) for i in range(3)]
    r0, r1 = eng.add_request(pr[0], 8), eng.add_request(pr[1], 8)
    r2 = eng.add_request(pr[2], 8)            # 2 slots -> r2 queues
    eng.step()
    assert eng.request(r2).state == QUEUED

    # Cancel the queued request: no pages were ever held.
    assert eng.cancel(r2) is True
    assert eng.request(r2).state == CANCELLED
    assert len(eng.results[r2]) == 0

    # Cancel a running request: slot + pages released immediately.
    live_before = eng.pool.live
    assert eng.cancel(r1) is True
    assert eng.request(r1).state == CANCELLED
    assert eng.pool.live < live_before
    eng.pool.check_balance()
    assert eng.cancel(r1) is False            # already terminal: no-op

    res = eng.drain()
    assert eng.request(r0).state == FINISHED
    assert (res[r0] == _one_shot(params, ms, pr[0], 8)).all()
    assert eng.counters["cancelled"] == 2
    assert eng.pool.live == 0


def test_running_request_expires_at_deadline_and_releases():
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv())
    prompt = np.asarray(jax.random.randint(KEY, (8,), 0, cfg.vocab_size))
    rid = eng.add_request(prompt, 16, deadline=2)
    eng.step()                                # admitted, decoding
    assert eng.pool.live > 0
    while eng.request(rid).state not in (EXPIRED, FINISHED):
        eng.step()
    r = eng.request(rid)
    assert r.state == EXPIRED
    assert isinstance(r.error, DeadlineExceededError)
    assert r.finished_step <= r.deadline + 1  # released within one step
    assert eng.pool.live == 0
    eng.pool.check_balance()
    assert eng.counters["expired"] == 1
    # The partial stream it DID produce is the true greedy prefix.
    ref = _one_shot(params, ms, prompt, 16)
    assert (eng.results[rid] == ref[:len(eng.results[rid])]).all()


def test_queued_request_expiry_leaves_survivor_bit_identical():
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv(n_slots=1, n_pages=5))
    key = jax.random.PRNGKey(9)
    pa = np.asarray(jax.random.randint(jax.random.fold_in(key, 0), (8,),
                                       0, cfg.vocab_size))
    pb = np.asarray(jax.random.randint(jax.random.fold_in(key, 1), (8,),
                                       0, cfg.vocab_size))
    ra = eng.add_request(pa, 12)
    rb = eng.add_request(pb, 12, deadline=3)  # 1 slot: expires in queue
    res = eng.drain()
    assert eng.request(rb).state == EXPIRED
    assert len(res[rb]) == 0
    assert eng.request(ra).state == FINISHED
    assert (res[ra] == _one_shot(params, ms, pa, 12)).all()
    assert eng.pool.live == 0


def test_drain_reports_per_request_terminal_status():
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv())
    key = jax.random.PRNGKey(11)
    pr = [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (8,),
                                        0, cfg.vocab_size)) for i in range(3)]
    r0 = eng.add_request(pr[0], 8)
    r1 = eng.add_request(pr[1], 8)
    r2 = eng.add_request(pr[2], 8, deadline=1)
    eng.step()
    eng.cancel(r1)
    res = eng.drain()                         # must not hang on the victims
    states = {r0: FINISHED, r1: CANCELLED, r2: EXPIRED}
    for rid, want in states.items():
        assert eng.request(rid).state == want, rid
        assert rid in res                     # victims keep partial output
    assert (res[r0] == _one_shot(params, ms, pr[0], 8)).all()
    assert eng.pool.live == 0
