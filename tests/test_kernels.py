"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (per the per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# dual rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d", [(8, 64), (50, 96), (130, 256), (1, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("plus_one", [False, True])
def test_dual_rmsnorm(m, d, dtype, plus_one):
    x = jax.random.normal(KEY, (m, d), dtype)
    sa = jax.random.normal(jax.random.fold_in(KEY, 1), (d,), jnp.float32)
    sb = jax.random.normal(jax.random.fold_in(KEY, 2), (d,), jnp.float32)
    ya, yb = ops.dual_rmsnorm(x, sa, sb, plus_one=plus_one, block_m=32)
    ra, rb = ref.dual_rmsnorm_ref(x, sa, sb, plus_one=plus_one)
    assert jnp.allclose(ya, ra, **_tol(dtype))
    assert jnp.allclose(yb, rb, **_tol(dtype))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", [
    ("causal", {}),
    ("causal", {"prefix_len": 5}),
    ("window", {"window": 7}),
    ("chunk", {"chunk": 16}),
    ("bidir", {}),
])
@pytest.mark.parametrize("s,t,hd", [(37, 37, 32), (64, 64, 64), (16, 48, 16)])
def test_flash_attention(kind, kw, s, t, hd):
    if kind != "bidir" and s != t:
        pytest.skip("causal kinds assume aligned self-attention here")
    sh = (3, s, hd)
    q = jax.random.normal(KEY, sh, jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (3, t, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (3, t, hd), jnp.float32)
    o = ops.flash_attention(q, k, v, kind=kind, block_q=16, block_k=16, **kw)
    r = ref.flash_attention_ref(q, k, v, kind=kind, **kw)
    assert jnp.allclose(o, r, atol=2e-5, rtol=2e-5), \
        float(jnp.abs(o - r).max())


def test_flash_attention_gqa_fold():
    """q_group folding: rows [pos, head] share the position mask."""
    g, s, hd = 4, 32, 16
    q = jax.random.normal(KEY, (2, s * g, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (2, s, hd), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (2, s, hd), jnp.float32)
    o = ops.flash_attention(q, k, v, kind="causal", q_group=g,
                            block_q=16, block_k=16)
    # oracle: per-head slices with plain causal mask
    for h in range(g):
        qh = q[:, h::g]
        rh = ref.flash_attention_ref(qh, k, v, kind="causal")
        assert jnp.allclose(o[:, h::g], rh, atol=2e-5), h


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (2, 40, 32), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 40, 32), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 40, 32), dtype)
    o = ops.flash_attention(q, k, v, block_q=16, block_k=16)
    r = ref.flash_attention_ref(q, k, v)
    assert jnp.allclose(o.astype(jnp.float32), r.astype(jnp.float32),
                        **_tol(dtype))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hkv,g,l,hd", [
    (2, 3, 4, 100, 32), (1, 1, 8, 257, 64), (4, 2, 1, 64, 16)])
@pytest.mark.parametrize("t_frac", [0.3, 1.0])
def test_decode_attention(b, hkv, g, l, hd, t_frac):
    q = jax.random.normal(KEY, (b, hkv, g, hd), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, l, hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, l, hkv, hd))
    t = max(int(l * t_frac) - 1, 0)
    o = ops.decode_attention(q, k, v, t, block_l=32)
    r = ref.decode_attention_ref(q, k, v, t)
    assert jnp.allclose(o, r, atol=2e-5, rtol=2e-5)


def test_decode_attention_traced_t():
    """t is a scalar-prefetch operand: no recompilation across steps."""
    q = jax.random.normal(KEY, (1, 2, 2, 16), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 2, 16))

    f = jax.jit(lambda t: ops.decode_attention(q, k, v, t, block_l=32))
    for t in (0, 13, 63):
        assert jnp.allclose(f(jnp.int32(t)),
                            ref.decode_attention_ref(q, k, v, t), atol=2e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,c,n", [(2, 100, 48, 8), (1, 33, 16, 1),
                                     (3, 256, 128, 16)])
def test_ssm_scan(b, s, c, n):
    a = jax.random.uniform(KEY, (b, s, c, n), jnp.float32, 0.5, 1.0)
    bb = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, c, n))
    h0 = jax.random.normal(jax.random.fold_in(KEY, 2), (b, c, n))
    y, hT = ops.ssm_scan(a, bb, h0, block_s=32, block_c=32)
    ry, rhT = ref.ssm_scan_ref(a, bb, h0)
    assert jnp.allclose(y, ry, atol=2e-4, rtol=2e-4)
    assert jnp.allclose(hT, rhT, atol=2e-4, rtol=2e-4)


def test_ssm_scan_carry_chains():
    """Splitting a sequence across two calls == one call (state handoff)."""
    a = jax.random.uniform(KEY, (1, 64, 16, 4), jnp.float32, 0.5, 1.0)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 16, 4))
    h0 = jnp.zeros((1, 16, 4))
    y_full, h_full = ops.ssm_scan(a, b, h0, block_s=16, block_c=16)
    y1, h1 = ops.ssm_scan(a[:, :32], b[:, :32], h0, block_s=16, block_c=16)
    y2, h2 = ops.ssm_scan(a[:, 32:], b[:, 32:], h1, block_s=16, block_c=16)
    assert jnp.allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-5)
    assert jnp.allclose(h2, h_full, atol=1e-5)
