"""Bucketed batched prefill: bit-identity, padding containment, config.

The tentpole contract: an engine that right-pads admitted prompts to a
bucket ladder and prefills several requests in ONE launch must produce
per-request greedy streams BIT-identical to the exact-length engine (and
so to one-shot ``generate()``), while bounding prefill compile count by
the ladder length. Padding must be contained: pad rows and pad pages
write nothing into the pool, and the radix tree never sees a padded
page. The config redesign rides along: grouped sub-configs are pure
views over the flat fields, and ``validate()`` is the one entry point
for every cross-field rule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (AdmissionConfig, DegradeConfig, PagedEngine,
                         PagedServeConfig, PagePool, ProgramCache,
                         Scheduler, ServeConfig, SpecConfig, Telemetry,
                         TelemetryConfig, bucket_for, default_buckets,
                         generate, make_paged_bucket_prefill_fn,
                         rows_for_bucket, validate_buckets)
from repro.serve import paged_cache as PG
from repro.serve.engine import (make_paged_prefill_fn,
                                make_paged_suffix_prefill_fn)

from _helpers import tiny

KEY = jax.random.PRNGKey(0)
PC = ParallelContext()


def _build(n_layers=2):
    cfg = tiny(n_layers=n_layers)
    ms = T.build_structure(cfg, tp=1)
    return cfg, ms, T.init_params(ms, KEY)


def _psv(**kw):
    base = dict(n_slots=4, page_size=8, n_pages=21, max_len=32,
                cache_dtype=jnp.float32)
    base.update(kw)
    return PagedServeConfig(**base)


def _prompt(i, length, vocab):
    return np.asarray(jax.random.randint(jax.random.fold_in(KEY, i),
                                         (length,), 0, vocab))


# ---------------------------------------------------------------------------
# Ladder math
# ---------------------------------------------------------------------------

def test_ladder_math():
    assert default_buckets(48, 8) == (8, 16, 32, 48)
    assert default_buckets(32, 8) == (8, 16, 32)
    assert bucket_for(5, (8, 16)) == 8
    assert bucket_for(9, (8, 16)) == 16
    assert bucket_for(17, (8, 16)) is None
    assert rows_for_bucket(8, 4, 4096) == 4     # slot-capped
    assert rows_for_bucket(16, 8, 32) == 2      # budget-capped
    assert rows_for_bucket(64, 8, 32) == 1      # floor: wider than budget
    validate_buckets((8, 16, 32), page_size=8, max_len=32)
    with pytest.raises(ValueError, match="strictly increasing"):
        validate_buckets((16, 8), page_size=8, max_len=32)
    with pytest.raises(ValueError, match="multiple of"):
        validate_buckets((8, 12), page_size=8, max_len=32)
    with pytest.raises(ValueError, match="exceeds max_len"):
        validate_buckets((8, 64), page_size=8, max_len=32)


# ---------------------------------------------------------------------------
# The tentpole bit-identity contract
# ---------------------------------------------------------------------------

def test_bucketed_engine_matches_exact_engine_staggered():
    """Staggered arrivals, mixed lengths: the bucketed engine's streams
    are BIT-identical to the exact-length reference engine's
    (``prefill_buckets=()``), and page accounting balances in both."""
    cfg, ms, params = _build()
    lens = [5, 8, 12, 16, 7, 20, 9, 13]
    prompts = [_prompt(i, L, cfg.vocab_size) for i, L in enumerate(lens)]
    engines = [PagedEngine(params, ms, _psv(prefill_buckets=pb))
               for pb in (None, ())]
    assert engines[0]._buckets == (8, 16, 32)
    assert engines[1]._buckets == ()
    for eng in engines:
        for p in prompts[:5]:
            eng.add_request(p, 6)
        for _ in range(2):
            eng.step()
        for p in prompts[5:]:
            eng.add_request(p, 6)
        eng.drain()
        eng.pool.check_balance()
        assert eng.pool.live == 0
    bkt, ref = engines
    assert sorted(bkt.results) == sorted(ref.results)
    for rid in bkt.results:
        assert (bkt.results[rid] == ref.results[rid]).all(), rid
    assert bkt.counters["bucket_prefills"] == len(lens)
    assert bkt.counters["bucket_groups"] >= 1
    assert bkt.counters["pad_tokens"] > 0
    assert ref.counters["bucket_prefills"] == 0
    # Compile count bounded by the ladder, not by the 7 distinct lengths.
    bkt_pins = [k for k in bkt.telemetry.compiles if k[1] == "prefill_bucket"]
    assert 1 <= len(bkt_pins) <= len(bkt._buckets)


def test_bucketed_engine_matches_one_shot_generate():
    cfg, ms, params = _build(n_layers=4)
    eng = PagedEngine(params, ms, _psv())
    lens = [6, 11, 8, 14]
    prompts = [_prompt(i, L, cfg.vocab_size) for i, L in enumerate(lens)]
    rids = [eng.add_request(p, 5) for p in prompts]
    eng.drain()
    sv = ServeConfig(max_len=32, temperature=0.0, cache_dtype=jnp.float32)
    for rid, p in zip(rids, prompts):
        ref = np.asarray(generate(params, jnp.asarray(p)[None], 5,
                                  ms=ms, pc=PC, sv=sv)[0])
        assert (eng.results[rid] == ref).all(), rid


def test_bucket_fn_matches_exact_fn_rowwise():
    """Program level: one [rows, bucket] launch with right-padded prompts
    and an inert pad row produces, per real row, the SAME first token and
    the SAME page bits as the exact-length batch-1 program."""
    cfg, ms, params = _build()
    psv = _psv()
    ps = psv.page_size
    bucket, rows = 16, 3
    lens = [9, 16]
    prompts_np = [_prompt(i, L, cfg.vocab_size) for i, L in enumerate(lens)]
    key = jax.random.PRNGKey(7)

    fn_b = jax.jit(make_paged_bucket_prefill_fn(ms, PC, psv, bucket, rows))
    n_pg = bucket // ps
    prompts = np.zeros((rows, bucket), np.int32)
    true_lens = np.ones((rows,), np.int32)
    page_ids = np.full((rows, n_pg), PG.GARBAGE_PAGE, np.int32)
    pages = [[1, 2], [3, 4]]       # rows 0..1 real, row 2 inert pad
    for i, (p, L) in enumerate(zip(prompts_np, lens)):
        prompts[i, :L] = p
        true_lens[i] = L
        page_ids[i, :-(-L // ps)] = pages[i][:-(-L // ps)]
    caches = PG.init_paged_caches(ms, n_slots=psv.n_slots,
                                  n_pages=psv.n_pages, page_size=ps,
                                  dtype=psv.cache_dtype)
    tok_b, ok_b, caches_b = fn_b(params, caches,
                                 jnp.asarray(prompts),
                                 jnp.asarray(true_lens),
                                 jnp.asarray(page_ids), key)
    assert np.asarray(ok_b).all()
    for i, (p, L) in enumerate(zip(prompts_np, lens)):
        fn_e = jax.jit(make_paged_prefill_fn(ms, PC, psv, L))
        caches_e = PG.init_paged_caches(ms, n_slots=psv.n_slots,
                                        n_pages=psv.n_pages, page_size=ps,
                                        dtype=psv.cache_dtype)
        npg = -(-L // ps)
        tok_e, _, caches_e = fn_e(params, caches_e,
                                  jnp.asarray(p[None]),
                                  jnp.asarray(pages[i][:npg], jnp.int32),
                                  jnp.int32(i), key)
        assert int(np.asarray(tok_b)[i]) == int(np.asarray(tok_e)[0])
        for seg_b, seg_e in zip(caches_b, caches_e):
            for name in seg_b:
                if not PG.is_paged_entry(name):
                    continue
                ba = T.cache_batch_axis(name)
                for pg in pages[i][:npg]:
                    # Bit equality over the page's REAL positions (the
                    # in-page position axis sits right after the pool's
                    # page axis); the tail of a partial page holds junk
                    # kv in the bucketed tree but is never unmasked
                    # before decode overwrites it.
                    n_real = min(ps, L - pages[i].index(pg) * ps)
                    sl = (slice(None),) * ba + (pg, slice(0, n_real))
                    got = np.asarray(seg_b[name][sl])
                    want = np.asarray(seg_e[name][sl])
                    assert (got == want).all(), name


def test_bucket_ctx_fn_matches_suffix_and_exact_fn_rowwise():
    """Program level, ctx-AWARE bucket: one [rows, bucket] launch carrying
    a radix-HIT row (per-row ctx-page gather), a COLD row (ctx_len 0,
    all-garbage ctx ids), and an inert pad row. The hit row must match the
    exact-length suffix program bit for bit (token + suffix page kv); the
    cold row must match the exact-length full program — heterogeneous
    (ctx_pages, suffix_len) rows share ONE launch without moving a bit."""
    cfg, ms, params = _build()
    psv = _psv()
    ps = psv.page_size
    key = jax.random.PRNGKey(7)

    # Donor: 16 shared tokens prefilled into pages (1, 2) — the radix ctx.
    donor = _prompt(0, 16, cfg.vocab_size)
    caches = PG.init_paged_caches(ms, n_slots=psv.n_slots,
                                  n_pages=psv.n_pages, page_size=ps,
                                  dtype=psv.cache_dtype)
    fn_d = jax.jit(make_paged_prefill_fn(ms, PC, psv, 16))
    _, _, caches = fn_d(params, caches, jnp.asarray(donor[None]),
                        jnp.asarray([1, 2], jnp.int32), jnp.int32(0), key)

    tail = _prompt(1, 6, cfg.vocab_size)     # hit row: ctx 16 + suffix 6
    cold = _prompt(2, 7, cfg.vocab_size)     # cold row: 7 fresh tokens
    bucket, rows, ctx_pages = 8, 3, 3        # pages_per_slot - 1
    prompts = np.zeros((rows, bucket), np.int32)
    true_lens = np.ones((rows,), np.int32)
    page_ids = np.full((rows, 1), PG.GARBAGE_PAGE, np.int32)
    ctx_ids = np.full((rows, ctx_pages), PG.GARBAGE_PAGE, np.int32)
    ctx_lens = np.zeros((rows,), np.int32)
    prompts[0, :6] = tail
    true_lens[0] = 6
    page_ids[0, 0] = 3
    ctx_ids[0, :2] = (1, 2)
    ctx_lens[0] = 16
    prompts[1, :7] = cold
    true_lens[1] = 7
    page_ids[1, 0] = 4
    fn_b = jax.jit(make_paged_bucket_prefill_fn(ms, PC, psv, bucket, rows,
                                                ctx_pages))
    tok_b, ok_b, caches_b = fn_b(params, caches, jnp.asarray(prompts),
                                 jnp.asarray(true_lens),
                                 jnp.asarray(page_ids),
                                 jnp.asarray(ctx_ids),
                                 jnp.asarray(ctx_lens), key)
    assert np.asarray(ok_b).all()

    # Hit-row reference: the exact-length suffix program over the SAME
    # donor caches.
    fn_s = jax.jit(make_paged_suffix_prefill_fn(ms, PC, psv, 2, 6))
    tok_s, ok_s, caches_s = fn_s(params, caches, jnp.asarray(tail[None]),
                                 jnp.asarray([1, 2], jnp.int32),
                                 jnp.asarray([3], jnp.int32),
                                 jnp.int32(0), key)
    assert np.asarray(ok_s).all()
    assert int(np.asarray(tok_b)[0]) == int(np.asarray(tok_s)[0])

    # Cold-row reference: the exact-length full program on a fresh pool.
    fn_e = jax.jit(make_paged_prefill_fn(ms, PC, psv, 7))
    caches_e = PG.init_paged_caches(ms, n_slots=psv.n_slots,
                                    n_pages=psv.n_pages, page_size=ps,
                                    dtype=psv.cache_dtype)
    tok_e, _, caches_e = fn_e(params, caches_e, jnp.asarray(cold[None]),
                              jnp.asarray([4], jnp.int32), jnp.int32(1), key)
    assert int(np.asarray(tok_b)[1]) == int(np.asarray(tok_e)[0])

    for (pg, n_real, ref) in ((3, 6, caches_s), (4, 7, caches_e)):
        for seg_b, seg_r in zip(caches_b, ref):
            for name in seg_b:
                if not PG.is_paged_entry(name):
                    continue
                ba = T.cache_batch_axis(name)
                sl = (slice(None),) * ba + (pg, slice(0, n_real))
                got = np.asarray(seg_b[name][sl])
                want = np.asarray(seg_r[name][sl])
                assert (got == want).all(), (name, pg)


def test_engine_hit_and_cold_rows_share_one_bucket_group():
    """Engine level: a radix-HIT member and a COLD request admitted
    together land in the SAME bucket group (one launch), the hit prefills
    only its suffix, prefill compiles stay bounded by the ladder with no
    exact-length program ever built, and all streams are bit-identical to
    one-shot ``generate()``."""
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv(prefix_cache=True))
    shared = _prompt(0, 8, cfg.vocab_size)          # one whole page
    donor = np.concatenate([shared, _prompt(1, 8, cfg.vocab_size)])
    member = np.concatenate([shared, _prompt(2, 6, cfg.vocab_size)])
    cold = _prompt(3, 7, cfg.vocab_size)
    rid0 = eng.add_request(donor, 5)
    eng.drain()                                     # donates the shared page
    g0 = eng.counters["bucket_groups"]
    assert g0 == 1 and eng.counters["prefix_hits"] == 0
    rid1 = eng.add_request(member, 5)
    rid2 = eng.add_request(cold, 5)
    eng.drain()
    c = eng.counters
    assert c["bucket_groups"] == g0 + 1, dict(c)    # ONE shared launch
    assert c["prefix_hits"] == 1, dict(c)
    assert c["suffix_prefills"] == 1, dict(c)
    assert c["full_prefills"] == 2, dict(c)         # donor + cold
    assert c["bucket_prefills"] == 3, dict(c)
    pins = [k for k in eng.telemetry.compiles if k[1] == "prefill_bucket"]
    assert 1 <= len(pins) <= len(eng._buckets), pins
    assert not any(k[1] in ("prefill_full", "prefill_suffix")
                   for k in eng.telemetry.compiles), (
        dict(eng.telemetry.compiles))
    sv = ServeConfig(max_len=32, temperature=0.0, cache_dtype=jnp.float32)
    for rid, p in ((rid0, donor), (rid1, member), (rid2, cold)):
        ref = np.asarray(generate(params, jnp.asarray(p)[None], 5,
                                  ms=ms, pc=PC, sv=sv)[0])
        assert (eng.results[rid] == ref).all(), rid


def test_scatter_rows_masks_pad_rows_and_pages():
    """Garbage-directed rows/pages write NOTHING: the garbage page stays
    zero and no allocatable page moves."""
    cfg, ms, params = _build()
    psv = _psv()
    caches = PG.init_paged_caches(ms, n_slots=psv.n_slots,
                                  n_pages=psv.n_pages,
                                  page_size=psv.page_size,
                                  dtype=psv.cache_dtype)
    bucket, rows = 16, 2
    fn = jax.jit(make_paged_bucket_prefill_fn(ms, PC, psv, bucket, rows))
    prompts = np.zeros((rows, bucket), np.int32)
    prompts[0, :9] = _prompt(0, 9, cfg.vocab_size)
    true_lens = np.asarray([9, 1], np.int32)
    page_ids = np.full((rows, 2), PG.GARBAGE_PAGE, np.int32)
    page_ids[0] = (5, 6)           # row 1 is ALL pad
    before = jax.tree.map(np.asarray, caches)
    _, _, caches = fn(params, caches, jnp.asarray(prompts),
                      jnp.asarray(true_lens), jnp.asarray(page_ids),
                      jax.random.PRNGKey(0))
    for seg_b, seg_a in zip(before, caches):
        for name in seg_b:
            if not PG.is_paged_entry(name):
                continue
            ba = T.cache_batch_axis(name)
            after = np.asarray(seg_a[name])
            for pg in range(psv.n_pages):
                sl = (slice(None),) * ba + (pg,)
                if pg in (5, 6):
                    continue       # the one real row's pages
                assert (after[sl] == seg_b[name][sl]).all(), (name, pg)
                if pg == PG.GARBAGE_PAGE:
                    assert (after[sl] == 0).all(), name


def test_radix_never_donates_a_padded_page():
    """Donation is structural: ``r.pages`` only ever holds the request's
    ALLOCATED pages (ceil(Lp/ps) of them), so bucket pad pages cannot
    reach the tree — and a same-prefix follower still bit-matches the
    exact engine."""
    cfg, ms, params = _build()
    lens = [12, 12, 5]             # 12 -> bucket 16: one padded page slot
    base = _prompt(0, 12, cfg.vocab_size)
    prompts = [base, base, _prompt(2, 5, cfg.vocab_size)]
    engines = [PagedEngine(params, ms,
                           _psv(prefix_cache=True, prefill_buckets=pb))
               for pb in (None, ())]
    for eng in engines:
        rids = [eng.add_request(p, 4) for p in prompts]
        eng.drain()
        if eng._buckets:
            # Every radix-held page id was allocated for REAL prompt
            # tokens — donation only ever considers len(tokens)//ps WHOLE
            # prompt pages, so a bucket's padded page slots (GARBAGE ids,
            # never allocated) are structurally unreachable.
            held = set()
            stack = list(eng.prefix.root.children.values())
            while stack:
                n = stack.pop()
                held.add(n.page)
                stack.extend(n.children.values())
            assert PG.GARBAGE_PAGE not in held
            assert len(held) <= 2          # 12//8 + 5//8 whole pages
    bkt, ref = engines
    for rid in bkt.results:
        assert (bkt.results[rid] == ref.results[rid]).all(), rid
    assert bkt.counters["prefix_hits"] == ref.counters["prefix_hits"]


# ---------------------------------------------------------------------------
# Scheduler: the budget counts what the device computes
# ---------------------------------------------------------------------------

def test_scheduler_budget_counts_padded_tokens():
    def mk(buckets):
        pool = PagePool(9)
        s = Scheduler(n_slots=4, pool=pool, page_size=8, max_len=32,
                      prefill_token_budget=20, prefill_buckets=buckets)
        for i in range(2):
            s.submit(np.zeros(9, np.int32), 2, -1)
        return s

    exact = mk(())
    assert len(exact.admit(0)) == 2        # 9 + 9 <= 20
    padded = mk((16,))
    # First admission ignores the budget (anti-livelock), but its PADDED
    # cost (16) leaves only 4 — the second 16-wide admission must wait.
    assert len(padded.admit(0)) == 1


# ---------------------------------------------------------------------------
# Config groups + ProgramCache
# ---------------------------------------------------------------------------

def test_config_groups_are_views_over_flats():
    flat = PagedServeConfig(n_slots=4, page_size=8, n_pages=9, max_len=32,
                            prefill_token_budget=64, max_queue=3,
                            degrade_delta=True, degrade_slots=1,
                            degrade_queue_depth=2, degrade_eff_depth=2,
                            telemetry=False, profile_decode=True)
    grouped = PagedServeConfig(
        n_slots=4, page_size=8, n_pages=9, max_len=32,
        admission=AdmissionConfig(prefill_token_budget=64, max_queue=3),
        degrade=DegradeConfig(enabled=True, slots=1, queue_depth=2,
                              eff_depth=2),
        telemetry_cfg=TelemetryConfig(enabled=False, profile_decode=True))
    assert flat == grouped
    assert grouped.degrade_slots == 1 and grouped.max_queue == 3
    assert flat.admission == AdmissionConfig(prefill_token_budget=64,
                                             max_queue=3)
    spec = PagedServeConfig(n_slots=4, page_size=8, n_pages=9, max_len=32,
                            spec=SpecConfig(k=2, delta=3))
    assert spec.spec_k == 2 and spec.spec_delta == 3
    spec.validate()


def test_validate_is_the_single_entry_point():
    def cfg(**kw):
        return _psv(**kw)

    with pytest.raises(ValueError, match="whole number of pages"):
        cfg(max_len=20).validate()
    with pytest.raises(ValueError, match="n_slots=0 must be >= 1"):
        cfg(n_slots=0).validate()
    with pytest.raises(ValueError, match="without spec_k"):
        cfg(spec_delta=3).validate()
    with pytest.raises(ValueError, match="without degrade_delta"):
        cfg(degrade_slots=1).validate()
    with pytest.raises(ValueError, match="tp=1-only"):
        cfg(spec_k=2, spec_delta=3).validate(mesh=True)
    with pytest.raises(ValueError, match="multiple of"):
        cfg(prefill_buckets=(8, 12)).validate()
    # The engine routes through validate(): a bad ladder dies in __init__.
    cfg_bad = cfg(prefill_buckets=(12,))
    _, ms, params = _build()
    with pytest.raises(ValueError, match="multiple of"):
        PagedEngine(params, ms, cfg_bad)


def test_program_cache_single_increment_site():
    tel = Telemetry()
    pc = ProgramCache(tel)
    built = []

    def build():
        built.append(1)
        return "fn"

    assert pc.get("main", "decode", 4, build) == "fn"
    assert pc.get("main", "decode", 4, build) == "fn"
    assert built == [1]                       # one build...
    assert tel.compiles[("main", "decode", 4)] == 1   # ...one event
    assert ("main", "decode", 4) in pc and len(pc) == 1
    pc.note("spec_verify", "decode", 8)       # fused-program second body
    assert tel.compiles[("spec_verify", "decode", 8)] == 1
    assert len(pc) == 1                       # note() caches nothing
