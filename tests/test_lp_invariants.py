"""The paper's core invariants: the retraining-free LP merge is exactly the
Fig. 2b computational-graph rewrite, and degrades to the vanilla model in
every limiting case."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced_config
from repro.core import interventions as IV
from repro.core import lp as LP
from repro.model import attention as A
from repro.model import blocks as B
from repro.model import stack as ST
from repro.model.params import init_tree
from repro.parallel.context import ParallelContext

from _helpers import tiny

PC = ParallelContext()


def _layer_params(cfg, key=0):
    return [init_tree(B.layer_template(cfg, s, 1), jax.random.PRNGKey(i + key))
            for i, s in enumerate(cfg.layer_specs())]


def _run(cfg, layer_params, plan, x, pos):
    segs, sp = LP.lp_convert(cfg, layer_params, plan)
    dims = A.attn_dims(cfg, 1)
    y, _, _ = ST.apply_stack_full(sp, x, segs, cfg=cfg, dims=dims, pc=PC,
                                  positions=pos)
    return y


@pytest.fixture(scope="module")
def setup():
    cfg = tiny(n_layers=6)
    lp = _layer_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 16, cfg.d_model))
    pos = jnp.arange(16)[None]
    return cfg, lp, x, pos


def test_empty_plan_is_vanilla(setup):
    """lp_plan=[] == vanilla sequential model, bit-exact."""
    cfg, lp, x, pos = setup
    y = _run(cfg, lp, LP.EMPTY_PLAN, x, pos)
    ref = IV.apply_intervened(lp, IV.sequential_plan(6), x, cfg=cfg,
                              positions=pos)
    assert jnp.allclose(y, ref, atol=1e-5)


def test_pair_equals_tp_form(setup):
    """The production pair path == the explicit two-path Fig. 2b formula
    evaluated with the ORIGINAL per-layer weights."""
    cfg, lp, x, pos = setup
    y = _run(cfg, lp, LP.LPPlan(((2, 3), (4, 5))), x, pos)
    plan = (IV.sequential_plan(2)
            + [IV.LayerGroup((2, 3), "tp"), IV.LayerGroup((4, 5), "tp")])
    ref = IV.apply_intervened(lp, plan, x, cfg=cfg, positions=pos)
    assert jnp.allclose(y, ref, atol=1e-5)


def test_zeroed_second_layer_is_single(setup):
    """An LP pair whose second member is zeroed == the first layer alone
    (the merge adds nothing but the second path's contribution)."""
    cfg, lp, x, pos = setup
    lp2 = list(lp)
    zero = jax.tree.map(jnp.zeros_like, lp2[3])
    # keep norms harmless: zero scale makes LN output 0 -> attn(0-scaled
    # input)=0 only if projections are zero too, which they are.
    lp2[3] = zero
    y_pair = _run(cfg, lp2, LP.LPPlan(((2, 3),)), x, pos)

    # reference: layers 0,1,2,4,5 sequential with layer 3 removed entirely
    ref = IV.apply_intervened(lp, IV.prune_plan(6, 3, 3), x, cfg=cfg,
                              positions=pos)
    assert jnp.allclose(y_pair, ref, atol=1e-5)


def test_extract_layers_roundtrip(setup):
    cfg, lp, x, pos = setup
    segs, sp = LP.lp_convert(cfg, lp, LP.LPPlan(((0, 1), (2, 3))))
    back = LP.extract_layers(sp, segs)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(lp)):
        assert jnp.allclose(a, b)


def test_replan(setup):
    """Elastic depth: re-pair an LP'd stack under a different plan without
    changing the weights."""
    cfg, lp, x, pos = setup
    segs1, sp1 = LP.lp_convert(cfg, lp, LP.LPPlan(((0, 1),)))
    segs2, sp2 = LP.replan(cfg, sp1, segs1, LP.LPPlan(((2, 3), (4, 5))))
    y = ST.apply_stack_full(sp2, x, segs2, cfg=cfg,
                            dims=A.attn_dims(cfg, 1), pc=PC, positions=pos)[0]
    ref = _run(cfg, lp, LP.LPPlan(((2, 3), (4, 5))), x, pos)
    assert jnp.allclose(y, ref, atol=1e-5)


def test_par_and_tp_forms_close_but_distinct(setup):
    """Fig. 2b's merged-residual form is NOT numerically the paper's (PAR)
    equation — but both stay close to the sequential output on a smooth
    random model (the paper's 'surprisingly it works' observation)."""
    cfg, lp, x, pos = setup
    y_tp = IV.apply_intervened(lp, IV.parallel2_plan(6, 1, 4, form="tp"), x,
                               cfg=cfg, positions=pos)
    y_par = IV.apply_intervened(lp, IV.parallel2_plan(6, 1, 4, form="par"), x,
                                cfg=cfg, positions=pos)
    assert not jnp.allclose(y_tp, y_par, atol=1e-6)
    seq = IV.apply_intervened(lp, IV.sequential_plan(6), x, cfg=cfg,
                              positions=pos)
    # Both approximations stay within a few rms of the sequential output.
    rms = jnp.sqrt(jnp.mean(seq ** 2))
    assert jnp.sqrt(jnp.mean((y_tp - seq) ** 2)) < 2 * rms
    assert jnp.sqrt(jnp.mean((y_par - seq) ** 2)) < 2 * rms


# ---------------------------------------------------------------------------
# Plan machinery
# ---------------------------------------------------------------------------

def test_plan_range_respects_compatibility():
    cfg = reduced_config(get_config("recurrentgemma-9b"), n_layers=6)
    # pattern: rec, rec, attn, rec, rec, attn
    plan = LP.plan_range(cfg, 0, 6)
    assert plan.pairs == ((0, 1), (3, 4))  # attn layers stay sequential


def test_plan_for_depth_exact():
    cfg = get_config("yi-6b")
    for d in (31, 28, 25):
        plan = LP.plan_for_depth(cfg, d)
        assert plan.effective_depth(cfg.n_layers) == d


def test_plan_validation():
    with pytest.raises(AssertionError):
        LP.LPPlan(((0, 2),))      # non-consecutive
    with pytest.raises(AssertionError):
        LP.LPPlan(((0, 1), (1, 2)))  # overlapping


def test_llama4_heterogeneous_pair():
    """Chunked + global attention layers share a template and may pair."""
    cfg = reduced_config(get_config("llama4-scout-17b-a16e"), n_layers=4)
    assert LP.pairable(cfg, 2)  # layers 2 (chunked) and 3 (global)
    plan = LP.plan_range(cfg, 0, 4)
    assert len(plan.pairs) == 2
