"""Data pipeline: determinism, structure, ICL metadata."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (DataConfig, SynthConfig, icl_eval_batch, lm_batch,
                        make_source)
from repro.data.synthetic import copy_sequence, icl_sequence, trigram_sequence

SC = SynthConfig(vocab_size=512)


def test_batch_at_is_pure():
    src = make_source(DataConfig(seq_len=32, global_batch=4, seed=7))
    a, b = src.batch_at(11), src.batch_at(11)
    assert bool((a["tokens"] == b["tokens"]).all())
    c = src.batch_at(12)
    assert not bool((a["tokens"] == c["tokens"]).all())


def test_labels_are_shifted_tokens():
    batch = lm_batch(jax.random.PRNGKey(0), SC, 64, 4)
    assert bool((batch["tokens"][:, 1:] == batch["labels"][:, :-1]).all())


def test_token_range():
    batch = lm_batch(jax.random.PRNGKey(1), SC, 128, 8)
    assert int(batch["tokens"].min()) >= 0
    assert int(batch["tokens"].max()) < SC.vocab_size


def test_copy_sequence_structure():
    s = copy_sequence(jax.random.PRNGKey(0), SC, 65)
    L = (65 - 2) // 2
    assert int(s[0]) == SC.copy_tok
    assert int(s[L + 1]) == SC.sep_tok
    assert bool((s[1:L + 1] == s[L + 2:2 * L + 2]).all())


def test_icl_answers_consistent():
    """The same x must map to the same y within a sequence (the in-context
    function is well-defined)."""
    toks, pos, ys = icl_sequence(jax.random.PRNGKey(3), SC, 100,
                                 return_meta=True)
    xs = toks[pos - 2]
    seen = {}
    for x, y in zip(np.asarray(xs), np.asarray(ys)):
        if x in seen:
            assert seen[x] == y
        seen[x] = y
    assert bool((toks[pos] == ys).all())  # answers sit at the marked slots


def test_trigram_is_deterministic_language():
    """Same key -> same sequence; different keys share the transition
    structure (same fixed language)."""
    a = trigram_sequence(jax.random.PRNGKey(0), SC, 64)
    b = trigram_sequence(jax.random.PRNGKey(0), SC, 64)
    assert bool((a == b).all())


def test_file_source_roundtrip(tmp_path):
    data = np.random.default_rng(0).integers(0, 1000, 10_000).astype(np.uint16)
    p = tmp_path / "tokens.bin"
    data.tofile(p)
    src = make_source(DataConfig(seq_len=64, global_batch=4, seed=1,
                                 source="file", path=str(p)))
    b0 = src.batch_at(0)
    assert b0["tokens"].shape == (4, 64)
    assert bool((src.batch_at(3)["tokens"] == src.batch_at(3)["tokens"]).all())
    # labels are the next-token view of the same window
    assert bool((b0["tokens"][:, 1:] == b0["labels"][:, :-1]).all())
