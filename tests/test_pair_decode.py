"""The fused LP-pair decode fast path.

Covers the tentpole invariants:
  * the stacked pair cache layout ([2, B, L, Hkv, hd], bare key names)
  * exact numerical parity: fused pair=True call == per-half pair=False
    loop == (at the model level) the per-half decode execution, and the
    Pallas fused kernel == the XLA fused core
  * launch accounting: ONE attention kernel launch per paired phase in a
    traced decode step
  * the seq-sharded fused pair path == the heads-mode path (subprocess,
    slow)
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.roofline import jaxpr_primitive_count
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import attention as A
from repro.model import blocks as B
from repro.model import transformer as T
from repro.model.params import init_tree, stack_tmpl
from repro.parallel.context import ParallelContext
from repro.serve import ServeConfig, generate

from _helpers import tiny, run_multidevice

PC = ParallelContext()
KEY = jax.random.PRNGKey(0)


def _pair_attn_params(cfg):
    tmpl = stack_tmpl(A.attn_template(cfg, 1), 2)
    return init_tree(tmpl, KEY)


# ---------------------------------------------------------------------------
# Unit parity: one fused call == the per-half loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", [
    ("attn", {}),
    ("attn_local", {"window": 8}),
])
def test_fused_pair_matches_per_half(kind, kw):
    cfg = tiny(n_layers=2)
    dims = A.attn_dims(cfg, 1)
    p = _pair_attn_params(cfg)
    Bt, L, t = 2, 16, 5
    xn = jax.random.normal(jax.random.fold_in(KEY, 1), (2, Bt, 1, cfg.d_model))
    ck = jax.random.normal(jax.random.fold_in(KEY, 2),
                           (2, Bt, kw.get("window", L), dims.hkv, dims.hd))
    cv = jax.random.normal(jax.random.fold_in(KEY, 3), ck.shape)
    window = kw.get("window", 0)
    cfg2 = dataclasses.replace(cfg, window=window) if window else cfg

    o_f, nk_f, nv_f = A.decode_attn_standard(
        p, xn, ck, cv, t, cfg2, dims, PC, kind=kind, pair=True, window=window)

    outs, nks, nvs = [], [], []
    for i in range(2):
        ph = jax.tree.map(lambda w: w[i], p)
        o, nk, nv = A.decode_attn_standard(
            ph, xn[i], ck[i], cv[i], t, cfg2, dims, PC, kind=kind,
            pair=False, window=window)
        outs.append(o)
        nks.append(nk)
        nvs.append(nv)

    assert jnp.allclose(o_f, sum(outs), atol=1e-5), \
        float(jnp.abs(o_f - sum(outs)).max())
    assert jnp.allclose(nk_f, jnp.stack(nks), atol=1e-6)
    assert jnp.allclose(nv_f, jnp.stack(nvs), atol=1e-6)


def test_fused_pallas_matches_fused_xla():
    """decode_attention_pair (one launch for both halves) == the XLA core."""
    cfg = tiny(n_layers=2)
    dims = A.attn_dims(cfg, 1)
    p = _pair_attn_params(cfg)
    Bt, L, t = 2, 24, 17
    xn = jax.random.normal(jax.random.fold_in(KEY, 4), (2, Bt, 1, cfg.d_model))
    ck = jax.random.normal(jax.random.fold_in(KEY, 5),
                           (2, Bt, L, dims.hkv, dims.hd))
    cv = jax.random.normal(jax.random.fold_in(KEY, 6), ck.shape)
    o_x, nk_x, _ = A.decode_attn_standard(p, xn, ck, cv, t, cfg, dims, PC,
                                          kind="attn", pair=True)
    A.set_decode_impl("pallas")
    try:
        o_p, nk_p, _ = A.decode_attn_standard(p, xn, ck, cv, t, cfg, dims, PC,
                                              kind="attn", pair=True)
    finally:
        A.set_decode_impl("xla")
    assert jnp.allclose(o_p, o_x, atol=2e-5, rtol=2e-5), \
        float(jnp.abs(o_p - o_x).max())
    assert jnp.allclose(nk_p, nk_x)


# ---------------------------------------------------------------------------
# Cache layout
# ---------------------------------------------------------------------------

def test_pair_cache_is_stacked_contiguous():
    cfg = tiny(n_layers=4)
    plan = plan_range(cfg, 0, 4)
    ms = T.build_structure(cfg, plan=plan, tp=1)
    params = T.init_params(ms, KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 7), (2, 8), 0,
                              cfg.vocab_size)
    _, caches = T.prefill(params, toks, ms=ms, pc=PC, max_len=16,
                          cache_dtype=jnp.float32)
    dims = ms.dims
    for c in caches:
        assert set(c.keys()) == {"k", "v"}
        # [count, 2, B, L, Hkv, hd]: the pair axis rides INSIDE one tensor.
        assert c["k"].shape[1:] == (2, 2, 16, dims.hkv_global, dims.hd)

    ms0 = T.build_structure(cfg, plan=LPPlan(()), tp=1)
    params0 = T.init_params(ms0, KEY)
    _, caches0 = T.prefill(params0, toks, ms=ms0, pc=PC, max_len=16,
                           cache_dtype=jnp.float32)
    for c in caches0:
        assert set(c.keys()) == {"k0", "v0"}


# ---------------------------------------------------------------------------
# Model-level parity: fused execution == per-half execution, same plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "falcon-mamba-7b",
                                  "whisper-medium"])
def test_fused_decode_matches_per_half_execution(arch, monkeypatch):
    """Same plan, same params => identical greedy tokens whether the pair
    decodes through the fused stacked path or the per-half loop."""
    cfg = reduced_config(get_config(arch), n_layers=4)
    plan = plan_range(cfg, 0, 4)
    ms = T.build_structure(cfg, plan=plan, tp=1)
    assert any(seg.group.pair for seg in ms.segments), "plan must pair"
    params = T.init_params(ms, KEY)
    sv = ServeConfig(max_len=32, temperature=0.0, cache_dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 8), (2, 8), 0,
                                 cfg.vocab_size)
    extras = {}
    if cfg.enc_layers:
        extras["frames"] = 0.02 * jax.random.normal(
            jax.random.fold_in(KEY, 9), (2, cfg.enc_seq, cfg.d_model))

    out_fused = generate(params, prompts, 6, ms=ms, pc=PC, sv=sv,
                         frames=extras.get("frames"))
    # Force the per-half fallback: no group advertises the stacked layout.
    monkeypatch.setattr(B, "pair_cache_stacked", lambda g: False)
    out_halves = generate(params, prompts, 6, ms=ms, pc=PC, sv=sv,
                          frames=extras.get("frames"))
    assert bool((out_fused == out_halves).all()), (out_fused, out_halves)


def test_pallas_decode_step_with_dual_norm_matches_xla():
    """A full decode step with BOTH pair fusions enabled — the stacked
    Pallas decode kernel and the dual-RMSNorm kernel at each phase entry —
    matches the XLA path."""
    from repro.model import norms as N
    cfg = tiny(n_layers=2)
    plan = plan_range(cfg, 0, 2)
    ms = T.build_structure(cfg, plan=plan, tp=1)
    params = T.init_params(ms, KEY)
    toks = jax.random.randint(jax.random.fold_in(KEY, 10), (2, 8), 0,
                              cfg.vocab_size)
    _, caches = T.prefill(params, toks, ms=ms, pc=PC, max_len=16,
                          cache_dtype=jnp.float32)
    nxt = jnp.zeros((2,), jnp.int32)
    lg_x, _ = T.decode_step(params, nxt, caches, jnp.int32(8), ms=ms, pc=PC)
    A.set_decode_impl("pallas")
    N.set_dual_impl("pallas")
    try:
        lg_p, _ = T.decode_step(params, nxt, caches, jnp.int32(8), ms=ms, pc=PC)
    finally:
        A.set_decode_impl("xla")
        N.set_dual_impl("xla")
    assert jnp.allclose(lg_p, lg_x, atol=2e-4, rtol=2e-4), \
        float(jnp.abs(lg_p - lg_x).max())


# ---------------------------------------------------------------------------
# Launch accounting
# ---------------------------------------------------------------------------

def test_one_attention_launch_per_paired_phase():
    """A traced decode step shows exactly one decode-attention kernel launch
    per paired phase (and one per unpaired layer)."""
    cfg = tiny(n_layers=6)
    for n_pairs, want in [(0, 6), (1, 5), (3, 3)]:
        plan = LPPlan(plan_range(cfg, 0, 6).pairs[:n_pairs])
        ms = T.build_structure(cfg, plan=plan, tp=1)
        params = jax.eval_shape(lambda ms=ms: T.init_params(ms, KEY))
        c_abs, _ = T.cache_meta(ms, batch=1, max_len=16, dtype=jnp.float32)
        A.set_decode_impl("pallas")
        try:
            jaxpr = jax.make_jaxpr(
                lambda p, c, ms=ms: T.decode_step(
                    p, jnp.zeros((1,), jnp.int32), c, jnp.int32(3),
                    ms=ms, pc=PC))(params, c_abs)
        finally:
            A.set_decode_impl("xla")
        n = jaxpr_primitive_count(jaxpr, "pallas_call")
        assert n == want, (n_pairs, n, want)


# ---------------------------------------------------------------------------
# Seq-sharded fused pair path (multi-device, slow)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_seq_sharded_pair_decode_matches_heads_mode():
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, json, dataclasses
from repro.configs import get_config, reduced_config
from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.serve.engine import ServeConfig, make_sharded_serve_step, make_sharded_prefill

# tinyllama reduced has 4 kv heads; tp=8 makes kv replicated so kv_mode="seq"
# engages the seq-sharded fused pair path.
cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=4)
plan = plan_range(cfg, 0, 4)
mesh = jax.make_mesh((1, 8), ("data", "model"))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
outs = {}
for mode in ("heads", "seq"):
    ms = T.build_structure(cfg, plan=plan, tp=8)
    sv = ServeConfig(max_len=32, kv_mode=mode, cache_dtype=jnp.float32)
    pre, c_specs, _ = make_sharded_prefill(ms, mesh, sv, batch=2, prompt_len=16)
    fn, c_abs, _, _ = make_sharded_serve_step(ms, mesh, sv, batch=2)
    params = T.init_params(ms, jax.random.PRNGKey(0))
    logits, caches = pre(params, toks)  # last-position logits [B, V]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    seq = [tok]
    key = jax.random.PRNGKey(2)
    for i in range(4):
        tok, caches = fn(params, tok, caches, jnp.int32(16 + i), key)
        seq.append(tok)
    outs[mode] = jnp.stack(seq, 1).tolist()
print("RESULT " + json.dumps(outs))
""")
    import json
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT")][0][7:])
    assert res["heads"] == res["seq"], res
