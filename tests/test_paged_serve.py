"""Continuous-batching serve subsystem: paged pair-KV cache + scheduler.

Core invariant: continuous-batched decode — requests admitted at different
steps, mixed prompt lengths, slots and pages recycled mid-flight — produces
exactly the same tokens per request as one-shot ``generate()``. Plus:
paged-vs-ring attention parity at the unit level, the paged Pallas kernel
vs the XLA gather core, page exhaustion -> queuing (no OOM, no
corruption), scheduler/page-pool unit behaviour, and the paged layout
validation gates.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import attention as A
from repro.model import transformer as T
from repro.model.params import init_tree, stack_tmpl
from repro.parallel.context import ParallelContext
from repro.serve import (PagedEngine, PagedServeConfig, PagePool, Scheduler,
                         ServeConfig, generate)
from repro.serve import paged_cache as PG

from _helpers import tiny

PC = ParallelContext()
KEY = jax.random.PRNGKey(0)


def _paginate(cache, page_size):
    """Ring cache [2, B, L, H, hd] -> (pool [2, n_pages, ps, H, hd], block
    tables [B, L/ps]): slot b's pages are contiguous, after a garbage page."""
    P2, B, L, H, hd = cache.shape
    n_pg = L // page_size
    pool = jnp.concatenate(
        [jnp.zeros((P2, 1, page_size, H, hd), cache.dtype),   # garbage page 0
         cache.reshape(P2, B * n_pg, page_size, H, hd)], axis=1)
    bt = 1 + jnp.arange(B * n_pg, dtype=jnp.int32).reshape(B, n_pg)
    return pool, bt


# ---------------------------------------------------------------------------
# Unit parity: paged attention == ring attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pair", [True, False])
def test_paged_decode_matches_ring(pair):
    cfg = tiny(n_layers=2)
    dims = A.attn_dims(cfg, 1)
    tmpl = A.attn_template(cfg, 1)
    p = init_tree(stack_tmpl(tmpl, 2) if pair else tmpl, KEY)
    Bt, L, ps = 2, 32, 8
    t = jnp.array([13, 5], jnp.int32)          # per-slot positions
    shape = (2, Bt, 1, cfg.d_model) if pair else (Bt, 1, cfg.d_model)
    xn = jax.random.normal(jax.random.fold_in(KEY, 1), shape)
    ck = jax.random.normal(jax.random.fold_in(KEY, 2),
                           (2, Bt, L, dims.hkv, dims.hd))
    cv = jax.random.normal(jax.random.fold_in(KEY, 3), ck.shape)
    kp, bt = _paginate(ck, ps)
    vp, _ = _paginate(cv, ps)
    if not pair:
        kp, vp = kp[0], vp[0]

    o_p, nk_p, nv_p = A.decode_attn_paged(
        p, xn, kp, vp, t, bt, cfg, dims, PC, kind="attn", pair=pair)

    # Ring reference: decode_attn_standard takes ONE position for the whole
    # batch, so run it per slot at that slot's position.
    for b in range(Bt):
        sl = (slice(None), slice(b, b + 1)) if pair else slice(b, b + 1)
        o_r, nk_r, nv_r = A.decode_attn_standard(
            p, xn[sl], ck[:, b:b + 1] if pair else ck[0, b:b + 1],
            cv[:, b:b + 1] if pair else cv[0, b:b + 1],
            int(t[b]), cfg, dims, PC, kind="attn", pair=pair)
        assert jnp.allclose(o_p[b:b + 1], o_r, atol=1e-5), b
        # The written slot must land at (bt[b, t//ps], t%ps) in the pool.
        pg, off = int(bt[b, int(t[b]) // ps]), int(t[b]) % ps
        if pair:
            written = nk_p[:, pg, off]
            expect = nk_r[:, 0, int(t[b])]
        else:
            written = nk_p[pg, off]
            expect = nk_r[0, int(t[b])]
        assert jnp.allclose(written, expect), b


def test_paged_pallas_matches_paged_xla():
    """decode_attention_pair_paged (one launch, block-table index maps)
    == the XLA gather core."""
    cfg = tiny(n_layers=2)
    dims = A.attn_dims(cfg, 1)
    p = init_tree(stack_tmpl(A.attn_template(cfg, 1), 2), KEY)
    Bt, L, ps = 3, 24, 8
    t = jnp.array([17, 3, 10], jnp.int32)
    xn = jax.random.normal(jax.random.fold_in(KEY, 4), (2, Bt, 1, cfg.d_model))
    ck = jax.random.normal(jax.random.fold_in(KEY, 5),
                           (2, Bt, L, dims.hkv, dims.hd))
    cv = jax.random.normal(jax.random.fold_in(KEY, 6), ck.shape)
    kp, bt = _paginate(ck, ps)
    vp, _ = _paginate(cv, ps)
    o_x, nk_x, _ = A.decode_attn_paged(p, xn, kp, vp, t, bt, cfg, dims, PC,
                                       kind="attn", pair=True)
    prev = A.get_decode_impl()
    A.set_decode_impl("pallas")
    try:
        o_p, nk_p, _ = A.decode_attn_paged(p, xn, kp, vp, t, bt, cfg, dims,
                                           PC, kind="attn", pair=True)
    finally:
        A.set_decode_impl(prev)
    assert jnp.allclose(o_p, o_x, atol=2e-5, rtol=2e-5), \
        float(jnp.abs(o_p - o_x).max())
    assert jnp.allclose(nk_p, nk_x)


# ---------------------------------------------------------------------------
# Pool layout
# ---------------------------------------------------------------------------

def test_paged_pool_keeps_stacked_pair_layout():
    cfg = tiny(n_layers=4)
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=1)
    abs_, _ = PG.paged_cache_meta(ms, n_slots=2, n_pages=9, page_size=8,
                                  dtype=jnp.float32)
    dims = ms.dims
    for seg in abs_:
        assert set(seg.keys()) == {"k", "v"}
        # [count, 2, n_pages, page_size, Hkv, hd] — pair axis INSIDE, pages
        # replace the [B, L] prefix.
        assert seg["k"].shape[1:] == (2, 9, 8, dims.hkv_global, dims.hd)

    ms0 = T.build_structure(cfg, plan=LPPlan(()), tp=1)
    abs0, _ = PG.paged_cache_meta(ms0, n_slots=2, n_pages=9, page_size=8,
                                  dtype=jnp.float32)
    for seg in abs0:
        assert set(seg.keys()) == {"k0", "v0"}
        assert seg["k0"].shape[1:] == (9, 8, dims.hkv_global, dims.hd)


@pytest.mark.parametrize("arch", ["recurrentgemma-9b", "whisper-medium",
                                  "paligemma-3b"])
def test_validate_paged_support_rejects(arch):
    """Window rings, cross-attention, and prefix-LM are not pageable."""
    cfg = reduced_config(get_config(arch), n_layers=4)
    ms = T.build_structure(cfg, tp=1)
    with pytest.raises(ValueError):
        PG.validate_paged_support(ms, 64)


# ---------------------------------------------------------------------------
# The core invariant: continuous batching == one-shot generate()
# ---------------------------------------------------------------------------

def _one_shot(params, ms, prompt, n_new, max_len):
    sv = ServeConfig(max_len=max_len, temperature=0.0,
                     cache_dtype=jnp.float32)
    return np.asarray(generate(params, jnp.asarray(prompt)[None], n_new,
                               ms=ms, pc=PC, sv=sv)[0])


@pytest.mark.parametrize("arch,pallas", [
    ("tinyllama-1.1b", False),
    ("tinyllama-1.1b", True),
    ("falcon-mamba-7b", False),
])
def test_continuous_batching_matches_one_shot(arch, pallas):
    """>= 8 concurrent requests, staggered admission, mixed prompt lengths:
    per-request tokens are EXACTLY those of one-shot generate()."""
    cfg = reduced_config(get_config(arch), n_layers=4)
    plan = plan_range(cfg, 0, 4)
    ms = T.build_structure(cfg, plan=plan, tp=1)
    params = T.init_params(ms, KEY)
    psv = PagedServeConfig(n_slots=8, page_size=8, n_pages=41, max_len=32,
                           cache_dtype=jnp.float32)
    eng = PagedEngine(params, ms, psv)
    lens = [6, 8, 12, 8, 6, 12, 8, 6, 12, 8]
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(KEY, i),
                                             (L,), 0, cfg.vocab_size))
               for i, L in enumerate(lens)]
    prev = A.get_decode_impl()
    if pallas:
        A.set_decode_impl("pallas")
    try:
        rids = [eng.add_request(p, 5) for p in prompts[:8]]
        s0 = eng.step()
        assert s0["decoded"] == 8, "8 requests must decode concurrently"
        eng.step()
        rids += [eng.add_request(p, 5) for p in prompts[8:]]  # staggered
        res = eng.drain()
    finally:
        A.set_decode_impl(prev)
    for rid, p in zip(rids, prompts):
        ref = _one_shot(params, ms, p, 5, psv.max_len)
        assert (res[rid] == ref).all(), (arch, rid, res[rid], ref)
    assert eng.pool.live == 0
    assert eng.pool.allocated_total == eng.pool.freed_total > 0


def test_page_exhaustion_queues_then_recycles():
    """With pages for only 2 requests in flight, later arrivals QUEUE (no
    OOM), get admitted as pages recycle, and still match one-shot."""
    cfg = tiny(n_layers=4)
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=1)
    params = T.init_params(ms, KEY)
    # 4 slots but only 4 allocatable pages; each request needs 2 pages.
    psv = PagedServeConfig(n_slots=4, page_size=8, n_pages=5, max_len=16,
                           cache_dtype=jnp.float32)
    eng = PagedEngine(params, ms, psv)
    prompts = [np.asarray(jax.random.randint(jax.random.fold_in(KEY, 40 + i),
                                             (8,), 0, cfg.vocab_size))
               for i in range(5)]
    rids = [eng.add_request(p, 4) for p in prompts]
    s0 = eng.step()
    assert s0["admitted"] == 2 and eng.sched.n_queued == 3  # exhaustion
    assert eng.pool.n_free == 0
    saw_queue_drain = False
    while eng.sched.n_queued or eng.sched.n_running:
        s = eng.step()
        saw_queue_drain = saw_queue_drain or s["admitted"] > 0
    assert saw_queue_drain
    for rid, p in zip(rids, prompts):
        ref = _one_shot(params, ms, p, 4, psv.max_len)
        assert (eng.results[rid] == ref).all(), rid
    assert eng.pool.live == 0
    assert eng.pool.allocated_total == eng.pool.freed_total == 10  # 5 x 2


def test_request_too_large_rejected_up_front():
    cfg = tiny(n_layers=2)
    ms = T.build_structure(cfg, tp=1)
    params = T.init_params(ms, KEY)
    psv = PagedServeConfig(n_slots=2, page_size=8, n_pages=3, max_len=16,
                           cache_dtype=jnp.float32)
    eng = PagedEngine(params, ms, psv)
    # 10 + 7 = 17 positions -> 3 pages > the 2-page pool: can never run.
    with pytest.raises(ValueError):
        eng.add_request(np.zeros(10, np.int32), 7)
    # 2 pages == pool capacity: queues fine.
    eng.add_request(np.zeros(10, np.int32), 6)
    res = eng.drain()
    assert len(res[0]) == 6 and eng.pool.live == 0


# ---------------------------------------------------------------------------
# Scheduler / page-pool units
# ---------------------------------------------------------------------------

def test_page_pool_accounting():
    pool = PagePool(6)           # 5 allocatable + garbage
    a = pool.alloc(3)
    assert a is not None and PG.GARBAGE_PAGE not in a
    assert pool.alloc(3) is None          # exhaustion -> None, not OOM
    b = pool.alloc(2)
    assert pool.live == 5 and pool.n_free == 0
    pool.free(a)
    assert pool.live == 2
    assert pool.allocated_total == 5 and pool.freed_total == 3
    pool.check_balance()
    pool.free(b)
    assert pool.live == 0
    pool.check_balance()


def test_scheduler_fcfs_and_budget():
    pool = PagePool(9)           # 8 allocatable
    sched = Scheduler(n_slots=2, pool=pool, page_size=8, max_len=32,
                      prefill_token_budget=10)
    r0 = sched.submit(np.zeros(8, np.int32), 4)
    r1 = sched.submit(np.zeros(8, np.int32), 4)
    r2 = sched.submit(np.zeros(8, np.int32), 4)
    adm = sched.admit()
    # Budget 10 < 16: only the head admits this step (first ignores budget);
    # slots then cap the next admission wave.
    assert [r.rid for r in adm] == [r0.rid]
    adm = sched.admit()
    assert [r.rid for r in adm] == [r1.rid]
    assert sched.admit() == []            # no free slot -> r2 waits (FCFS)
    sched.finish(r0)
    adm = sched.admit()
    assert [r.rid for r in adm] == [r2.rid]
    assert pool.live == 4
    sched.finish(r1)
    sched.finish(r2)
    assert pool.live == 0
    pool.check_balance()
