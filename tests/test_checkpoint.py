"""Checkpoint durability + elastic resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.train import OptConfig, TrainConfig, checkpoint as CK, init_state, make_train_step

from _helpers import tiny

PC = ParallelContext()


def _trained_state(steps=3, fsdp=False):
    cfg = tiny(n_layers=4)
    plan = plan_range(cfg, 1, 3)
    ms = T.build_structure(cfg, plan=plan, tp=1, fsdp=fsdp, fsdp_data=1)
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=20))
    state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
    step = jax.jit(make_train_step(ms, PC, tc))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    for _ in range(steps):
        state, _ = step(state, batch)
    return cfg, ms, tc, state, batch


def test_roundtrip_exact(tmp_path):
    cfg, ms, tc, state, _ = _trained_state()
    logical = CK.state_to_logical(state, ms, PC)
    CK.save(str(tmp_path), logical, int(state["step"]))
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), logical)
    back = CK.restore(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(logical)):
        assert jnp.allclose(a, b)
    state2 = CK.logical_to_state(back, ms, PC, tc)
    for a, b in zip(jax.tree.leaves(state2["master"]),
                    jax.tree.leaves(state["master"])):
        assert jnp.allclose(a, b)


def test_restore_into_fsdp_layout(tmp_path):
    """Elastic mode change: a regular-layout checkpoint restores into an
    FSDP run (the 'scale up to the big slice' path)."""
    cfg, ms, tc, state, batch = _trained_state()
    logical = CK.state_to_logical(state, ms, PC)
    CK.save(str(tmp_path), logical, 3)

    ms_f = T.build_structure(cfg, plan=ms.plan, tp=1, fsdp=True, fsdp_data=1)
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), logical)
    back = CK.restore(str(tmp_path), like)
    state_f = CK.logical_to_state(back, ms_f, PC, tc)
    # the FSDP state must produce the SAME loss on the same batch
    from repro.train import make_eval_step
    m_r = jax.jit(make_eval_step(ms, PC, tc))(state["params"], batch)
    m_f = jax.jit(make_eval_step(ms_f, PC, tc))(state_f["params"], batch)
    assert jnp.allclose(m_r["loss"], m_f["loss"], atol=1e-4)
    # and round back out to identical logical content
    logical2 = CK.state_to_logical(state_f, ms_f, PC)
    for a, b in zip(jax.tree.leaves(logical2["master"]),
                    jax.tree.leaves(logical["master"])):
        assert jnp.allclose(a, b, atol=1e-6)


def test_latest_pointer_and_gc(tmp_path):
    cfg, ms, tc, state, _ = _trained_state()
    logical = CK.state_to_logical(state, ms, PC)
    ck = CK.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(logical, s)
    ck.wait()
    assert CK.latest_step(str(tmp_path)) == 30
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000020", "step_00000030"]


def test_corruption_detected(tmp_path):
    cfg, ms, tc, state, _ = _trained_state()
    logical = CK.state_to_logical(state, ms, PC)
    d = CK.save(str(tmp_path), logical, 5)
    # flip bytes in one leaf
    victim = os.path.join(d, "arr_00003.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    like = jax.tree.map(lambda x: np.zeros(x.shape, x.dtype), logical)
    with pytest.raises(AssertionError, match="corrupt"):
        CK.restore(str(tmp_path), like)


def test_interrupted_save_invisible(tmp_path):
    """A .tmp directory (crash mid-write) is never picked up by LATEST."""
    cfg, ms, tc, state, _ = _trained_state()
    logical = CK.state_to_logical(state, ms, PC)
    CK.save(str(tmp_path), logical, 5)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert CK.latest_step(str(tmp_path)) == 5
