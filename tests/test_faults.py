"""Fault isolation + deterministic chaos primitives.

A fault hitting one request may never perturb another: the victim lands in
a typed terminal state, its slot and pages come back, and every survivor's
token stream stays bit-identical to an undisturbed run. FaultPlan draws its
whole event schedule from one seed so any chaos outcome replays exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (ALL_FAULT_KINDS, FAILED, FINISHED,
                         BlockTableCorruptionError, FaultPlan,
                         NonFiniteLogitsError, PageAccountingError,
                         PagedEngine, PagedServeConfig, PagePool,
                         PoisonedPromptError, PrefixCache, ServeConfig,
                         generate)
from repro.serve import paged_cache as PG

from _helpers import tiny

PC = ParallelContext()
KEY = jax.random.PRNGKey(0)


def _build(n_layers=2):
    cfg = tiny(n_layers=n_layers)
    ms = T.build_structure(cfg, tp=1)
    return cfg, ms, T.init_params(ms, KEY)


def _psv(**kw):
    base = dict(n_slots=2, page_size=8, n_pages=9, max_len=32,
                cache_dtype=jnp.float32)
    base.update(kw)
    return PagedServeConfig(**base)


def _one_shot(params, ms, prompt, n_new):
    sv = ServeConfig(max_len=32, temperature=0.0, cache_dtype=jnp.float32)
    return np.asarray(generate(params, jnp.asarray(prompt)[None], n_new,
                               ms=ms, pc=PC, sv=sv)[0])


# ---------------------------------------------------------------------------
# FaultPlan: the whole schedule is a pure function of the seed
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_and_well_formed():
    a, b = FaultPlan(3, n_steps=100), FaultPlan(3, n_steps=100)
    assert a.events == b.events and len(a) == len(b) > 0
    assert FaultPlan(4, n_steps=100).events != a.events
    kinds = {e.kind for e in a.events}
    assert kinds == set(ALL_FAULT_KINDS)       # every kind scheduled
    for e in a.events:
        assert 5 <= e.step < 100               # inside [start, n_steps)
    # at(step) is a pure lookup over the same events.
    from_at = [e for s in range(100) for e in a.at(s)]
    assert sorted(from_at, key=lambda e: (e.step, e.kind, e.index)) == \
        sorted(a.events, key=lambda e: (e.step, e.kind, e.index))


def test_fault_plan_rejects_bad_config():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(0, kinds=("not_a_kind",))
    with pytest.raises(ValueError, match="horizon"):
        FaultPlan(0, n_steps=6, per_kind=5, start=5)  # 1 step, 5 draws


# ---------------------------------------------------------------------------
# PagePool abuse: typed, pre-mutation, balance stays green
# ---------------------------------------------------------------------------

def test_pool_double_free_is_typed_and_non_destructive():
    pool = PagePool(4)
    (p,) = pool.alloc(1)
    pool.free([p])
    with pytest.raises(PageAccountingError, match="double-free past zero"):
        pool.free([p])
    pool.check_balance()                       # abuse mutated NOTHING
    assert pool.n_free == 3


def test_pool_rejects_foreign_and_garbage_pages():
    pool = PagePool(4)
    with pytest.raises(PageAccountingError, match="out-of-range"):
        pool.free([99])
    with pytest.raises(PageAccountingError, match="garbage page"):
        pool.share([PG.GARBAGE_PAGE])
    pool.check_balance()


def test_pool_batch_abuse_is_atomic():
    # A batch mixing valid and invalid refs must mutate nothing at all:
    # validation is multiplicity-aware and runs before any refcount moves.
    pool = PagePool(4)
    (p,) = pool.alloc(1)                       # refcount 1
    with pytest.raises(PageAccountingError, match="exceeds its refcount"):
        pool.free([p, p])                      # x2 against refcount 1
    assert pool.refcount(p) == 1               # the valid half not applied
    pool.check_balance()


def test_pool_alloc_fault_injection_counts_and_recovers():
    pool = PagePool(4)
    pool.fail_next_allocs(2)
    assert pool.alloc(1) is None and pool.alloc(1) is None
    assert pool.alloc_faults == 2
    got = pool.alloc(2)                        # recovered
    assert got is not None and len(got) == 2
    pool.check_balance()


def test_engine_rides_through_alloc_failure():
    # A refused allocation leaves the request QUEUED (admission rolls
    # back), accounting balanced, and the eventual run bit-identical.
    cfg, ms, params = _build()
    eng = PagedEngine(params, ms, _psv(n_slots=1, n_pages=5))
    prompt = np.asarray(jax.random.randint(KEY, (8,), 0, cfg.vocab_size))
    rid = eng.add_request(prompt, 8)
    eng.pool.fail_next_allocs(1)
    eng.step()
    assert eng.pool.alloc_faults == 1
    assert eng.sched.n_running == 0 and eng.sched.n_queued == 1
    res = eng.drain()
    assert eng.request(rid).state == FINISHED
    assert (res[rid] == _one_shot(params, ms, prompt, 8)).all()
    assert eng.pool.live == 0


# ---------------------------------------------------------------------------
# NaN containment: victim fails typed, survivor stays bit-identical
# ---------------------------------------------------------------------------

def test_nan_poisoned_slot_fails_survivor_bit_identical():
    cfg, ms, params = _build()
    key = jax.random.PRNGKey(5)
    pa = np.asarray(jax.random.randint(jax.random.fold_in(key, 0), (8,),
                                       0, cfg.vocab_size))
    pb = np.asarray(jax.random.randint(jax.random.fold_in(key, 1), (8,),
                                       0, cfg.vocab_size))

    eng = PagedEngine(params, ms, _psv())
    ra, rb = eng.add_request(pa, 12), eng.add_request(pb, 12)
    eng.step()                                 # both running
    eng.step()
    victim = eng.request(ra)
    eng._poison_slots.add(victim.slot)         # what NAN_LOGITS injects
    eng.step()
    assert victim.state == FAILED
    assert isinstance(victim.error, NonFiniteLogitsError)
    eng.pool.check_balance()

    res = eng.drain()
    assert eng.request(rb).state == FINISHED
    assert (res[rb] == _one_shot(params, ms, pb, 12)).all()
    # The victim's pre-fault tokens are the true greedy prefix.
    ref_a = _one_shot(params, ms, pa, 12)
    assert (res[ra] == ref_a[:len(res[ra])]).all()
    assert len(res[ra]) < 12

    # The poisoned slot is clean for reuse: a new request through the SAME
    # engine (and likely the same slot) still matches one-shot.
    rc = eng.add_request(pa, 12)
    res2 = eng.drain()
    assert (res2[rc] == ref_a).all()
    assert eng.pool.live == 0


def test_block_table_corruption_detected_and_contained():
    cfg, ms, params = _build()
    key = jax.random.PRNGKey(6)
    pa = np.asarray(jax.random.randint(jax.random.fold_in(key, 0), (8,),
                                       0, cfg.vocab_size))
    pb = np.asarray(jax.random.randint(jax.random.fold_in(key, 1), (8,),
                                       0, cfg.vocab_size))
    eng = PagedEngine(params, ms, _psv())
    ra, rb = eng.add_request(pa, 12), eng.add_request(pb, 12)
    eng.step()
    victim = eng.request(ra)
    # What BLOCK_TABLE_CORRUPT injects: a host-side row no longer matching
    # the scheduler's page ownership record.
    eng.block_tables[victim.slot, 0] = (eng.block_tables[victim.slot, 0]
                                        + 1) % eng.psv.n_pages
    eng.step()                                 # validation pass catches it
    assert victim.state == FAILED
    assert isinstance(victim.error, BlockTableCorruptionError)
    eng.pool.check_balance()
    res = eng.drain()
    assert eng.request(rb).state == FINISHED
    assert (res[rb] == _one_shot(params, ms, pb, 12)).all()
    assert eng.pool.live == 0


def test_poisoned_prompt_fails_at_prefill_not_the_engine():
    cfg, ms, params = _build()
    key = jax.random.PRNGKey(8)
    pa = np.asarray(jax.random.randint(jax.random.fold_in(key, 0), (8,),
                                       0, cfg.vocab_size))
    pb = np.asarray(jax.random.randint(jax.random.fold_in(key, 1), (8,),
                                       0, cfg.vocab_size))
    eng = PagedEngine(params, ms, _psv())
    ra = eng.add_request(pa, 8)
    rb = eng.add_request(pb, 8)
    # What POISON_PROMPT injects: corrupt the QUEUED copy after the submit
    # boundary already validated it (an embed-table OOB read otherwise).
    victim = eng.request(ra)
    victim.prompt = victim.prompt.copy()
    victim.prompt[3] = cfg.vocab_size + 2
    res = eng.drain()
    assert eng.request(ra).state == FAILED
    assert isinstance(eng.request(ra).error, PoisonedPromptError)
    assert len(res[ra]) == 0
    assert eng.request(rb).state == FINISHED
    assert (res[rb] == _one_shot(params, ms, pb, 8)).all()
    assert eng.pool.live == 0


# ---------------------------------------------------------------------------
# Radix containment: purge_pages drops suspect subtrees, skips locked ones
# ---------------------------------------------------------------------------

def test_purge_pages_drops_subtree_and_refunds_pool():
    ps = 2
    pool = PagePool(8)
    tree = PrefixCache(ps)
    toks = np.arange(6, dtype=np.int32)        # 3 chunks
    pages = list(pool.alloc(3))
    assert tree.insert(toks, pages, step=0) == pages
    assert tree.resident_pages == 3
    # Purging the MIDDLE page drops it and everything donated beyond it.
    freed = tree.purge_pages([pages[1]], pool)
    assert freed == 2 and tree.resident_pages == 1
    pool.check_balance()
    assert pool.live == 1                      # only the untainted root


def test_purge_pages_skips_locked_subtrees():
    ps = 2
    pool = PagePool(8)
    tree = PrefixCache(ps)
    toks = np.arange(4, dtype=np.int32)        # 2 chunks
    pages = list(pool.alloc(2))
    tree.insert(toks, pages, step=0)
    path = tree.match(toks, max_pages=2, step=1)
    tree.lock_path(path, pool, step=1)         # a running request's pins
    assert tree.purge_pages([pages[0]], pool) == 0
    assert tree.resident_pages == 2            # untouched while pinned
    tree.release_path(path, pool)
    assert tree.purge_pages([pages[0]], pool) == 2
    assert pool.live == 0
    pool.check_balance()
