"""Fault tolerance: injected node failure mid-run; supervision resumes from
the latest checkpoint and reaches the same final state as an uninterrupted
run (bitwise, thanks to the step-pure data pipeline)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.elastic import InjectedFailure, failing_hook, supervise
from repro.launch.train import RunConfig, train_loop


def _rc(tmp_path, steps=24):
    return RunConfig(arch="tinyllama-1.1b", n_layers=2, eff_depth=1,
                     steps=steps, seq_len=32, global_batch=4,
                     lr=1e-3, warmup=2, ckpt_dir=str(tmp_path),
                     ckpt_every=8, log_every=100)


def test_failure_then_resume_matches_clean_run(tmp_path):
    clean = train_loop(_rc(tmp_path / "clean"))

    rc = _rc(tmp_path / "faulty")
    with pytest.raises(InjectedFailure):
        train_loop(rc, hook=failing_hook(13))  # dies between ckpts 8 and 16
    resumed = train_loop(rc)  # picks up from step 8 automatically

    for a, b in zip(jax.tree.leaves(clean["state"]["params"]),
                    jax.tree.leaves(resumed["state"]["params"])):
        assert jnp.allclose(a, b, atol=1e-6), "resume diverged from clean run"


def test_supervise_bounded_retries(tmp_path):
    rc = _rc(tmp_path)
    calls = {"n": 0}

    def flaky(step, metrics):
        if calls["n"] < 2 and step == 10:
            calls["n"] += 1
            raise InjectedFailure("boom")

    out = supervise(rc, max_restarts=3, hook=flaky)
    assert int(out["state"]["step"]) == rc.steps
    assert calls["n"] == 2  # failed twice, finished on the third attempt


def test_supervise_gives_up(tmp_path):
    rc = _rc(tmp_path)

    def always(step, metrics):
        if step == 10:
            raise InjectedFailure("persistent")

    with pytest.raises(RuntimeError, match="giving up"):
        supervise(rc, max_restarts=2, hook=always)
