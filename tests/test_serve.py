"""Serving correctness: prefill+decode == full recompute, per family."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import pytest

from _helpers import run_multidevice

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import ServeConfig, generate

PC = ParallelContext()


def _setup(arch, lp=True):
    cfg = reduced_config(get_config(arch), n_layers=4 if arch != "recurrentgemma-9b" else 6)
    if cfg.moe_experts:  # capacity drops would break exact prefill/decode equality
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    plan = plan_range(cfg, 0, cfg.n_layers) if lp else None
    ms = T.build_structure(cfg, plan=plan, tp=1)
    params = T.init_params(ms, jax.random.PRNGKey(0))
    extras = {}
    if cfg.prefix_len:
        extras["prefix"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(5), (2, cfg.prefix_len, cfg.d_model))
    if cfg.enc_layers:
        extras["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(6), (2, cfg.enc_seq, cfg.d_model))
    return cfg, ms, params, extras


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg, ms, params, extras = _setup(arch)
    S = 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0, cfg.vocab_size)
    S_tot = S + (cfg.prefix_len or 0)
    pl_logits, caches = T.prefill(params, toks, ms=ms, pc=PC,
                                  max_len=S_tot + 4,
                                  prefix_embed=extras.get("prefix"),
                                  enc_frames=extras.get("frames"),
                                  cache_dtype=jnp.float32)
    full, _, _ = T.forward_full(params, toks, ms=ms, pc=PC,
                                prefix_embed=extras.get("prefix"),
                                enc_frames=extras.get("frames"))
    assert jnp.allclose(pl_logits, full[:, -1], atol=2e-3), \
        f"{arch} prefill mismatch {float(jnp.abs(pl_logits - full[:, -1]).max())}"

    nxt = jnp.argmax(pl_logits, -1).astype(jnp.int32)
    d_logits, _ = T.decode_step(params, nxt, caches, jnp.int32(S_tot),
                                ms=ms, pc=PC)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    full2, _, _ = T.forward_full(params, toks2, ms=ms, pc=PC,
                                 prefix_embed=extras.get("prefix"),
                                 enc_frames=extras.get("frames"))
    err = float(jnp.abs(d_logits - full2[:, -1]).max())
    assert err < 2e-3, f"{arch} decode mismatch {err}"


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "falcon-mamba-7b",
                                  "recurrentgemma-9b"])
def test_generate_greedy_matches_recompute(arch):
    cfg, ms, params, extras = _setup(arch)
    sv = ServeConfig(max_len=48, temperature=0.0, cache_dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab_size)
    out = generate(params, prompts, 6, ms=ms, pc=PC, sv=sv,
                   prefix=extras.get("prefix"), frames=extras.get("frames"))
    toks = prompts
    for _ in range(6):
        lg, _, _ = T.forward_full(params, toks, ms=ms, pc=PC,
                                  prefix_embed=extras.get("prefix"),
                                  enc_frames=extras.get("frames"))
        toks = jnp.concatenate(
            [toks, jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]], 1)
    assert bool((toks[:, 8:] == out).all()), arch


def test_ring_buffer_window_decode():
    """Sliding-window cache reuses a ring: decoding past the window must
    match the full recompute."""
    cfg = reduced_config(get_config("recurrentgemma-9b"), n_layers=3)
    ms = T.build_structure(cfg, tp=1)
    params = T.init_params(ms, jax.random.PRNGKey(0))
    W = cfg.window
    S = W + 4  # prompt longer than the window
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    _, caches = T.prefill(params, toks, ms=ms, pc=PC, max_len=S + 8,
                          cache_dtype=jnp.float32)
    nxt = jnp.array([7], jnp.int32)
    d_logits, _ = T.decode_step(params, nxt, caches, jnp.int32(S), ms=ms, pc=PC)
    toks2 = jnp.concatenate([toks, nxt[:, None]], 1)
    full2, _, _ = T.forward_full(params, toks2, ms=ms, pc=PC)
    assert jnp.allclose(d_logits, full2[:, -1], atol=2e-3)


def test_temperature_sampling_valid():
    cfg, ms, params, extras = _setup("tinyllama-1.1b")
    sv = ServeConfig(max_len=32, temperature=1.0, cache_dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0,
                                 cfg.vocab_size)
    out = generate(params, prompts, 8, ms=ms, pc=PC, sv=sv,
                   key=jax.random.PRNGKey(11))
    assert out.shape == (4, 8)
    assert bool(((out >= 0) & (out < cfg.vocab_size)).all())
    out2 = generate(params, prompts, 8, ms=ms, pc=PC, sv=sv,
                    key=jax.random.PRNGKey(11))
    assert bool((out == out2).all()), "sampling must be key-deterministic"


def test_sampling_key_sensitivity():
    """generate() with temperature > 0: same key => identical tokens,
    different keys => the sequences differ somewhere."""
    cfg, ms, params, _ = _setup("tinyllama-1.1b")
    sv = ServeConfig(max_len=40, temperature=1.0, cache_dtype=jnp.float32)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (4, 8), 0,
                                 cfg.vocab_size)
    a = generate(params, prompts, 12, ms=ms, pc=PC, sv=sv,
                 key=jax.random.PRNGKey(21))
    a2 = generate(params, prompts, 12, ms=ms, pc=PC, sv=sv,
                  key=jax.random.PRNGKey(21))
    b = generate(params, prompts, 12, ms=ms, pc=PC, sv=sv,
                 key=jax.random.PRNGKey(22))
    assert bool((a == a2).all())
    assert not bool((a == b).all()), \
        "different keys must change at least one sampled token"


@pytest.mark.slow
def test_vocab_parallel_sample_matches_gather_reference():
    """Gumbel-max over the SHARDED vocabulary == gathering the full logits
    and sampling on one device (each rank's gumbels reproduced by folding
    the key with its rank index)."""
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, json
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map
from repro.model import embedding as E
from repro.parallel.context import make_context

tp = 8
mesh = jax.make_mesh((1, tp), ("data", "model"))
pc = make_context(mesh)
B, V = 4, 64
key = jax.random.PRNGKey(7)
logits = jax.random.normal(jax.random.PRNGKey(3), (B, V), jnp.float32) * 3.0
temp = 0.7

fn = shard_map(lambda lg: E.vocab_parallel_sample(lg, key, temp, pc),
               mesh=mesh, in_specs=(P(None, "model"),), out_specs=P(None),
               check_vma=False)
toks = jax.jit(fn)(logits)

# Gather-then-sample reference: concatenate the per-rank gumbel draws
# (key folded with the rank) into the full-vocab noise vector, then argmax.
Vl = V // tp
g = jnp.concatenate([jax.random.gumbel(jax.random.fold_in(key, r), (B, Vl),
                                       jnp.float32) for r in range(tp)], -1)
ref = jnp.argmax(logits / temp + g, axis=-1).astype(jnp.int32)
print("RESULT " + json.dumps({"toks": toks.tolist(), "ref": ref.tolist()}))
""")
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT")][0][7:])
    assert res["toks"] == res["ref"], res
