"""Trainer semantics on CPU: overfit, schedules, masks, clipping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.train import (OptConfig, TrainConfig, init_state, make_eval_step,
                         make_train_step)
from repro.train.optimizer import schedule_lr

from _helpers import tiny

PC = ParallelContext()


def _fixture(lp=True, **tc_kw):
    cfg = tiny(n_layers=4)
    plan = plan_range(cfg, 1, 3) if lp else None
    ms = T.build_structure(cfg, plan=plan, tp=1)
    tc = TrainConfig(**tc_kw)
    state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
    return cfg, ms, tc, state


def test_overfit_fixed_batch():
    cfg, ms, tc, state = _fixture(
        opt=OptConfig(lr=3e-3, warmup_steps=2, total_steps=40), accum=2)
    step = jax.jit(make_train_step(ms, PC, tc))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    first = last = None
    for _ in range(30):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first - 2.0, (first, last)


def test_accum_equals_large_batch():
    """accum=4 over a batch == accum=1 on the same batch (same mean grads)."""
    cfg, ms, _, _ = _fixture()
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    outs = []
    for accum in (1, 4):
        tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1,
                                       total_steps=10), accum=accum)
        state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
        state, m = jax.jit(make_train_step(ms, PC, tc))(state, batch)
        outs.append(state["params"]["embed"]["tok"])
    assert jnp.allclose(outs[0], outs[1], atol=1e-5)


def test_finetune_lp_only_freezes_rest():
    cfg, ms, tc, state = _fixture(
        opt=OptConfig(lr=1e-2, warmup_steps=0, total_steps=10,
                      weight_decay=0.0),
        finetune_lp_only=True)
    p0 = jax.tree.map(lambda x: x.copy(), state["params"])
    step = jax.jit(make_train_step(ms, PC, tc))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    state, _ = step(state, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)})
    pair_idx = [i for i, s in enumerate(ms.segments) if s.group.pair]
    other_idx = [i for i, s in enumerate(ms.segments) if not s.group.pair]
    assert float(jnp.abs(state["params"]["embed"]["tok"]
                         - p0["embed"]["tok"]).max()) == 0.0
    moved = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(state["params"]["segments"][pair_idx[0]]),
        jax.tree.leaves(p0["segments"][pair_idx[0]])))
    frozen = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(state["params"]["segments"][other_idx[0]]),
        jax.tree.leaves(p0["segments"][other_idx[0]])))
    assert moved > 0 and frozen == 0.0


def test_wsd_schedule_shape():
    opt = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="wsd",
                    decay_frac=0.2)
    lrs = [float(schedule_lr(opt, s)) for s in range(100)]
    assert lrs[0] == pytest.approx(0.1)       # warmup start
    assert lrs[9] == pytest.approx(1.0)       # warmup end
    assert lrs[50] == pytest.approx(1.0)      # stable
    assert lrs[99] <= 0.06                     # decayed (1 - 19/20 + eps)
    # monotone decay in the final phase
    assert all(a >= b for a, b in zip(lrs[80:], lrs[81:]))


def test_grad_clip_activates():
    cfg, ms, _, _ = _fixture()
    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=0, total_steps=10,
                                   grad_clip=1e-8))
    state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
    p0 = state["params"]["embed"]["tok"].copy()
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    state, m = jax.jit(make_train_step(ms, PC, tc))(
        state, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)})
    # grad contribution ~1e-8-scaled: master moves only by the wd-free Adam
    # step on a clipped grad; update magnitude ~ lr regardless, but the
    # DIRECTION is the clipped grad; just assert the norm was recorded > clip.
    assert float(m["grad_norm"]) > 1e-6


def test_eval_step():
    cfg, ms, tc, state = _fixture(opt=OptConfig())
    ev = jax.jit(make_eval_step(ms, PC, tc))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    m = ev(state["params"], {"tokens": toks, "labels": jnp.roll(toks, -1, 1)})
    assert bool(jnp.isfinite(m["loss"]))


def test_masked_labels_ignored():
    """labels=-1 positions contribute nothing to the loss."""
    cfg, ms, tc, state = _fixture(opt=OptConfig())
    ev = jax.jit(make_eval_step(ms, PC, tc))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    labels = jnp.roll(toks, -1, 1)
    m1 = ev(state["params"], {"tokens": toks, "labels": labels})
    # mask half the positions; recompute expected mean over the kept half
    mask = jnp.arange(16)[None, :] % 2 == 0
    labels2 = jnp.where(mask, labels, -1)
    m2 = ev(state["params"], {"tokens": toks, "labels": labels2})
    assert not jnp.allclose(m1["xent"], m2["xent"])
    assert bool(jnp.isfinite(m2["xent"]))
