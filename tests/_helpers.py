"""Shared test utilities."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config


def tiny(arch="tinyllama-1.1b", n_layers=4):
    return reduced_config(get_config(arch), n_layers=n_layers)


def rand_tokens(key, batch, seq, vocab):
    return jax.random.randint(key, (batch, seq), 0, vocab)


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run ``code`` in a subprocess with n host devices; return stdout.
    Raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout
