"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
config of the same family and runs one forward + one train step on CPU,
with and without LP, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced_config
from repro.core.lp import EMPTY_PLAN, plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.train import OptConfig, TrainConfig, init_state, make_train_step

PC = ParallelContext()


def _batch(cfg, key, B=2, S=24):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1).at[:, -1].set(-1)}
    if cfg.prefix_len:
        batch["prefix"] = 0.02 * jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model))
    if cfg.enc_layers:
        batch["frames"] = 0.02 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("lp", [False, True], ids=["vanilla", "lp"])
def test_forward_and_train_step(arch, lp):
    cfg = reduced_config(get_config(arch))
    plan = plan_range(cfg, 0, cfg.n_layers) if lp else EMPTY_PLAN
    if lp and not plan.pairs:
        pytest.skip("no pairable layers at this reduced depth")
    ms = T.build_structure(cfg, plan=plan, tp=1)
    key = jax.random.PRNGKey(0)
    params = T.init_params(ms, key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux, _ = T.forward_full(params, batch["tokens"], ms=ms, pc=PC,
                                    prefix_embed=batch.get("prefix"),
                                    enc_frames=batch.get("frames"))
    S_total = batch["tokens"].shape[1] + (cfg.prefix_len or 0)
    vp = -(-cfg.vocab_size // 1)
    assert logits.shape == (2, S_total, vp)
    assert bool(jnp.isfinite(logits).all()), f"{arch} logits not finite"

    tc = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    state = init_state(ms, key, PC, tc)
    step = make_train_step(ms, PC, tc)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch} loss not finite"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_registry(arch):
    """The FULL config matches the assignment's published numbers."""
    cfg = get_config(arch)
    expect = {
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expect, f"{arch}: {got} != {expect}"


def test_moe_configs():
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.moe_experts, l4.moe_top_k, l4.moe_shared_expert) == (16, 1, True)
    dbrx = get_config("dbrx-132b")
    assert (dbrx.moe_experts, dbrx.moe_top_k) == (16, 4)


def test_ssm_config():
    fm = get_config("falcon-mamba-7b")
    assert fm.ssm_state == 16 and fm.d_inner == 8192
