"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(see requirements-dev.txt); skipping property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.analysis.roofline import collective_bytes
from repro.core.lp import LPPlan, plan_for_depth, plan_range
from repro.model.embedding import vocab_pad
from repro.model.rope import apply_rope
from repro.parallel.compress import compress_psum
from repro.parallel.zero import flatten_leaf, unflatten_leaf
from repro.configs import get_config, ASSIGNED_ARCHS

SET = settings(max_examples=25, deadline=None)


@SET
@given(st.integers(1, 4).map(lambda n: 2 ** n),
       st.lists(st.integers(1, 7), min_size=1, max_size=3))
def test_zero_flatten_roundtrip(dp, dims):
    """flatten_leaf -> unflatten_leaf is the identity for any shape/dp."""
    shape = tuple(dims)
    x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
    flat = flatten_leaf(jnp.asarray(x), dp)
    assert flat.shape[0] == dp
    back = unflatten_leaf(flat, shape, jnp.float32)
    assert np.allclose(back, x)


@SET
@given(st.integers(2, 64), st.integers(1, 32))
def test_vocab_pad_divisible(v, tp):
    vp = vocab_pad(v, tp)
    assert vp % tp == 0 and 0 <= vp - v < tp


@SET
@given(st.integers(0, 500), st.integers(2, 16).map(lambda x: 2 * x))
def test_rope_preserves_norm(pos, hd):
    """Rotation preserves the per-head L2 norm for any position."""
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 1, 2, hd)),
                    jnp.float32)
    y = apply_rope(x, jnp.array([[pos]]), 10_000.0)
    assert jnp.allclose(jnp.linalg.norm(y, axis=-1),
                        jnp.linalg.norm(x, axis=-1), rtol=1e-4)


@SET
@given(st.integers(0, 2**31 - 1))
def test_rope_relative(seed):
    """<q_m, k_n> depends only on m - n (the defining RoPE property)."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, 16)), jnp.float32)
    m, n, d = rng.integers(0, 100, 3)

    def score(a, b, pa, pb):
        qa = apply_rope(a, jnp.array([[int(pa)]]), 1e4)
        kb = apply_rope(b, jnp.array([[int(pb)]]), 1e4)
        return float(jnp.sum(qa * kb))

    assert score(q, k, m, n) == pytest.approx(score(q, k, m + d, n + d),
                                              rel=1e-3, abs=1e-3)


@SET
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 100.0))
def test_compress_error_bound(seed, scale_mag):
    """One int8 quantised reduction: |err| <= scale/2 elementwise and the
    dequantised value + error reconstructs the input exactly."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(64,)) * scale_mag, jnp.float32)
    out, err = compress_psum(g, (), None)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(err))) <= scale / 2 + 1e-6
    assert jnp.allclose(out + err, g, atol=1e-5 * scale_mag)


@SET
@given(st.integers(0, 2**31 - 1))
def test_compress_error_feedback_converges(seed):
    """Repeatedly reducing the SAME gradient with error feedback: the
    running average of outputs converges to the true value."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
    err = None
    acc = jnp.zeros_like(g)
    n = 20
    for _ in range(n):
        out, err = compress_psum(g, (), err)
        acc = acc + out
    assert jnp.allclose(acc / n, g, atol=1e-2)


@SET
@given(st.sampled_from(ASSIGNED_ARCHS), st.integers(0, 12))
def test_plan_for_depth_invariants(arch, reduction):
    cfg = get_config(arch)
    target = cfg.n_layers - reduction
    try:
        plan = plan_for_depth(cfg, target)
    except AssertionError:
        return  # more pairs requested than compatibility allows — rejected
    assert plan.effective_depth(cfg.n_layers) == min(target, cfg.n_layers)
    layers = plan.paired_layers()
    assert len(layers) == 2 * len(plan.pairs)  # no overlaps


@SET
@given(st.integers(1, 30), st.integers(0, 29), st.integers(0, 29))
def test_plan_range_no_overlap(n, a, b):
    s, e = min(a, b), max(a, b) + 1
    cfg = get_config("yi-6b")
    plan = plan_range(cfg, min(s, cfg.n_layers), min(e, cfg.n_layers))
    seen = set()
    for i, j in plan.pairs:
        assert j == i + 1
        assert i not in seen and j not in seen
        seen.update((i, j))


def test_collective_parser_on_synthetic_hlo():
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256] %x), replica_groups={{0,1,2,3}}
  %ag = bf16[64]{0} all-gather(bf16[16] %y), replica_groups=[2,8]<=[16]
  %rs = f32[32]{0} reduce-scatter(f32[128] %z), replica_groups={{0,1,2,3}}
"""
    out = collective_bytes(hlo)
    assert out["count:all-reduce"] == 1
    assert out["all-reduce"] == pytest.approx(2 * 128 * 256 * 4 * 3 / 4)
    assert out["all-gather"] == pytest.approx(64 * 2 * 7 / 8)
    assert out["reduce-scatter"] == pytest.approx(32 * 4 * 3)


@SET
@given(st.integers(0, 2),
       st.lists(st.tuples(st.booleans(), st.integers(1, 9)),
                min_size=1, max_size=20))
def test_spec_rewind_page_accounting(n_shared, ops):
    """Speculative rewind vs the page pool: any interleaving of horizon
    extensions (draft/verify writes claiming pages) and rewinds (rejected
    drafts un-written, fully-rewound pages returned) keeps the refcount
    ledger balanced, never frees a radix-shared page, and accounts every
    stale position exactly once."""
    from repro.serve import PagePool, rewind_plan

    ps = 4
    pool = PagePool(n_pages=64)
    shared = pool.alloc(n_shared) if n_shared else []
    if shared:
        pool.share(shared)        # tree residency + the running request
    pages = list(shared)
    ln = n_shared * ps            # written horizon (tokens)
    for grow, amount in ops:
        if grow:
            new = min(ln + amount, 30 * ps)
            need = -(-new // ps) - len(pages)
            if need > 0:
                pages += pool.alloc(need)
            ln = new
        else:
            new = max(ln - amount, n_shared * ps)
            zero, free = rewind_plan(pages, n_shared, new, ln, ps)
            assert len(zero) == ln - new          # every stale position
            assert all(p in pages for p, _ in zero)
            assert not set(free) & set(shared)    # shared never freed
            pool.free_rewound(free)
            pages = pages[:len(pages) - len(free)]
            ln = new
        pool.check_balance()
        # Radix-shared pages stay pinned at refcount 2 throughout.
        assert pool.shared == len(set(shared))
    if shared:
        with pytest.raises(Exception):
            pool.free_rewound(shared)             # still doubly held
        pool.check_balance()                      # refusal left no trace
    pool.free(pages)                              # request releases
    if shared:
        pool.free(shared)                         # tree releases
    pool.check_balance()
    assert pool.live == 0
