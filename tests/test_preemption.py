"""Preemptive scheduling over the refcounted page pool.

The contract under test is acceptance gate (b): a preempted-then-resumed
request produces EXACTLY the tokens of the same request run uninterrupted.
The engine achieves that without cross-shape numerics: surviving donated
pages keep the ORIGINAL kv bits, the missing prompt tail re-runs the
suffix/full prefill at the original reduction shape, and parked generated
positions are replayed through the SAME decode program that produced them
(the engine asserts each replayed prediction reproduces the parked token).
Scheduler-level tests pin the trigger/victim/requeue mechanics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (PagedEngine, PagedServeConfig, PagePool,
                         PrefixCache, Scheduler, ServeConfig, generate)

PC = ParallelContext()
KEY = jax.random.PRNGKey(0)
PS = 8


def _build(n_layers=4, arch="tinyllama-1.1b"):
    cfg = reduced_config(get_config(arch), n_layers=n_layers)
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, n_layers), tp=1)
    return cfg, ms, T.init_params(ms, KEY)


def _one_shot(params, ms, prompt, n_new, max_len):
    sv = ServeConfig(max_len=max_len, temperature=0.0,
                     cache_dtype=jnp.float32)
    return np.asarray(generate(params, jnp.asarray(prompt)[None], n_new,
                               ms=ms, pc=PC, sv=sv)[0])


def _prompt(i, n, vocab):
    return np.asarray(jax.random.randint(jax.random.fold_in(KEY, 50 + i),
                                         (n,), 0, vocab))


# ---------------------------------------------------------------------------
# Scheduler mechanics
# ---------------------------------------------------------------------------

def test_scheduler_preempts_youngest_after_k_blocked_rounds():
    pool = PagePool(9)          # 8 allocatable
    sched = Scheduler(n_slots=4, pool=pool, page_size=8, max_len=32,
                      preempt_after=3)
    r0 = sched.submit(np.zeros(8, np.int32), 24)   # 4 pages
    r1 = sched.submit(np.zeros(8, np.int32), 24)   # 4 pages -> pool full
    assert len(sched.admit(0)) == 2
    r2 = sched.submit(np.zeros(8, np.int32), 8)    # 2 pages -> blocked
    for step in (1, 2):
        assert sched.admit(step) == []
        assert not sched.should_preempt()
    assert sched.admit(3) == [] and sched.should_preempt()
    victim, slot = sched.preempt_youngest(3)
    assert victim is r1 and slot == 1   # r0 took slot 0, r1 slot 1
    assert victim.status == "queued" and victim.pages == []
    # Re-queued BEHIND the blocked head: head admits first.
    assert [r.rid for r in sched.queue] == [r2.rid, r1.rid]
    adm = sched.admit(4)
    assert adm and adm[0] is r2
    assert sched.head_blocked == 0
    pool.check_balance()


def test_scheduler_preempt_donates_whole_written_pages():
    pool = PagePool(9)
    tree = PrefixCache(page_size=8)
    sched = Scheduler(n_slots=2, pool=pool, page_size=8, max_len=32,
                      prefix_cache=tree, preempt_after=1)
    r = sched.submit(np.arange(12, dtype=np.int32), 20)   # 4 pages
    sched.admit(0)
    r.out.extend([7, 8, 9, 10, 11])     # pretend 5 decoded tokens
    # written kv = 12 + 5 - 1 = 16 positions = 2 whole pages donated
    victim, _ = sched.preempt_youngest(1)
    assert victim is r
    assert tree.resident_pages == 2
    assert pool.live == 2               # the other 2 pages were released
    # Resume: the match hits its own donated pages (prompt + generated) —
    # the generated-range node is flagged decode_written, so only the
    # resume-style match (include_decode_written) reaches it; a fresh
    # match stops at the prompt-range node.
    path = tree.match(r.seq_tokens, max_pages=8, step=2,
                      include_decode_written=True)
    assert len(path) == 2 and path[1].decode_written
    assert len(tree.match(r.seq_tokens, max_pages=8, step=2)) == 1
    pool.check_balance()


def test_scheduler_requeue_goes_behind_head_even_when_queue_longer():
    pool = PagePool(5)
    sched = Scheduler(n_slots=2, pool=pool, page_size=8, max_len=16,
                      preempt_after=1)
    r0 = sched.submit(np.zeros(8, np.int32), 8)   # 2 pages
    r1 = sched.submit(np.zeros(8, np.int32), 8)
    sched.admit(0)                                # both admitted, pool full
    r2 = sched.submit(np.zeros(8, np.int32), 8)
    r3 = sched.submit(np.zeros(8, np.int32), 8)
    sched.admit(1)
    victim, _ = sched.preempt_youngest(1)
    assert victim is r1
    assert [r.rid for r in sched.queue] == [r2.rid, r1.rid, r3.rid]


# ---------------------------------------------------------------------------
# Engine end-to-end bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefix_cache", [True, False])
def test_preempted_request_matches_uninterrupted_run(prefix_cache):
    """(b) of the acceptance gate, with and without the radix cache: with
    it, resume radix-hits the preemption donation (cheap); without it,
    resume re-prefills from scratch — both must be bit-identical to the
    uninterrupted run (the engine additionally self-checks every replayed
    token against the parked one)."""
    cfg, ms, params = _build()
    psv = PagedServeConfig(n_slots=4, page_size=PS, n_pages=9, max_len=32,
                           cache_dtype=jnp.float32,
                           prefix_cache=prefix_cache, preempt_after=2)
    eng = PagedEngine(params, ms, psv)
    pa, pb, pc_ = (_prompt(i, 8, cfg.vocab_size) for i in range(3))
    ra = eng.add_request(pa, 20)       # 4 pages each: two fill the pool
    rb = eng.add_request(pb, 20)
    for _ in range(4):
        eng.step()
    rc = eng.add_request(pc_, 4)       # blocks -> preempts the youngest
    res = eng.drain()
    assert eng.sched.preemptions_total >= 1
    assert eng.counters["replay_tokens"] > 0
    for rid, (p, n) in zip((ra, rb, rc), [(pa, 20), (pb, 20), (pc_, 4)]):
        ref = _one_shot(params, ms, p, n, psv.max_len)
        assert (res[rid] == ref).all(), (prefix_cache, rid)
    # Everything the tree does not hold drained back to the free list.
    resident = eng.prefix.resident_pages if eng.prefix else 0
    assert eng.pool.live == resident
    eng.pool.check_balance()


def test_preemption_unblocks_short_request_behind_long_head():
    """Head-of-line removal: a short request stuck behind page-hogging
    long decodes gets served long before they finish."""
    cfg, ms, params = _build()
    psv = PagedServeConfig(n_slots=4, page_size=PS, n_pages=9, max_len=64,
                           cache_dtype=jnp.float32, preempt_after=2)
    eng = PagedEngine(params, ms, psv)
    long_a = eng.add_request(_prompt(0, 8, cfg.vocab_size), 48)  # 7 pages
    eng.step()
    short = eng.add_request(_prompt(1, 8, cfg.vocab_size), 4)    # 2 pages
    short_done = None
    for _ in range(40):
        eng.step()
        if short in eng.results and short_done is None:
            short_done = eng.step_count
    assert short_done is not None, "short request starved"
    assert long_a not in eng.results or \
        eng.request(long_a).finished_step >= short_done
    eng.drain()
    ref = _one_shot(params, ms, eng.request(short).prompt, 4, psv.max_len)
    assert (eng.results[short] == ref).all()


def test_preemption_cascade_converges_and_stays_exact():
    """Repeated preemptions (several victims, repeated resumes) must
    converge — no livelock — and keep every request exact."""
    cfg, ms, params = _build()
    psv = PagedServeConfig(n_slots=4, page_size=PS, n_pages=9, max_len=32,
                           cache_dtype=jnp.float32, prefix_cache=True,
                           preempt_after=1)
    eng = PagedEngine(params, ms, psv)
    reqs = [(_prompt(i, 8, cfg.vocab_size), 16 - 4 * (i % 3))
            for i in range(5)]
    rids = [eng.add_request(p, n) for p, n in reqs]
    res = eng.drain()
    for rid, (p, n) in zip(rids, reqs):
        assert (res[rid] == _one_shot(params, ms, p, n, psv.max_len)).all()


def test_fresh_request_never_links_decode_written_donation():
    """A preemption donation includes generated-range pages whose kv the
    DECODE program wrote (max_len-horizon reduction — not what a cold
    prefill of the same tokens produces). Those nodes are resume-only: a
    FRESH request whose prompt extends the victim's prompt+generated
    stream must stop its match at the prompt-range nodes and stay
    bit-identical to one-shot generate()."""
    cfg, ms, params = _build()
    psv = PagedServeConfig(n_slots=2, page_size=PS, n_pages=17, max_len=32,
                           cache_dtype=jnp.float32, prefix_cache=True,
                           preempt_after=0)
    eng = PagedEngine(params, ms, psv)
    prompt = _prompt(0, 8, cfg.vocab_size)
    rid = eng.add_request(prompt, 12)
    for _ in range(10):
        eng.step()
    victim, slot = eng.sched.preempt_youngest(eng.step_count)
    eng.block_tables[slot] = 0
    eng.tok[slot] = 0
    eng.pos[slot] = 0
    # The donation now holds prompt pages (clean) + a generated-range
    # page flagged decode_written.
    flagged = [n for n in eng.prefix.evictable_leaves() if n.decode_written]
    assert flagged, "preemption must donate flagged generated-range pages"
    # Fresh request whose prompt IS the victim's prompt + generated head:
    # must match only the clean prompt page (8 tokens = 1 page), not the
    # flagged ones.
    ext_prompt = np.concatenate(
        [prompt, np.asarray(victim.out[:8], np.int32)])
    rid2 = eng.add_request(ext_prompt, 4)
    eng.step()
    r2 = eng.request(rid2)
    assert r2.n_shared * PS <= prompt.shape[0]
    assert not any(n.decode_written for n in r2.shared_path)
    eng.drain()
    ref = _one_shot(params, ms, ext_prompt, 4, psv.max_len)
    assert (eng.results[rid2] == ref).all()
    # ... while the victim's own resume DID re-link its flagged pages
    # (cheap resume) and stays exact.
    assert (eng.results[rid] == _one_shot(params, ms, prompt, 12,
                                          psv.max_len)).all()


def test_mamba_preemption_resumes_via_full_reprefill():
    """State mixers have no kv pages to resume from: the engine re-prefills
    prompt (rebuilding conv/h state) and replays decode — still exact."""
    cfg, ms, params = _build(arch="falcon-mamba-7b")
    psv = PagedServeConfig(n_slots=4, page_size=PS, n_pages=9, max_len=32,
                           cache_dtype=jnp.float32, prefix_cache=True,
                           preempt_after=2)
    eng = PagedEngine(params, ms, psv)
    assert eng.prefix is None          # sharing auto-disabled
    pa, pb, pc_ = (_prompt(i, 8, cfg.vocab_size) for i in range(3))
    ra = eng.add_request(pa, 20)
    rb = eng.add_request(pb, 20)
    for _ in range(4):
        eng.step()
    rc = eng.add_request(pc_, 4)
    res = eng.drain()
    assert eng.sched.preemptions_total >= 1
    for rid, (p, n) in zip((ra, rb, rc), [(pa, 20), (pb, 20), (pc_, 4)]):
        assert (res[rid] == _one_shot(params, ms, p, n, psv.max_len)).all()
