"""Self-speculative decoding: shallow-Δ drafts, full-depth verify.

Host-side math units (acceptance, packing masks, rewind bookkeeping),
the paged-cache rewind primitives, and the engine-level contract: a
spec_k>0 engine's greedy streams are BIT-IDENTICAL to the plain engine
under staggered continuous batching — in the rejection-heavy regime
(raw random weights: the shallow draft agrees with full depth only at
chance level) and in the trained-model agreement regime (segments
scaled down, where acceptance must actually pay) — and with the radix
prefix cache live, where a hit row prefills only its suffix yet the
drafter must still see the full prompt. Plus the guard rails:
recurrent-state architectures auto-disable speculation with a warning,
and invalid spec configurations raise at construction.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import transformer as T
from repro.serve import (PagedEngine, PagedServeConfig, PagePool,
                         accept_length, build_draft_step, build_trace,
                         build_verify_batch, commit_tokens, draft_plan_for,
                         rewind_plan, rewind_tokens, spec_eligible,
                         stale_span, validate_trace)
from repro.serve import paged_cache as PG

from _helpers import tiny

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Host-side units: plans, masks, acceptance
# ---------------------------------------------------------------------------

def test_draft_plan_must_be_strictly_more_aggressive():
    cfg = tiny(n_layers=4)
    base = plan_range(cfg, 0, 4)            # fully paired already
    with pytest.raises(ValueError, match="strictly more aggressive"):
        draft_plan_for(cfg, base, 0)
    # From an unpaired base, Δ=0 gives the maximal pairing.
    plan = draft_plan_for(cfg, LPPlan(()), 0)
    assert len(plan.pairs) == 2
    # spec_delta > 0 routes through plan_for_depth.
    plan3 = draft_plan_for(cfg, LPPlan(()), 3)
    assert 0 < len(plan3.pairs) <= 2


def test_spec_eligibility_by_mixer():
    cfg = tiny(n_layers=4)
    ms = T.build_structure(cfg, plan=LPPlan(()), tp=1)
    assert spec_eligible(ms)
    cfg_m = reduced_config(get_config("falcon-mamba-7b"), n_layers=2)
    ms_m = T.build_structure(cfg_m, plan=LPPlan(()), tp=1)
    assert not spec_eligible(ms_m)


def test_build_draft_step_masks_idle_and_overflow():
    tok = np.array([7, 9, 11], np.int32)
    pos = np.array([4, 6, 8], np.int32)
    bt = np.arange(6, dtype=np.int32).reshape(3, 2) + 1
    drafts = np.array([[20, 21, 22]], np.int32)
    remaining = np.array([3, 0, -1])        # running / last-token / idle
    t0, p0, b0 = build_draft_step(0, tok, drafts, pos, bt, remaining)
    assert list(t0) == [7, 9, 0] and list(p0) == [4, 6, 0]
    assert (b0[2] == PG.GARBAGE_PAGE).all() and (b0[0] == bt[0]).all()
    # Step 1 feeds draft 0; slot 1 (remaining=0) is now past budget.
    t1, p1, b1 = build_draft_step(1, tok, drafts, pos, bt, remaining)
    assert list(t1) == [20, 0, 0] and list(p1) == [5, 0, 0]
    assert (b1[1] == PG.GARBAGE_PAGE).all()


def test_build_verify_batch_row_layout():
    k = 2
    tok = np.array([7, 9], np.int32)
    pos = np.array([4, 6], np.int32)
    bt = np.arange(4, dtype=np.int32).reshape(2, 2) + 1
    poison = np.array([False, True])
    drafts = np.array([[20, 30], [21, 31]], np.int32)
    remaining = np.array([5, 1])
    tok_v, pos_v, bt_v, poison_v = build_verify_batch(
        k, tok, pos, bt, poison, drafts, remaining)
    # Slot 0 rows 0..2: u_0=tok, u_1=draft0, u_2=draft1 at pos 4,5,6.
    assert list(tok_v[:3]) == [7, 20, 21] and list(pos_v[:3]) == [4, 5, 6]
    # Slot 1 (remaining=1): row j=2 is past budget -> idle convention.
    assert list(tok_v[3:]) == [9, 30, 0] and list(pos_v[3:]) == [6, 7, 0]
    assert (bt_v[5] == PG.GARBAGE_PAGE).all() and (bt_v[4] == bt[1]).all()
    # Poison replicates to the slot's ACTIVE rows only.
    assert list(poison_v) == [False, False, False, True, True, False]


def test_accept_commit_stale_math():
    drafts = np.array([5, 6, 7], np.int32)
    verify = np.array([5, 6, 9, 4], np.int32)   # disagrees at draft 2
    assert accept_length(drafts, verify, 3) == 2
    assert accept_length(drafts, verify, 1) == 1     # cap binds
    assert commit_tokens(drafts, verify, 2) == [5, 6, 9]
    # Bonus-only episode: nothing accepted, full model's own pick.
    assert commit_tokens(drafts, verify, 0) == [5]
    # After accepting a of k probed at p0: [p0+a+1, p0+j_hi+1) is stale.
    assert stale_span(10, 2, 3) == (13, 14)
    assert stale_span(10, 3, 3) == (14, 14)          # full accept: empty


# ---------------------------------------------------------------------------
# Rewind bookkeeping: plan, pool, device zeroing
# ---------------------------------------------------------------------------

def test_rewind_plan_math_and_guards():
    pages, ps = [4, 9, 2], 4
    zero, free = rewind_plan(pages, 0, 5, 10, ps)
    assert zero == [(9, 1), (9, 2), (9, 3), (2, 0), (2, 1)]
    assert free == [2]                  # page 2 holds no live position
    zero, free = rewind_plan(pages, 0, 8, 9, ps)
    assert zero == [(2, 0)] and free == [2]
    assert rewind_plan(pages, 0, 7, 7, ps) == ([], [])
    with pytest.raises(ValueError, match="within the written"):
        rewind_plan(pages, 0, 8, 6, ps)
    with pytest.raises(ValueError, match="read-only"):
        rewind_plan(pages, 1, 3, 10, ps)     # cuts into shared page 0
    with pytest.raises(ValueError, match="exceeds"):
        rewind_plan(pages, 0, 5, 13, ps)


def test_free_rewound_refuses_shared_pages():
    pool = PagePool(n_pages=8)
    own = pool.alloc(2)
    shared = pool.alloc(1)
    pool.share(shared)                       # refcount 2: radix + request
    pool.free_rewound(own)                   # privately held: fine
    with pytest.raises(Exception, match="rewind-free"):
        pool.free_rewound(shared)
    pool.free(shared)
    pool.free(shared)
    pool.check_balance()
    assert pool.live == 0


def test_rewind_tokens_zeroes_only_targeted_positions():
    cfg = tiny(n_layers=2)
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 2), tp=1)
    caches = PG.init_paged_caches(ms, n_slots=2, n_pages=5, page_size=4,
                                  dtype=jnp.float32)
    ones = [{n: jnp.ones_like(v) for n, v in seg.items()}
            for seg in caches]
    out = rewind_tokens(ones, jnp.array([2, 3], jnp.int32),
                        jnp.array([1, 0], jnp.int32))
    for seg in out:
        for name, v in seg.items():
            if not PG.is_paged_entry(name):
                assert (np.asarray(v) == 1).all()
                continue
            a = np.asarray(v)
            ba = T.cache_batch_axis(name)
            moved = np.moveaxis(a, (ba, ba + 1), (0, 1)) if ba else a
            assert (moved[2, 1] == 0).all() and (moved[3, 0] == 0).all()
            assert (moved[2, 0] == 1).all() and (moved[1] == 1).all()


# ---------------------------------------------------------------------------
# Engine: bit-identity, counters, telemetry, guards
# ---------------------------------------------------------------------------

def _spec_engines(params, ms, spec_k, **kw):
    psv0 = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=32,
                            cache_dtype=jnp.float32, **kw)
    psvk = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=32,
                            cache_dtype=jnp.float32, spec_k=spec_k, **kw)
    return PagedEngine(params, ms, psv0), PagedEngine(params, ms, psvk)


def _staggered_drive(eng, prompts, max_new=7):
    rids = [eng.add_request(p, max_new) for p in prompts[:4]]
    eng.step()
    rids += [eng.add_request(p, max_new) for p in prompts[4:]]
    eng.drain()
    return rids


def _prompts(cfg, lens=(6, 8, 12, 8, 6, 12)):
    return [np.asarray(jax.random.randint(jax.random.fold_in(KEY, i),
                                          (L,), 0, cfg.vocab_size))
            for i, L in enumerate(lens)]


def test_spec_engine_bit_identical_raw_weights():
    """Raw random weights: chance-level draft agreement — the rejection
    and rewind paths run hot, and the stream may not move a bit."""
    cfg = tiny(n_layers=4)
    ms = T.build_structure(cfg, plan=LPPlan(()), tp=1)
    params = T.init_params(ms, KEY)
    eng0, engk = _spec_engines(params, ms, spec_k=2)
    prompts = _prompts(cfg)
    rids0 = _staggered_drive(eng0, prompts)
    ridsk = _staggered_drive(engk, prompts)
    for r0, rk in zip(rids0, ridsk):
        assert (eng0.results[r0] == engk.results[rk]).all(), (r0, rk)
    c = engk.counters
    assert c["verify_steps"] > 0
    assert c["draft_steps"] == 2 * c["verify_steps"]
    assert c["spec_accepted"] + c["spec_rejected"] > 0
    assert c["spec_rejected"] > 0            # raw weights DO reject
    assert c["spec_rewound"] > 0             # ...and rejections rewind
    assert engk.pool.live == 0
    assert engk.pool.allocated_total == engk.pool.freed_total > 0
    # Episode telemetry: one histogram observation + one spec_log row
    # per running slot per verify; the trace renders them as slices.
    h = engk.telemetry.hists["spec_accept"]
    assert h.count == len(engk.telemetry.spec_log) > 0
    doc = build_trace(engk.telemetry, n_slots=4)
    validate_trace(doc)
    spec_slices = [e for e in doc["traceEvents"] if e.get("cat") == "spec"]
    assert len(spec_slices) == len(engk.telemetry.spec_log)
    assert all(e["name"].startswith("spec:") for e in spec_slices)


def test_spec_engine_accepts_in_agreement_regime():
    """Segments scaled toward identity: the shallow draft agrees with
    full depth (the trained-model regime) — still bit-identical, and
    acceptance must beat one token per verify."""
    cfg = tiny(n_layers=4)
    ms = T.build_structure(cfg, plan=LPPlan(()), tp=1)
    params = T.init_params(ms, KEY)
    params = dict(params, segments=jax.tree.map(lambda x: x * 0.1,
                                                params["segments"]))
    eng0, engk = _spec_engines(params, ms, spec_k=2)
    prompts = _prompts(cfg)
    rids0 = _staggered_drive(eng0, prompts)
    ridsk = _staggered_drive(engk, prompts)
    for r0, rk in zip(rids0, ridsk):
        assert (eng0.results[r0] == engk.results[rk]).all(), (r0, rk)
    snap = engk.metrics_snapshot()
    spec = snap["spec"]
    assert spec["k"] == 2
    assert spec["draft_eff_depth"] == engk.ms_draft.effective_depth
    assert spec["accept_per_verify"] > 1.0, spec
    assert engk.counters["spec_accepted"] > 0
    # Fewer engine steps than the plain engine: the speedup's
    # deterministic form.
    assert engk.step_count < eng0.step_count


def test_spec_engine_bit_identical_with_prefix_cache():
    """spec_k x radix cache: a prefix-HIT member rides the bucketed
    suffix path while speculation is live.  The hit row sits out the
    draft mirror's bucket prefill until the engine primes it with a
    full-prompt draft prefill, so drafts see the tokens the shared pages
    hold — gated here by bit-identity to the plain prefix-on engine,
    with BOTH subsystems proven hot by the counters."""
    cfg = tiny(n_layers=4)
    ms = T.build_structure(cfg, plan=LPPlan(()), tp=1)
    params = T.init_params(ms, KEY)
    eng0, engk = _spec_engines(params, ms, spec_k=2, prefix_cache=True)

    def toks(i, L):
        return np.asarray(jax.random.randint(jax.random.fold_in(KEY, 10 + i),
                                             (L,), 0, cfg.vocab_size))

    shared = toks(0, 8)                         # one whole page
    donor = np.concatenate([shared, toks(1, 8)])
    member = np.concatenate([shared, toks(2, 6)])
    cold = toks(3, 7)
    rids = []
    for eng in (eng0, engk):
        r0 = eng.add_request(donor, 5)
        eng.drain()                             # donates the shared page
        r1 = eng.add_request(member, 5)
        r2 = eng.add_request(cold, 5)
        eng.drain()
        rids.append((r0, r1, r2))
    for a, b in zip(*rids):
        assert (eng0.results[a] == engk.results[b]).all(), (a, b)
    for eng in (eng0, engk):
        c = eng.counters
        assert c["prefix_hits"] == 1, dict(c)
        assert c["suffix_prefills"] == 1, dict(c)
        assert eng.pool.live == eng.prefix.resident_pages
    ck = engk.counters
    assert ck["verify_steps"] > 0, dict(ck)
    assert ck["draft_steps"] == 2 * ck["verify_steps"], dict(ck)
    assert ck["spec_accepted"] + ck["spec_rejected"] > 0, dict(ck)


def test_spec_auto_disables_on_recurrent_blocks():
    """State-model guard: mamba blocks have no per-position kv to rewind
    — spec_k must drop to 0 with an actionable warning, and the fallback
    engine must stay bit-identical to a spec_k=0 engine."""
    cfg = reduced_config(get_config("falcon-mamba-7b"), n_layers=4)
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=1)
    params = T.init_params(ms, KEY)
    psv = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=32,
                           cache_dtype=jnp.float32, spec_k=2)
    with pytest.warns(UserWarning, match="auto-disabled"):
        engk = PagedEngine(params, ms, psv)
    assert engk.spec_k == 0 and engk.ms_draft is None
    psv0 = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=32,
                            cache_dtype=jnp.float32)
    eng0 = PagedEngine(params, ms, psv0)
    prompts = _prompts(cfg, lens=(6, 8, 12))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        rk = [engk.add_request(p, 5) for p in prompts]
        engk.drain()
    r0 = [eng0.add_request(p, 5) for p in prompts]
    eng0.drain()
    for a, b in zip(rk, r0):
        assert (engk.results[a] == eng0.results[b]).all()
    assert "spec" not in engk.metrics_snapshot()


def test_spec_config_validation():
    cfg = tiny(n_layers=4)
    ms = T.build_structure(cfg, plan=LPPlan(()), tp=1)
    params = T.init_params(ms, KEY)

    def psv(**kw):
        return PagedServeConfig(n_slots=4, page_size=8, n_pages=33,
                                max_len=32, cache_dtype=jnp.float32, **kw)

    with pytest.raises(ValueError, match="spec_k"):
        PagedEngine(params, ms, psv(spec_k=-1))
    with pytest.raises(ValueError, match="greedy"):
        PagedEngine(params, ms, psv(spec_k=2, temperature=0.7))
    with pytest.raises(ValueError, match="degrade"):
        PagedEngine(params, ms, psv(spec_k=2, degrade_delta=True,
                                    degrade_slots=2))
    with pytest.raises(ValueError, match="spec_delta"):
        PagedEngine(params, ms, psv(spec_delta=3))
    # Base already maximally paired: no strictly-more-aggressive draft.
    ms_full = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=1)
    params_full = T.init_params(ms_full, KEY)
    with pytest.raises(ValueError, match="aggressive"):
        PagedEngine(params_full, ms_full, psv(spec_k=2))
