"""Graceful overload degradation: bounded queue, deadline-aware shedding,
and the aggressive-Δ degraded cohort.

Under overload the engine degrades BY POLICY — typed shed errors and a
deeper-merged (cheaper) model for overflow admissions — never by crash or
unbounded queue growth. Degraded admissions trade depth for capacity, not
correctness: their streams must be bit-identical to a fixed aggressive-Δ
engine built from the same weights by ``LP.replan``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lp import LPPlan, plan_for_depth, plan_range, replan
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (COHORT_DEGRADED, COHORT_MAIN, EXPIRED, FINISHED,
                         LoadShedError, PagedEngine, PagedServeConfig,
                         QueueFullError, ServeConfig, generate)

from _helpers import tiny

PC = ParallelContext()
KEY = jax.random.PRNGKey(0)


def _one_shot(params, ms, prompt, n_new):
    sv = ServeConfig(max_len=32, temperature=0.0, cache_dtype=jnp.float32)
    return np.asarray(generate(params, jnp.asarray(prompt)[None], n_new,
                               ms=ms, pc=PC, sv=sv)[0])


def test_bounded_queue_sheds_by_deadline_slack():
    cfg = tiny(n_layers=2)
    ms = T.build_structure(cfg, tp=1)
    params = T.init_params(ms, KEY)
    psv = PagedServeConfig(n_slots=1, page_size=8, n_pages=9, max_len=32,
                           cache_dtype=jnp.float32, max_queue=2)
    eng = PagedEngine(params, ms, psv)
    key = jax.random.PRNGKey(3)
    pr = [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (8,),
                                        0, cfg.vocab_size)) for i in range(5)]
    r0 = eng.add_request(pr[0], 8, deadline=100)   # fills the queue (cap 2)
    r1 = eng.add_request(pr[1], 8, deadline=100)

    # A no-deadline newcomer is infinitely slack — it never displaces a
    # deadlined request: typed rejection, queue EXACTLY as it was.
    with pytest.raises(QueueFullError):
        eng.add_request(pr[2], 8)
    assert eng.sched.n_queued == 2

    # A strictly-more-urgent newcomer displaces the slackest queued
    # request, which lands EXPIRED with a typed LoadShedError — not
    # silently dropped.
    r3 = eng.add_request(pr[3], 8, deadline=50)
    assert eng.sched.n_queued == 2             # cap never exceeded
    shed = [r for r in (r0, r1) if eng.request(r).state == EXPIRED]
    assert len(shed) == 1
    assert isinstance(eng.request(shed[0]).error, LoadShedError)
    assert eng.counters["shed"] == 1

    # An EQUALLY urgent newcomer (same deadline as the slackest) does not
    # displace: shedding requires STRICTLY more urgency.
    with pytest.raises(QueueFullError):
        eng.add_request(pr[4], 8, deadline=100)

    res = eng.drain()
    assert eng.sched.n_queued == 0
    survivors = [r for r in (r0, r1, r3) if r not in shed]
    for rid in survivors:
        assert eng.request(rid).state == FINISHED
    i = {r0: 0, r1: 1, r3: 3}
    for rid in survivors:
        assert (res[rid] == _one_shot(params, ms, pr[i[rid]], 8)).all()
    assert eng.pool.live == 0


def test_degraded_cohort_bit_identical_to_fixed_delta_engine():
    # Base: 4 layers, 1 pair merged (eff depth 3). Degraded cohort: eff
    # depth 2 (2 pairs) — same weights, re-paired retraining-free.
    cfg = tiny(n_layers=4)
    base_plan = LPPlan(plan_range(cfg, 0, 4).pairs[:1])
    ms = T.build_structure(cfg, plan=base_plan, tp=1)
    params = T.init_params(ms, KEY)
    psv = PagedServeConfig(n_slots=2, page_size=8, n_pages=17, max_len=32,
                           cache_dtype=jnp.float32, degrade_delta=True,
                           degrade_slots=1, degrade_queue_depth=1,
                           degrade_eff_depth=2)
    eng = PagedEngine(params, ms, psv)
    key = jax.random.PRNGKey(4)
    pr = [np.asarray(jax.random.randint(jax.random.fold_in(key, i), (8,),
                                        0, cfg.vocab_size)) for i in range(3)]
    rids = [eng.add_request(p, 8) for p in pr]
    res = eng.drain()
    for rid in rids:
        assert eng.request(rid).state == FINISHED

    # With 1 main slot and a 3-deep backlog, the overflow admission went
    # to the degraded cohort (pressure >= degrade_queue_depth).
    cohorts = [eng.request(r).cohort for r in rids]
    assert COHORT_DEGRADED in cohorts and COHORT_MAIN in cohorts
    assert eng.counters["degraded_admissions"] == cohorts.count(
        COHORT_DEGRADED)

    # Main-cohort streams match the BASE model; degraded streams match the
    # fixed aggressive-Δ model built from the SAME weights via replan.
    deg_plan = plan_for_depth(cfg, 2, end=4)
    _, seg_params = replan(cfg, params["segments"], ms.segments, deg_plan)
    ms_deg = T.build_structure(cfg, plan=deg_plan, tp=1)
    params_deg = dict(params, segments=seg_params)
    for rid, prompt in zip(rids, pr):
        ref_ms, ref_p = ((ms_deg, params_deg)
                         if eng.request(rid).cohort == COHORT_DEGRADED
                         else (ms, params))
        assert (res[rid] == _one_shot(ref_p, ref_ms, prompt, 8)).all(), rid
    assert eng.pool.live == 0
    eng.pool.check_balance()


def test_degraded_cohort_only_under_pressure():
    # No backlog -> every admission stays on the main cohort even with
    # degrade_delta configured: degradation is an overload response, not a
    # default.
    cfg = tiny(n_layers=4)
    base_plan = LPPlan(plan_range(cfg, 0, 4).pairs[:1])
    ms = T.build_structure(cfg, plan=base_plan, tp=1)
    params = T.init_params(ms, KEY)
    psv = PagedServeConfig(n_slots=3, page_size=8, n_pages=13, max_len=32,
                           cache_dtype=jnp.float32, degrade_delta=True,
                           degrade_slots=1, degrade_queue_depth=2,
                           degrade_eff_depth=2)
    eng = PagedEngine(params, ms, psv)
    prompt = np.asarray(jax.random.randint(KEY, (8,), 0, cfg.vocab_size))
    rid = eng.add_request(prompt, 8)
    res = eng.drain()
    assert eng.request(rid).cohort == COHORT_MAIN
    assert eng.counters["degraded_admissions"] == 0
    assert (res[rid] == _one_shot(params, ms, prompt, 8)).all()
