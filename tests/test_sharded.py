"""Multi-device parity (8 CPU host devices via subprocess): the sharded
system == the single-device reference, and the LP collective-halving claim
is visible in the compiled HLO."""
import json

import pytest

from _helpers import run_multidevice

pytestmark = pytest.mark.slow


def test_tp_dp_fsdp_parity():
    """One subprocess checks: (a) TPxDP shard_map == single device,
    (b) FSDP == single device, (c) pod-compressed grads stay close."""
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding
from repro.configs import get_config, reduced_config
from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext, make_context
from repro.train import TrainConfig, OptConfig, init_state, make_train_step, make_sharded_train_step
from repro.train.trainer import state_pspecs

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=4)
plan = plan_range(cfg, 1, 3)
tc = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=2, total_steps=40))
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
babs = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)

ms1 = T.build_structure(cfg, plan=plan, tp=1)
st1 = init_state(ms1, jax.random.PRNGKey(0), ParallelContext(), tc)
step1 = jax.jit(make_train_step(ms1, ParallelContext(), tc))
for _ in range(3):
    st1, m1 = step1(st1, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
losses = {}
for name, kw in [("tp", dict(fsdp=False)),
                 ("fsdp", dict(fsdp=True, fsdp_data=2))]:
    ms2 = T.build_structure(cfg, plan=plan, tp=2, **kw)
    pc2 = make_context(mesh, sp=True)
    st2 = jax.device_put(init_state(ms2, jax.random.PRNGKey(0), pc2, tc),
        jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(ms2, pc2, tc)))
    fn, _, bspec, _ = make_sharded_train_step(ms2, mesh, tc, babs, donate=False)
    bsh = jax.device_put(batch, jax.tree.map(lambda s: NamedSharding(mesh, s), bspec))
    for _ in range(3):
        st2, m2 = fn(st2, bsh)
    losses[name] = float(m2["loss"])

tc3 = TrainConfig(opt=OptConfig(lr=3e-3, warmup_steps=2, total_steps=40), compress_pod=True)
ms3 = T.build_structure(cfg, plan=plan, tp=2)
pc3 = make_context(mesh, sp=True)
st3 = jax.device_put(init_state(ms3, jax.random.PRNGKey(0), pc3, tc3),
    jax.tree.map(lambda s: NamedSharding(mesh, s), state_pspecs(ms3, pc3, tc3)))
fn3, _, bspec3, _ = make_sharded_train_step(ms3, mesh, tc3, babs, donate=False)
bsh = jax.device_put(batch, jax.tree.map(lambda s: NamedSharding(mesh, s), bspec3))
for _ in range(3):
    st3, m3 = fn3(st3, bsh)
losses["compressed"] = float(m3["loss"])
losses["ref"] = float(m1["loss"])
print("RESULT " + json.dumps(losses))
""")
    res = json.loads([l for l in out.splitlines() if l.startswith("RESULT")][0][7:])
    assert abs(res["tp"] - res["ref"]) < 2e-3, res
    assert abs(res["fsdp"] - res["ref"]) < 2e-3, res
    assert abs(res["compressed"] - res["ref"]) < 5e-2, res


def test_lp_halves_allreduce_count_in_hlo():
    """THE paper claim, structurally: over the paired range, the decode step
    needs half the all-reduces. Count them in the compiled HLO."""
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, json, re
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan
from repro.model import transformer as T
from repro.model import stack as STK
from repro.serve.engine import ServeConfig, make_sharded_serve_step
from repro.analysis.roofline import collective_bytes

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=8)
mesh = jax.make_mesh((1, 2), ("data", "model"))
counts = {}
STK.set_scan_unroll(True)
for name, plan in [("vanilla", LPPlan(())),
                   ("lp", LPPlan(((0,1),(2,3),(4,5),(6,7))))]:
    ms = T.build_structure(cfg, plan=plan, tp=2)
    sv = ServeConfig(max_len=64, kv_mode="heads")
    fn, c_abs, c_specs, pc = make_sharded_serve_step(ms, mesh, sv, batch=4)
    import repro.launch.specs as SP
    p_abs = jax.tree.map(lambda pd: jax.ShapeDtypeStruct(pd.shape, jnp.float32),
                         T.model_template(ms), is_leaf=lambda x: hasattr(x, "pspec"))
    tok = jax.ShapeDtypeStruct((4,), jnp.int32)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    lowered = fn.lower(p_abs, tok, c_abs, t, key)
    txt = lowered.compile().as_text()
    coll = collective_bytes(txt)
    counts[name] = int(coll.get("count:all-reduce", 0))
print("RESULT " + json.dumps(counts))
""")
    res = json.loads([l for l in out.splitlines() if l.startswith("RESULT")][0][7:])
    vanilla, lp = res["vanilla"], res["lp"]
    # 8 layers x 2 ARs -> 4 pairs x 2 ARs: difference must be exactly 8
    assert vanilla - lp == 8, res


def test_pipeline_parallel_matches_sequential():
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, json
from jax.sharding import PartitionSpec as P
import repro.compat  # installs the jax.shard_map alias on old JAX
from repro.parallel.pp import pipeline_apply, stage_slice

mesh = jax.make_mesh((4,), ("pipe",))
n_stages, n_layers, d = 4, 8, 16
ws = jax.random.normal(jax.random.PRNGKey(0), (n_layers, d, d)) * (d ** -0.5)

def seq_ref(x):
    for i in range(n_layers):
        x = jnp.tanh(x @ ws[i])
    return x

x_micro = jax.random.normal(jax.random.PRNGKey(1), (8, 2, d))

def stage_fn(params, x):
    for w in params:
        x = jnp.tanh(x @ w)
    return x

def run(x_micro):
    stage = jax.lax.axis_index("pipe")
    # static per-stage params: slice with dynamic_slice over the stacked tree
    lo0, hi0 = stage_slice(n_layers, n_stages, 0)
    k = hi0 - lo0
    params = jax.lax.dynamic_slice_in_dim(ws, stage * k, k, axis=0)
    return pipeline_apply(lambda p, x: stage_fn(p, x), params, x_micro,
                          axis="pipe", n_stages=n_stages)

f = jax.jit(jax.shard_map(run, mesh=mesh, in_specs=P(), out_specs=P(),
                          check_vma=False))
out = f(x_micro)
ref = jax.vmap(seq_ref)(x_micro)
print("RESULT", float(jnp.abs(out - ref).max()))
""")
    err = float([l for l in out.splitlines() if l.startswith("RESULT")][0].split()[1])
    assert err < 1e-5


def test_sp_on_off_equal():
    """Sequence parallelism is a pure re-decomposition: same math."""
    out = run_multidevice(r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import repro.compat  # installs the jax.shard_map alias on old JAX
from repro.configs import get_config, reduced_config
from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import make_context

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=4)
plan = plan_range(cfg, 0, 4)
mesh = jax.make_mesh((2, 4), ("data", "model"))
ms = T.build_structure(cfg, plan=plan, tp=4)
params = T.init_params(ms, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size)
outs = []
for sp in (False, True):
    pc = make_context(mesh, sp=sp)
    def fwd(p, tk):
        lg, _, _ = T.forward_full(p, tk, ms=ms, pc=pc)
        return lg
    f = jax.jit(jax.shard_map(fwd, mesh=mesh,
        in_specs=(T.param_pspecs(ms), P("data", None)),
        out_specs=P("data", None, "model"), check_vma=False))
    outs.append(f(params, toks))
import numpy as np
print("RESULT", float(jnp.abs(outs[0] - outs[1]).max()))
""")
    err = float([l for l in out.splitlines() if l.startswith("RESULT")][0].split()[1])
    assert err < 2e-4
