"""Sharded paged serving: the tp>1 PagedEngine and its supporting layers.

Fast units (single device): the sharded pool's layout/pspecs mirror the
ring cache's model-axis rules, the paged kernels' head_map scalar-prefetch
selection agrees with slicing the pool, and ``validate_paged_support``
rejects kv-head counts the model axis cannot cut evenly.

Slow subprocess tests (8 host devices): a tp=2 engine under staggered
continuous batching is BIT-identical to the tp=1 engine and to one-shot
``sharded_generate``; one sharded paged decode step matches the sharded
ring step; the Pallas in-kernel head selection agrees with the XLA gather
path under replicated kv (tp > n_kv); and the prefix cache STAYS ON under
tp>1 — radix-hit suffix prefills on the sharded engine are bit-identical
to cold full prefills and to one-shot ``sharded_generate``.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.lp import plan_range
from repro.kernels.decode_attention import (decode_attention_paged,
                                            decode_attention_pair_paged)
from repro.model import attention as A
from repro.model import blocks as BL
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import paged_cache as PG

from _helpers import run_multidevice, tiny

KEY = jax.random.PRNGKey(0)


@dataclasses.dataclass(frozen=True)
class _FixedRank(ParallelContext):
    """ParallelContext pinned to one rank — lets a single-device unit test
    evaluate the per-rank kv in-gather for every rank without shard_map."""
    rank: int = 0

    def tp_index(self):
        return jnp.int32(self.rank)


# ---------------------------------------------------------------------------
# Fast units
# ---------------------------------------------------------------------------

def test_sharded_pool_layout_and_pspecs():
    """The paged pool under tp=2 keeps the ring cache's partition rules:
    kv-sharded head axis carries "model" at the SAME axis position (pages
    replace [B, L] without moving any sharded dim); state entries keep
    their ring pspecs; pool shapes stay GLOBAL (hkv_global heads)."""
    cfg = tiny(n_layers=4)                      # 4 q heads, 4 kv heads
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=2)
    abs_, ps_ = PG.paged_cache_meta(ms, n_slots=2, n_pages=9, page_size=8,
                                    dtype=jnp.float32)
    dims = ms.dims
    assert dims.kv_sharded
    for seg_abs, seg_ps in zip(abs_, ps_):
        assert set(seg_abs.keys()) == {"k", "v"}
        for name in ("k", "v"):
            # [count, 2, n_pages, ps, Hkv_global, hd]
            assert seg_abs[name].shape[1:] == (2, 9, 8, dims.hkv_global,
                                               dims.hd)
            spec = tuple(seg_ps[name])
            assert spec[4] == "model", spec        # head axis sharded
            assert all(s is None for i, s in enumerate(spec) if i != 4)

    # Replicated kv (tp > n_kv): pool replicated, no model axis anywhere.
    cfg_r = dataclasses.replace(cfg, n_kv_heads=2)
    ms_r = T.build_structure(cfg_r, plan=plan_range(cfg_r, 0, 4), tp=4)
    assert not ms_r.dims.kv_sharded
    _, ps_r = PG.paged_cache_meta(ms_r, n_slots=2, n_pages=9, page_size=8,
                                  dtype=jnp.float32)
    for seg_ps in ps_r:
        for name in ("k", "v"):
            assert all(s is None for s in tuple(seg_ps[name]))


def test_paged_kernel_head_map_selects_stored_head():
    """head_map=[i] must equal running the identity kernel on the pool
    sliced to head i — the in-kernel form of select_local_kv."""
    B, n_pages, ps, Hkv, hd, n_pg = 2, 7, 8, 3, 16, 3
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (n_pages, ps, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), k.shape)
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (B, 1, 4, hd))
    bt = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
    t = jnp.array([13, 20], jnp.int32)
    for h in range(Hkv):
        got = decode_attention_paged(q, k, v, bt, t,
                                     head_map=jnp.array([h], jnp.int32))
        ref = decode_attention_paged(q, k[:, :, h:h + 1], v[:, :, h:h + 1],
                                     bt, t)
        assert jnp.array_equal(got, ref), h


def test_paged_pair_kernel_head_map_matches_sliced_pool():
    """Pair variant: one head_map serves both halves; multi-entry maps
    (the per-head TP mode) permute heads exactly like pool gathering."""
    B, n_pages, ps, Hkv, hd, n_pg = 2, 5, 4, 2, 16, 2
    k = jax.random.normal(jax.random.fold_in(KEY, 4),
                          (2, n_pages, ps, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 5), k.shape)
    q = jax.random.normal(jax.random.fold_in(KEY, 6), (2, B, 2, 1, hd))
    bt = jnp.array([[1, 2], [3, 4]], jnp.int32)
    t = jnp.array([5, 7], jnp.int32)
    hm = jnp.array([1, 0], jnp.int32)              # swap the two heads
    got = decode_attention_pair_paged(q, k, v, bt, t, head_map=hm)
    ref = decode_attention_pair_paged(q, k[:, :, :, ::-1], v[:, :, :, ::-1],
                                      bt, t)
    assert jnp.array_equal(got, ref)


def test_validate_paged_support_rejects_indivisible_kv():
    """n_kv % tp != 0 with sharded kv heads must fail AT VALIDATION with a
    message naming the fix, not inside the kernel index map; replicated kv
    (tp > n_kv) and dividing configs stay accepted."""
    cfg = dataclasses.replace(tiny(n_layers=2), n_heads=4, n_kv_heads=3)
    ms = T.build_structure(cfg, tp=2)              # 3 kv heads over 2 ranks
    with pytest.raises(ValueError, match="does not divide"):
        PG.validate_paged_support(ms, 64)
    ok = T.build_structure(tiny(n_layers=2), tp=2)          # 4 over 2
    PG.validate_paged_support(ok, 64)
    repl = T.build_structure(cfg, tp=4)            # replicated: 3 < 4
    PG.validate_paged_support(repl, 64)


def test_fold_ctx_kv_sharded_pool_is_identity_on_heads():
    """kv-SHARDED pool: ``gather_ctx`` inside shard_map already hands each
    rank its LOCAL head shard, so the fold must be pure layout (pair-major
    head fold), bit-identical to folding the shard by hand."""
    cfg = tiny(n_layers=4)                          # 4 q heads, 4 kv heads
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=2)
    dims = ms.dims
    assert dims.kv_sharded
    group = ms.segments[0].group
    B, Tc = 2, 8
    ck = jax.random.normal(jax.random.fold_in(KEY, 10),
                           (2, B, Tc, dims.hkv, dims.hd))
    cv = jax.random.normal(jax.random.fold_in(KEY, 11), ck.shape)
    ks, vs = BL._fold_ctx_kv({"k": ck, "v": cv}, dims, ParallelContext(),
                             group=group)
    ref_k = jnp.moveaxis(ck, 0, 2).reshape(B, Tc, 2 * dims.hkv, dims.hd)
    ref_v = jnp.moveaxis(cv, 0, 2).reshape(B, Tc, 2 * dims.hkv, dims.hd)
    assert jnp.array_equal(ks, ref_k) and jnp.array_equal(vs, ref_v)

    # The trace-time audit: a ctx tree carrying the GLOBAL head count on a
    # sharded-kv rank is mis-sharded and must fail loudly.
    bad = jax.random.normal(jax.random.fold_in(KEY, 12),
                            (2, B, Tc, dims.hkv_global, dims.hd))
    with pytest.raises(AssertionError, match="kv layout"):
        BL._fold_ctx_kv({"k": bad, "v": bad}, dims, ParallelContext(),
                        group=group)


def test_fold_ctx_kv_replicated_pool_ingathers_rank_head():
    """REPLICATED pool (n_kv < tp): every rank holds all stored heads and
    the fold in-gathers this rank's head — the same selection the paged
    decode kernel performs via ``paged_head_map``, checked against slicing
    the pool by hand for EVERY rank."""
    cfg = dataclasses.replace(tiny(n_layers=4), n_kv_heads=2)
    tp = 4
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=tp)
    dims = ms.dims
    assert not dims.kv_sharded
    Hk_eff, _ = A.core_layout(dims)
    group = ms.segments[0].group
    B, Tc = 2, 8
    ck = jax.random.normal(jax.random.fold_in(KEY, 13),
                           (2, B, Tc, dims.hkv, dims.hd))
    cv = jax.random.normal(jax.random.fold_in(KEY, 14), ck.shape)
    for r in range(tp):
        ks, vs = BL._fold_ctx_kv({"k": ck, "v": cv}, dims,
                                 _FixedRank(rank=r), group=group)
        assert ks.shape == (B, Tc, 2 * Hk_eff, dims.hd)
        h = min(r * dims.hq // dims.group, dims.hkv - 1)
        sel_k = ck[:, :, :, h:h + Hk_eff]
        sel_v = cv[:, :, :, h:h + Hk_eff]
        ref_k = jnp.moveaxis(sel_k, 0, 2).reshape(B, Tc, 2 * Hk_eff, dims.hd)
        ref_v = jnp.moveaxis(sel_v, 0, 2).reshape(B, Tc, 2 * Hk_eff, dims.hd)
        assert jnp.array_equal(ks, ref_k) and jnp.array_equal(vs, ref_v), r


# ---------------------------------------------------------------------------
# Multi-device (subprocess) parity
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_tp2_engine_bit_identical_to_tp1_and_sharded_one_shot():
    """Staggered tp=2 continuous batching == tp=1 engine == one-shot
    sharded_generate, bitwise per request; accounting drains; the prefix
    cache stays LIVE under the mesh."""
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import transformer as T
from repro.serve import (PagedEngine, PagedServeConfig, ServeConfig,
                         sharded_generate)

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=6)
plan = LPPlan(plan_range(cfg, 0, 6).pairs[:3])
ms1 = T.build_structure(cfg, plan=plan, tp=1)
ms2 = T.build_structure(cfg, plan=plan, tp=2)
params = T.init_params(ms1, jax.random.PRNGKey(0))
mesh = jax.make_mesh((1, 2), ("data", "model"))
psv = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=64,
                       cache_dtype=jnp.float32)
key = jax.random.PRNGKey(7)
prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                         (L,), 0, cfg.vocab_size))
           for i, L in enumerate([6, 9, 12, 8, 11, 7])]
res = {}
for name, ms, mk in (("tp1", ms1, None), ("tp2", ms2, mesh)):
    eng = PagedEngine(params, ms, psv, mesh=mk)
    rids = [eng.add_request(p, 10) for p in prompts[:4]]
    eng.step(); eng.step()                       # staggered admission
    rids += [eng.add_request(p, 10) for p in prompts[4:]]
    eng.drain()
    assert eng.pool.live == 0
    assert eng.pool.allocated_total == eng.pool.freed_total > 0
    res[name] = eng
same = all((res["tp1"].results[r] == res["tp2"].results[r]).all()
           for r in res["tp1"].results)
sv = ServeConfig(max_len=64, temperature=0.0, cache_dtype=jnp.float32)
one_shot = all(
    (res["tp2"].results[i] ==
     sharded_generate(params, prompts[i][None], 10, ms=ms2, mesh=mesh,
                      sv=sv)[0]).all()
    for i in range(3))
psv_px = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=64,
                          cache_dtype=jnp.float32, prefix_cache=True)
prefix_on = PagedEngine(params, ms2, psv_px, mesh=mesh).prefix is not None
print("RESULT " + json.dumps({"same": same, "one_shot": one_shot,
                              "prefix_on": prefix_on}))
""")
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT")][0][7:])
    assert res == {"same": True, "one_shot": True, "prefix_on": True}, res


@pytest.mark.slow
def test_tp2_prefix_hit_bit_identical_to_cold_and_one_shot():
    """Sharded radix sharing end to end: a donor family prompt, then
    radix-HIT members through the tp=2 prefix-on engine — bit-identical to
    the tp=1 prefix-on engine, to a prefix-OFF tp=2 engine (cold prefills),
    and to one-shot ``sharded_generate``; hit suffixes ride the bucket
    path (no exact-length suffix program is ever compiled)."""
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import transformer as T
from repro.serve import (PagedEngine, PagedServeConfig, ServeConfig,
                         sharded_generate)

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=6)
plan = LPPlan(plan_range(cfg, 0, 6).pairs[:3])
ms1 = T.build_structure(cfg, plan=plan, tp=1)
ms2 = T.build_structure(cfg, plan=plan, tp=2)
params = T.init_params(ms1, jax.random.PRNGKey(0))
mesh = jax.make_mesh((1, 2), ("data", "model"))
psv = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=64,
                       cache_dtype=jnp.float32, prefix_cache=True)
key = jax.random.PRNGKey(11)
shared = np.asarray(jax.random.randint(jax.random.fold_in(key, 0), (16,),
                                       0, cfg.vocab_size))
tails = [np.asarray(jax.random.randint(jax.random.fold_in(key, 1 + i),
                                       (8,), 0, cfg.vocab_size))
         for i in range(3)]
prompts = [np.concatenate([shared, t]) for t in tails]
res, rids = {}, {}
for name, ms, mk in (("tp1", ms1, None), ("tp2", ms2, mesh)):
    eng = PagedEngine(params, ms, psv, mesh=mk)
    r = [eng.add_request(prompts[0], 8)]       # donor: cold full prefill
    eng.drain()                                # donates the shared pages
    r += [eng.add_request(p, 8) for p in prompts[1:]]   # radix hits
    eng.drain()
    assert eng.counters["prefix_hits"] == 2, dict(eng.counters)
    assert eng.counters["suffix_prefills"] == 2, dict(eng.counters)
    assert not any(k[1] in ("prefill_full", "prefill_suffix")
                   for k in eng.telemetry.compiles), (
        dict(eng.telemetry.compiles))
    assert sum(1 for k in eng.telemetry.compiles
               if k[1] == "prefill_bucket") <= len(eng._buckets)
    res[name], rids[name] = eng, r
tp_same = all((res["tp1"].results[a] == res["tp2"].results[b]).all()
              for a, b in zip(rids["tp1"], rids["tp2"]))
psv_off = PagedServeConfig(n_slots=4, page_size=8, n_pages=33, max_len=64,
                           cache_dtype=jnp.float32)
eng_c = PagedEngine(params, ms2, psv_off, mesh=mesh)
crids = [eng_c.add_request(p, 8) for p in prompts]
eng_c.drain()
assert eng_c.counters["suffix_prefills"] == 0
cold_same = all((eng_c.results[c] == res["tp2"].results[b]).all()
                for c, b in zip(crids, rids["tp2"]))
sv = ServeConfig(max_len=64, temperature=0.0, cache_dtype=jnp.float32)
one_shot = all(
    (res["tp2"].results[b] ==
     sharded_generate(params, prompts[i][None], 8, ms=ms2, mesh=mesh,
                      sv=sv)[0]).all()
    for i, b in enumerate(rids["tp2"]))
print("RESULT " + json.dumps({"tp_same": tp_same, "cold_same": cold_same,
                              "one_shot": one_shot}))
""")
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT")][0][7:])
    assert res == {"tp_same": True, "cold_same": True,
                   "one_shot": True}, res


@pytest.mark.slow
def test_sharded_paged_step_matches_sharded_ring_step():
    """One decode step, same state: the shard_map'd PAGED program (pool +
    block tables) and the shard_map'd RING program pick the same next
    token from logits that agree to float tolerance."""
    out = run_multidevice(r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import transformer as T
from repro.serve import PagedEngine, PagedServeConfig, ServeConfig
from repro.serve.engine import make_sharded_prefill, make_sharded_serve_step

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=4)
plan = LPPlan(plan_range(cfg, 0, 4).pairs[:2])
ms = T.build_structure(cfg, plan=plan, tp=2)
params = T.init_params(ms, jax.random.PRNGKey(0))
mesh = jax.make_mesh((1, 2), ("data", "model"))
prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(5), (9,), 0,
                                       cfg.vocab_size))
MAXLEN = 32

# Ring: sharded prefill (sp off, exact length) + one sharded serve step.
sv = ServeConfig(max_len=MAXLEN, temperature=0.0, cache_dtype=jnp.float32)
pre, _, _ = make_sharded_prefill(ms, mesh, sv, batch=1, prompt_len=9,
                                 sp=False)
step, _, _, _ = make_sharded_serve_step(ms, mesh, sv, batch=1,
                                        shard_batch=False)
logits, rcaches = pre(params, jnp.asarray(prompt)[None])
tok0 = jnp.argmax(logits, -1).astype(jnp.int32)
key = jax.random.PRNGKey(0)
tok1_ring, _ = step(params, tok0, rcaches, jnp.int32(9), key)

# Paged: the engine's sharded prefill + one sharded paged decode step.
psv = PagedServeConfig(n_slots=2, page_size=8, n_pages=9, max_len=MAXLEN,
                       cache_dtype=jnp.float32)
eng = PagedEngine(params, ms, psv, mesh=mesh)
rid = eng.add_request(prompt, 2)
eng.step()            # admit + prefill + one decode
toks = eng.request(rid).out
match = (int(tok0[0]) == toks[0]) and (int(tok1_ring[0]) == toks[1])
print("RESULT " + json.dumps({"match": bool(match),
                              "toks": [int(t) for t in toks[:2]]}))
""")
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT")][0][7:])
    assert res["match"], res


@pytest.mark.slow
def test_pallas_head_selection_matches_xla_under_replicated_kv():
    """tp=4 > n_kv=2 (replicated kv): the Pallas paged kernels' in-kernel
    head_map selection must produce the same streams as the XLA gather
    path, which itself must match the tp=1 engine."""
    out = run_multidevice(r"""
import dataclasses, jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config, reduced_config
from repro.core.lp import LPPlan, plan_range
from repro.model import attention as A
from repro.model import transformer as T
from repro.serve import PagedEngine, PagedServeConfig

cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=4)
cfg = dataclasses.replace(cfg, n_kv_heads=2)
plan = LPPlan(plan_range(cfg, 0, 4).pairs[:2])
ms4 = T.build_structure(cfg, plan=plan, tp=4)
ms1 = T.build_structure(cfg, plan=plan, tp=1)
params = T.init_params(ms1, jax.random.PRNGKey(0))
mesh = jax.make_mesh((1, 4), ("data", "model"))
psv = PagedServeConfig(n_slots=4, page_size=8, n_pages=17, max_len=32,
                       cache_dtype=jnp.float32)
key = jax.random.PRNGKey(3)
prompts = [np.asarray(jax.random.randint(jax.random.fold_in(key, i),
                                         (L,), 0, cfg.vocab_size))
           for i, L in enumerate([6, 9, 12])]
outs = {}
for impl, ms, mk in (("xla", ms4, mesh), ("pallas", ms4, mesh),
                     ("tp1", ms1, None)):
    A.set_decode_impl("pallas" if impl == "pallas" else "xla")
    try:
        eng = PagedEngine(params, ms, psv, mesh=mk)
        rids = [eng.add_request(p, 8) for p in prompts]
        eng.drain()
        outs[impl] = [eng.results[r].tolist() for r in rids]
    finally:
        A.set_decode_impl("xla")
print("RESULT " + json.dumps({"px": outs["pallas"] == outs["xla"],
                              "x1": outs["xla"] == outs["tp1"]}))
""")
    res = json.loads([l for l in out.splitlines()
                      if l.startswith("RESULT")][0][7:])
    assert res == {"px": True, "x1": True}, res
