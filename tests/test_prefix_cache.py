"""Radix prefix cache: refcounted pages, copy-on-write sharing, exactness.

Three layers of guarantees:
  * PagePool refcounting — property-style random interleavings of
    alloc/share/free hold the generalized accounting invariant
    ``allocated - freed == live_unique``, never touch the garbage page,
    and only return a shared page to the free list at refcount 0.
  * Radix tree semantics — whole-page chunk matching, first-donor-wins
    insertion, LRU eviction of unlocked leaves only.
  * End-to-end bit-identity — a prefix-hit request produces EXACTLY the
    tokens of (i) the same request on a cold engine with sharing disabled
    and (ii) one-shot generate(); shared pages are never written
    (copy-on-write by construction).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.lp import plan_range
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import (PagedEngine, PagedServeConfig, PagePool,
                         PrefixCache, ServeConfig, generate)
from repro.serve import paged_cache as PG

PC = ParallelContext()
KEY = jax.random.PRNGKey(0)
PS = 8


# ---------------------------------------------------------------------------
# PagePool refcounting (property-style)
# ---------------------------------------------------------------------------

def test_pool_share_free_lifecycle():
    pool = PagePool(6)
    a = pool.alloc(2)
    assert a is not None and PG.GARBAGE_PAGE not in a
    pool.share(a)                      # second holder
    pool.check_balance()
    assert pool.live == 2
    pool.free(a)                       # first holder lets go
    assert pool.live == 2              # still resident: refcount 1
    assert pool.freed_total == 0
    pool.free(a)                       # last holder -> free list
    assert pool.live == 0 and pool.freed_total == 2
    pool.check_balance()


def test_pool_double_free_shared_page_only_recycles_at_zero():
    pool = PagePool(4)
    (p,) = pool.alloc(1)
    pool.share([p])
    pool.share([p])                    # refcount 3
    pool.free([p])
    pool.free([p])
    assert pool.n_free == 2            # still held by one reference
    assert pool.refcount(p) == 1
    pool.free([p])
    assert pool.n_free == 3 and pool.refcount(p) == 0
    with pytest.raises(AssertionError):
        pool.free([p])                 # freeing past zero is a bug


def test_pool_garbage_page_never_allocated_or_refcounted():
    pool = PagePool(5)
    seen = set()
    while True:
        got = pool.alloc(1)
        if got is None:
            break
        seen.update(got)
    assert PG.GARBAGE_PAGE not in seen and len(seen) == 4
    with pytest.raises(AssertionError):
        pool.share([PG.GARBAGE_PAGE])
    with pytest.raises(AssertionError):
        pool.free([PG.GARBAGE_PAGE])


def test_pool_random_interleavings_hold_invariant():
    """Seeded random alloc/share/free program against a model: after every
    operation ``allocated - freed == live_unique`` and the free list agrees
    with the refcounts."""
    rng = np.random.default_rng(7)
    pool = PagePool(17)
    held = []                          # (page, holders) live handles
    for _ in range(500):
        op = rng.integers(0, 3)
        if op == 0:
            n = int(rng.integers(1, 4))
            got = pool.alloc(n)
            if got is not None:
                held.extend((p, 1) for p in got)
        elif op == 1 and held:
            i = int(rng.integers(len(held)))
            p, h = held[i]
            pool.share([p])
            held[i] = (p, h + 1)
        elif op == 2 and held:
            i = int(rng.integers(len(held)))
            p, h = held[i]
            pool.free([p])
            if h == 1:
                held.pop(i)
            else:
                held[i] = (p, h - 1)
        pool.check_balance()
        assert pool.live == len({p for p, _ in held})
    for p, h in held:
        pool.free([p] * h)
    pool.check_balance()
    assert pool.live == 0


# ---------------------------------------------------------------------------
# Radix tree semantics
# ---------------------------------------------------------------------------

def _toks(*chunks):
    return np.asarray([t for c in chunks for t in c], np.int32)


def test_radix_match_whole_pages_and_insert_transfer():
    pool = PagePool(10)
    tree = PrefixCache(page_size=4)
    toks = _toks(range(4), range(10, 14), range(20, 22))   # 2.5 pages
    pages = pool.alloc(3)
    moved = tree.insert(toks, pages[:2], step=0)           # whole pages only
    assert moved == pages[:2] and tree.n_nodes == 2
    # Same chunks again: first donor wins, nothing transfers.
    pages2 = pool.alloc(2)
    assert tree.insert(toks[:8], pages2, step=1) == []
    pool.free(pages2)
    # Match walks chunk-by-chunk and respects the cap.
    m = tree.match(toks, max_pages=8, step=2)
    assert [n.page for n in m] == pages[:2]
    assert len(tree.match(toks, max_pages=1, step=2)) == 1
    # Diverging second chunk stops the walk after one page.
    other = _toks(range(4), range(99, 103))
    assert len(tree.match(other, max_pages=8, step=3)) == 1


def test_radix_evicts_lru_unlocked_leaves_only():
    pool = PagePool(10)
    tree = PrefixCache(page_size=2)
    # Two branches off one shared root chunk.
    pa = pool.alloc(2)
    pb = pool.alloc(1)
    tree.insert(_toks((0, 1), (2, 3)), pa, step=0)
    tree.insert(_toks((0, 1), (7, 8)), [pa[0], pb[0]], step=5)
    assert tree.n_nodes == 3 and pool.live == 3
    # Lock the (2, 3) leaf: only the (7, 8) leaf is evictable.
    path = tree.match(_toks((0, 1), (2, 3)), max_pages=2, step=6)
    tree.lock_path(path, pool, step=6)
    assert {n.page for n in tree.evictable_leaves()} == {pb[0]}
    assert tree.evict(5, pool) == 1          # leaf (7,8) only; root chunk
    assert tree.n_nodes == 2                 # is locked via the path
    tree.release_path(path, pool)
    # Now the whole chain peels leaf-first (LRU).
    assert tree.evict(5, pool) == 2
    assert tree.n_nodes == 0 and pool.live == 0
    pool.check_balance()


def test_radix_protect_set_survives_eviction():
    pool = PagePool(6)
    tree = PrefixCache(page_size=2)
    pg = pool.alloc(2)
    tree.insert(_toks((0, 1), (2, 3)), pg, step=0)
    path = tree.match(_toks((0, 1), (2, 3)), max_pages=2, step=1)
    freed = tree.evict(5, pool, protect={id(n) for n in path})
    assert freed == 0 and tree.n_nodes == 2


# ---------------------------------------------------------------------------
# Engine end-to-end: bit-identity + CoW
# ---------------------------------------------------------------------------

def _build(n_layers=4):
    cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=n_layers)
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, n_layers), tp=1)
    return cfg, ms, T.init_params(ms, KEY)


def _one_shot(params, ms, prompt, n_new, max_len):
    sv = ServeConfig(max_len=max_len, temperature=0.0,
                     cache_dtype=jnp.float32)
    return np.asarray(generate(params, jnp.asarray(prompt)[None], n_new,
                               ms=ms, pc=PC, sv=sv)[0])


def _family(cfg, shared_len, tail_len, n):
    shared = np.asarray(jax.random.randint(jax.random.fold_in(KEY, 1),
                                           (shared_len,), 0, cfg.vocab_size))
    return [np.concatenate([shared, np.asarray(jax.random.randint(
        jax.random.fold_in(KEY, 100 + i), (tail_len,), 0, cfg.vocab_size))])
        for i in range(n)]


def test_prefix_hit_bit_identical_to_cold_and_one_shot():
    """(a) of the acceptance gate: serve a donor, then same-prefix requests
    with sharing ON; tokens must equal both the sharing-OFF engine and
    one-shot generate(), while the engine reports real prefill savings."""
    cfg, ms, params = _build()
    prompts = _family(cfg, 16, 8, 4)
    psv = PagedServeConfig(n_slots=4, page_size=PS, n_pages=33, max_len=48,
                           cache_dtype=jnp.float32, prefix_cache=True)
    eng = PagedEngine(params, ms, psv)
    assert eng.prefix is not None
    rids = [eng.add_request(prompts[0], 6)]
    eng.drain()                        # donor finishes -> donates its pages
    rids += [eng.add_request(p, 6) for p in prompts[1:]]
    res = dict(eng.drain())
    assert eng.counters["prefix_hits"] >= len(prompts) - 1
    assert eng.counters["hit_tokens"] >= (len(prompts) - 1) * 16
    # Saved prefill compute: only the donor ran its full prompt.
    assert eng.counters["prefill_tokens"] == 24 + (len(prompts) - 1) * 8

    cold = PagedEngine(params, ms, PagedServeConfig(
        n_slots=4, page_size=PS, n_pages=33, max_len=48,
        cache_dtype=jnp.float32, prefix_cache=False))
    cold_rids = [cold.add_request(p, 6) for p in prompts]
    cold_res = cold.drain()
    for rid, crid, p in zip(rids, cold_rids, prompts):
        ref = _one_shot(params, ms, p, 6, psv.max_len)
        assert (res[rid] == ref).all(), rid
        assert (res[rid] == cold_res[crid]).all(), rid


def test_full_prompt_rematch_keeps_two_token_suffix():
    """An identical repeat request may match at most (Lp-2)//ps pages: the
    suffix forward needs >= 2 rows (1-row forwards lower to matvecs with a
    different reduction grouping — not bit-safe) and the last position's
    logits seed sampling. Exactness must survive the full-match edge."""
    cfg, ms, params = _build()
    prompt = np.asarray(jax.random.randint(jax.random.fold_in(KEY, 2),
                                           (24,), 0, cfg.vocab_size))
    psv = PagedServeConfig(n_slots=2, page_size=PS, n_pages=17, max_len=32,
                           cache_dtype=jnp.float32, prefix_cache=True)
    eng = PagedEngine(params, ms, psv)
    r0 = eng.add_request(prompt, 4)
    eng.drain()
    r1 = eng.add_request(prompt, 4)    # exact repeat
    res = eng.drain()
    # 24 tokens = 3 pages, but the cap is (24-2)//8 = 2 pages.
    assert eng.counters["hit_tokens"] == 16
    ref = _one_shot(params, ms, prompt, 4, psv.max_len)
    assert (res[r0] == ref).all() and (res[r1] == ref).all()


def test_shared_pages_are_never_written():
    """Copy-on-write by construction: serving prefix-hit requests must not
    change a single byte of the donated prefix pages."""
    cfg, ms, params = _build()
    prompts = _family(cfg, 16, 8, 3)
    psv = PagedServeConfig(n_slots=4, page_size=PS, n_pages=33, max_len=48,
                           cache_dtype=jnp.float32, prefix_cache=True)
    eng = PagedEngine(params, ms, psv)
    eng.add_request(prompts[0], 6)
    eng.drain()
    path = eng.prefix.match(prompts[0][:16], max_pages=2,
                            step=eng.step_count)
    shared_pages = jnp.asarray([n.page for n in path])
    before = [{k: np.asarray(jnp.take(v, shared_pages,
                                      axis=T.cache_batch_axis(k)))
               for k, v in seg.items()} for seg in eng.caches]
    for p in prompts[1:]:
        eng.add_request(p, 6)
    eng.drain()
    after = [{k: np.asarray(jnp.take(v, shared_pages,
                                     axis=T.cache_batch_axis(k)))
              for k, v in seg.items()} for seg in eng.caches]
    for sb, sa in zip(before, after):
        for k in sb:
            assert (sb[k] == sa[k]).all(), k


def test_eviction_under_pressure_then_still_exact():
    """A pool too small to keep donations resident must evict refcount-0
    leaves to admit new work — and stay bit-exact throughout."""
    cfg, ms, params = _build()
    prompts = _family(cfg, 16, 8, 2)
    other = np.asarray(jax.random.randint(jax.random.fold_in(KEY, 3),
                                          (24,), 0, cfg.vocab_size))
    # 6 allocatable pages; each request needs 4 -> donations must evict.
    psv = PagedServeConfig(n_slots=2, page_size=PS, n_pages=7, max_len=32,
                           cache_dtype=jnp.float32, prefix_cache=True)
    eng = PagedEngine(params, ms, psv)
    ra = eng.add_request(prompts[0], 8)
    eng.drain()
    assert eng.prefix.resident_pages > 0
    rb = eng.add_request(other, 8)      # no hit; needs eviction space
    eng.drain()
    assert eng.prefix.evicted_pages_total > 0
    rc = eng.add_request(prompts[1], 8)  # family member after eviction
    res = eng.drain()
    for rid, (p, n) in zip((ra, rb, rc),
                           [(prompts[0], 8), (other, 8), (prompts[1], 8)]):
        assert (res[rid] == _one_shot(params, ms, p, n, 32)).all(), rid
    eng.pool.check_balance()


def test_prefix_cache_disabled_for_state_models():
    """Mamba/rec state cannot resume from kv pages: the engine silently
    disables sharing (and still serves correctly)."""
    cfg = reduced_config(get_config("falcon-mamba-7b"), n_layers=4)
    ms = T.build_structure(cfg, plan=plan_range(cfg, 0, 4), tp=1)
    params = T.init_params(ms, KEY)
    psv = PagedServeConfig(n_slots=2, page_size=PS, n_pages=17, max_len=32,
                           cache_dtype=jnp.float32, prefix_cache=True)
    eng = PagedEngine(params, ms, psv)
    assert eng.prefix is None
    prompt = np.asarray(jax.random.randint(jax.random.fold_in(KEY, 4),
                                           (8,), 0, cfg.vocab_size))
    rid = eng.add_request(prompt, 4)
    res = eng.drain()
    assert (res[rid] == _one_shot(params, ms, prompt, 4, 32)).all()


def test_pool_drains_to_tree_residency_only():
    """After drain, live pages are exactly the tree's residents (requests
    hold nothing); disabling the tree recovers PR 2's drain-to-zero."""
    cfg, ms, params = _build()
    prompts = _family(cfg, 16, 8, 2)
    psv = PagedServeConfig(n_slots=4, page_size=PS, n_pages=33, max_len=48,
                           cache_dtype=jnp.float32, prefix_cache=True)
    eng = PagedEngine(params, ms, psv)
    for p in prompts:
        eng.add_request(p, 4)
    eng.drain()
    assert eng.pool.live == eng.prefix.resident_pages > 0
    eng.pool.check_balance()
