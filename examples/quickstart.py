"""Quickstart: convert a model to Layer Parallelism and serve it.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end on a CPU-sized model:
  1. build a model, 2. train it briefly, 3. apply the retraining-free LP
  merge at a chosen effective depth, 4. check perplexity before/after,
  5. generate text with the LP model.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.lp import lp_convert, plan_for_depth
from repro.data import DataConfig, SynthConfig, eval_ppl_batch, make_source
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.serve import ServeConfig, generate
from repro.train import OptConfig, TrainConfig
from repro.train.trainer import init_state, make_train_step, from_flat_global, _leaf_meta

PC = ParallelContext()


def main():
    # 1. A small llama-family model (reduced tinyllama, 8 layers).
    cfg = reduced_config(get_config("tinyllama-1.1b"), n_layers=8)
    ms = T.build_structure(cfg, tp=1)
    print(f"model: {cfg.name}, {cfg.n_layers} layers, "
          f"{T.param_count(ms) / 1e6:.1f}M params")

    # 2. Train briefly on the synthetic corpus.
    tc = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=20, total_steps=200))
    state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
    step = jax.jit(make_train_step(ms, PC, tc), donate_argnums=(0,))
    src = make_source(DataConfig(seq_len=64, global_batch=8),
                      SynthConfig(vocab_size=cfg.vocab_size))
    for s in range(200):
        state, m = step(state, src.batch_at(s))
        if s % 50 == 0:
            print(f"  step {s}: loss {float(m['loss']):.3f}")
    # fp32 weights out of the ZeRO shards
    tmpl, treedef, infos = _leaf_meta(ms)
    params = treedef.unflatten([
        from_flat_global(f, li.pd.shape, li.pspec, PC)
        for f, li in zip(treedef.flatten_up_to(state["master"]), infos)])

    # 3. Retraining-free LP conversion: depth 8 -> 6 (two pairs).
    plan = plan_for_depth(cfg, 6)
    print(f"LP plan: pairs={plan.pairs} -> effective depth "
          f"{plan.effective_depth(cfg.n_layers)}")
    layers = [jax.tree.map(lambda v: v[i], params["segments"][0])
              for i in range(cfg.n_layers)]
    segs, seg_params = lp_convert(cfg, layers, plan)
    lp_params = dict(params, segments=seg_params)
    ms_lp = T.build_structure(cfg, plan=plan, tp=1)

    # 4. Perplexity before/after (paper Fig. 6 in miniature).
    def ppl(p, m):
        b = eval_ppl_batch(jax.random.PRNGKey(99),
                           SynthConfig(vocab_size=cfg.vocab_size), 64, 8)
        loss, parts = T.loss_fn(p, b, ms=m, pc=PC)
        return float(jnp.exp(parts["xent"]))

    print(f"ppl vanilla = {ppl(params, ms):.3f}")
    print(f"ppl LP      = {ppl(lp_params, ms_lp):.3f}  "
          "(modest increase, zero retraining)")

    # 5. Generate with the LP model.
    sv = ServeConfig(max_len=128, temperature=0.8)
    prompts = src.batch_at(0)["tokens"][:2, :16]
    out = generate(lp_params, prompts, 16, ms=ms_lp, pc=PC, sv=sv,
                   key=jax.random.PRNGKey(7))
    print("generated:", out[0].tolist())


if __name__ == "__main__":
    main()
