"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
with the full production substrate — ZeRO optimizer sharding, WSD schedule,
grad accumulation, async checkpointing, restart-safe data order.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--tiny]

(--tiny shrinks to ~10M for a fast smoke on the CI CPU; the default ~100M
configuration is sized for a real run.)
"""
import argparse

import jax

from repro.configs.base import ArchConfig, LayerSpec
from repro.core.lp import plan_range
from repro.data import DataConfig, SynthConfig, make_source
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.train import OptConfig, TrainConfig, checkpoint as CK
from repro.train.trainer import init_state, make_train_step

PC = ParallelContext()


def build_cfg(tiny: bool) -> ArchConfig:
    if tiny:
        return ArchConfig(name="lm-10m", family="dense", n_layers=6,
                          d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024,
                          vocab_size=2048,
                          block_pattern=(LayerSpec(),))
    return ArchConfig(name="lm-100m", family="dense", n_layers=12,
                      d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                      vocab_size=32768,
                      block_pattern=(LayerSpec(),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--accum", type=int, default=2)
    ap.add_argument("--lp", action="store_true",
                    help="train WITH layer pairs active (LP-aware training)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    cfg = build_cfg(args.tiny)
    plan = plan_range(cfg, 2, cfg.n_layers - 2) if args.lp else None
    ms = T.build_structure(cfg, plan=plan, tp=1)
    print(f"{cfg.name}: {T.param_count(ms) / 1e6:.1f}M params, "
          f"effective depth {ms.effective_depth}/{cfg.n_layers}")

    tc = TrainConfig(
        opt=OptConfig(lr=6e-4, warmup_steps=args.steps // 10,
                      total_steps=args.steps, schedule="wsd",
                      weight_decay=0.1),
        accum=args.accum, remat=True)
    src = make_source(DataConfig(seq_len=args.seq_len,
                                 global_batch=args.global_batch),
                      SynthConfig(vocab_size=cfg.vocab_size))
    state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
    step = jax.jit(make_train_step(ms, PC, tc), donate_argnums=(0,))
    ckpt = CK.AsyncCheckpointer(args.ckpt_dir)

    tokens_per_step = args.seq_len * args.global_batch
    for s in range(args.steps):
        state, m = step(state, src.batch_at(s))
        if s % 20 == 0 or s == args.steps - 1:
            print(f"[{s:4d}] loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e} "
                  f"tokens={(s + 1) * tokens_per_step:,}", flush=True)
        if (s + 1) % 100 == 0:
            ckpt.save(CK.state_to_logical(state, ms, PC), s + 1)
    ckpt.save(CK.state_to_logical(state, ms, PC), args.steps)
    ckpt.wait()
    print(f"done; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
