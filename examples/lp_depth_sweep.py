"""Example: the accuracy/latency trade-off of LP at serving time.

    PYTHONPATH=src python examples/lp_depth_sweep.py

Trains a small model once, then sweeps the effective depth (the paper's Δ
knob), reporting perplexity and the structural decode-cost proxy (number of
TP sync points per token = 2 x effective depth) — a miniature of the
paper's Fig. 1 trade-off curve.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.core.lp import lp_convert, plan_for_depth
from repro.data import DataConfig, SynthConfig, eval_ppl_batch, make_source
from repro.model import transformer as T
from repro.parallel.context import ParallelContext
from repro.train import OptConfig, TrainConfig
from repro.train.trainer import (_leaf_meta, from_flat_global, init_state,
                                 make_train_step)

PC = ParallelContext()


def main():
    cfg = reduced_config(get_config("yi-6b"), n_layers=10)
    ms = T.build_structure(cfg, tp=1)
    tc = TrainConfig(opt=OptConfig(lr=2e-3, warmup_steps=20, total_steps=250))
    state = init_state(ms, jax.random.PRNGKey(0), PC, tc)
    step = jax.jit(make_train_step(ms, PC, tc), donate_argnums=(0,))
    sc = SynthConfig(vocab_size=cfg.vocab_size)
    src = make_source(DataConfig(seq_len=64, global_batch=8), sc)
    for s in range(250):
        state, m = step(state, src.batch_at(s))
    print(f"trained: final loss {float(m['loss']):.3f}")

    tmpl, treedef, infos = _leaf_meta(ms)
    params = treedef.unflatten([
        from_flat_global(f, li.pd.shape, li.pspec, PC)
        for f, li in zip(treedef.flatten_up_to(state["master"]), infos)])
    layers = [jax.tree.map(lambda v: v[i], params["segments"][0])
              for i in range(cfg.n_layers)]

    def ppl(p, m_):
        b = eval_ppl_batch(jax.random.PRNGKey(99), sc, 64, 8)
        _, parts = T.loss_fn(p, b, ms=m_, pc=PC)
        return float(jnp.exp(parts["xent"]))

    print(f"\n{'depth':>6s} {'Δ':>3s} {'syncs/token':>12s} {'ppl':>8s}")
    for depth in range(cfg.n_layers, cfg.n_layers - 5, -1):
        plan = plan_for_depth(cfg, depth)
        segs, seg_params = lp_convert(cfg, layers, plan)
        p = dict(params, segments=seg_params)
        m_ = T.build_structure(cfg, plan=plan, tp=1)
        print(f"{depth:6d} {plan.delta:3d} {2 * depth:12d} "
              f"{ppl(p, m_):8.3f}")


if __name__ == "__main__":
    main()
